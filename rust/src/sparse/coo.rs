//! Third-order COO sparse tensor — used ONLY by the baseline comparator.
//!
//! The paper's baseline ("Sparse PARAFAC2", Kiers' algorithm adjusted for
//! sparse tensors per Chew et al. [12] + Tensor Toolbox [5]) materializes
//! the intermediate tensor `Y ∈ R^{R×J×K}` as an explicit sparse tensor
//! every ALS iteration and runs Tensor-Toolbox-style MTTKRPs on it. That
//! explicit structure — 3 indices + 1 value per nonzero, re-sorted per
//! mode — is exactly the memory/time overhead SPARTan eliminates, so this
//! module implements it faithfully (including the per-column `accumarray`
//! temporary of TTB's `mttkrp`) rather than charitably.

use crate::linalg::Mat;
use crate::util::membudget::{BudgetExceeded, MemBudget};

/// COO sparse 3-way tensor with u32 coordinates.
#[derive(Clone, Debug)]
pub struct CooTensor3 {
    dims: [usize; 3],
    subs: Vec<[u32; 3]>,
    vals: Vec<f64>,
    /// Which mode the nonzeros are currently sorted by (TTB keeps a sort
    /// order and re-sorts on matricization; we track it to charge that
    /// reorganization cost when modes change).
    sorted_mode: Option<usize>,
}

impl CooTensor3 {
    pub fn new(dims: [usize; 3]) -> CooTensor3 {
        CooTensor3 { dims, subs: Vec::new(), vals: Vec::new(), sorted_mode: None }
    }

    /// Reserve for `n` nonzeros, charging the memory budget.
    pub fn reserve(&mut self, n: usize, budget: &MemBudget) -> Result<(), BudgetExceeded> {
        budget.charge((n * (std::mem::size_of::<[u32; 3]>() + 8)) as u64)?;
        self.subs.reserve(n);
        self.vals.reserve(n);
        Ok(())
    }

    #[inline]
    pub fn push(&mut self, i: u32, j: u32, k: u32, v: f64) {
        debug_assert!((i as usize) < self.dims[0] && (j as usize) < self.dims[1] && (k as usize) < self.dims[2]);
        self.subs.push([i, j, k]);
        self.vals.push(v);
        self.sorted_mode = None;
    }

    pub fn dims(&self) -> [usize; 3] {
        self.dims
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn heap_bytes(&self) -> u64 {
        (self.subs.capacity() * std::mem::size_of::<[u32; 3]>()
            + self.vals.capacity() * std::mem::size_of::<f64>()) as u64
    }

    /// Sort nonzeros by the given mode's index (TTB's matricization step).
    /// This is deliberately a real sort — the data reorganization the paper
    /// charges the baseline for — and its transient copies (permutation +
    /// reordered subs/vals, ≈ another full tensor) are charged against the
    /// memory budget, mirroring how Matlab's `permute`/`sort` double the
    /// footprint.
    pub fn sort_by_mode(&mut self, mode: usize, budget: &MemBudget) -> Result<(), BudgetExceeded> {
        if self.sorted_mode == Some(mode) {
            return Ok(());
        }
        let n = self.nnz();
        let transient =
            (n * (std::mem::size_of::<usize>() + std::mem::size_of::<[u32; 3]>() + 8)) as u64;
        budget.charge(transient)?;
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by_key(|&t| self.subs[t][mode]);
        let subs = order.iter().map(|&t| self.subs[t]).collect();
        let vals = order.iter().map(|&t| self.vals[t]).collect();
        self.subs = subs;
        self.vals = vals;
        self.sorted_mode = Some(mode);
        budget.release(transient);
        Ok(())
    }

    /// Tensor-Toolbox-style MTTKRP for `mode`:
    /// `M = X_(mode) · (C ⊙ B)` where `(B, C)` are the factor matrices of
    /// the other two modes in ascending mode order.
    ///
    /// Matches TTB `mttkrp(X, U, n)` column-by-column: for each rank
    /// column r it materializes the nnz-length elementwise product
    /// `vals .* B(j,r) .* C(k,r)` and `accumarray`s it into `M(:,r)` —
    /// including the nnz-sized temporary, charged to `budget`.
    pub fn mttkrp(
        &mut self,
        mode: usize,
        factors: [&Mat; 3],
        budget: &MemBudget,
    ) -> Result<Mat, BudgetExceeded> {
        assert!(mode < 3);
        let (mb, mc) = match mode {
            0 => (1, 2),
            1 => (0, 2),
            _ => (0, 1),
        };
        let b = factors[mb];
        let c = factors[mc];
        assert_eq!(b.rows(), self.dims[mb], "factor {mb} rows mismatch");
        assert_eq!(c.rows(), self.dims[mc], "factor {mc} rows mismatch");
        let r = b.cols();
        assert_eq!(c.cols(), r);

        self.sort_by_mode(mode, budget)?;

        let out_rows = self.dims[mode];
        budget.charge((out_rows * r * 8) as u64)?;
        let mut m = Mat::zeros(out_rows, r);

        // TTB materializes one nnz-length temporary per rank column.
        budget.charge((self.nnz() * 8) as u64)?;
        let mut tmp = vec![0.0f64; self.nnz()];
        for col in 0..r {
            for (t, sub) in self.subs.iter().enumerate() {
                tmp[t] = self.vals[t]
                    * b[(sub[mb] as usize, col)]
                    * c[(sub[mc] as usize, col)];
            }
            // accumarray over the target mode index
            for (t, sub) in self.subs.iter().enumerate() {
                m[(sub[mode] as usize, col)] += tmp[t];
            }
        }
        budget.release((self.nnz() * 8) as u64);
        Ok(m)
    }

    /// Dense materialization (tests only).
    pub fn to_dense(&self) -> Vec<Mat> {
        // one Mat (dims[0] × dims[1]) per frontal slice k
        let mut out: Vec<Mat> = (0..self.dims[2]).map(|_| Mat::zeros(self.dims[0], self.dims[1])).collect();
        for (sub, &v) in self.subs.iter().zip(&self.vals) {
            out[sub[2] as usize][(sub[0] as usize, sub[1] as usize)] += v;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{khatri_rao, matmul};
    use crate::util::rng::Pcg64;

    /// Reference MTTKRP via explicit matricization + KRP.
    fn reference_mttkrp(t: &CooTensor3, mode: usize, factors: [&Mat; 3]) -> Mat {
        let dims = t.dims();
        let (mb, mc) = match mode {
            0 => (1, 2),
            1 => (0, 2),
            _ => (0, 1),
        };
        // X_(mode) as dense (dims[mode] × dims[mb]*dims[mc]) with column
        // index = i_b + i_c * dims[mb]  (matches KRP (C ⊙ B) row order).
        let mut x = Mat::zeros(dims[mode], dims[mb] * dims[mc]);
        for (sub, &v) in t.subs.iter().zip(&t.vals) {
            let col = sub[mb] as usize + sub[mc] as usize * dims[mb];
            x[(sub[mode] as usize, col)] += v;
        }
        let krp = khatri_rao(factors[mc], factors[mb]); // (C ⊙ B)
        matmul(&x, &krp)
    }

    fn random_tensor(rng: &mut Pcg64, dims: [usize; 3], nnz: usize) -> CooTensor3 {
        let mut t = CooTensor3::new(dims);
        for _ in 0..nnz {
            t.push(
                rng.below(dims[0] as u64) as u32,
                rng.below(dims[1] as u64) as u32,
                rng.below(dims[2] as u64) as u32,
                rng.normal(),
            );
        }
        t
    }

    #[test]
    fn mttkrp_matches_reference_all_modes() {
        let mut rng = Pcg64::seed(81);
        let dims = [4, 6, 5];
        let mut t = random_tensor(&mut rng, dims, 40);
        let f0 = Mat::rand_normal(4, 3, &mut rng);
        let f1 = Mat::rand_normal(6, 3, &mut rng);
        let f2 = Mat::rand_normal(5, 3, &mut rng);
        let budget = MemBudget::unlimited();
        for mode in 0..3 {
            let got = t.mttkrp(mode, [&f0, &f1, &f2], &budget).unwrap();
            let want = reference_mttkrp(&t, mode, [&f0, &f1, &f2]);
            assert!(got.max_abs_diff(&want) < 1e-10, "mode {mode}");
        }
    }

    #[test]
    fn sort_by_mode_is_stable_result() {
        let mut rng = Pcg64::seed(82);
        let mut t = random_tensor(&mut rng, [3, 3, 3], 20);
        let f = Mat::rand_normal(3, 2, &mut rng);
        let budget = MemBudget::unlimited();
        let a = t.mttkrp(0, [&f, &f, &f], &budget).unwrap();
        t.sort_by_mode(2, &budget).unwrap();
        t.sort_by_mode(0, &budget).unwrap();
        let b = t.mttkrp(0, [&f, &f, &f], &budget).unwrap();
        assert!(a.max_abs_diff(&b) < 1e-12);
    }

    #[test]
    fn budget_exceeded_on_reserve() {
        let budget = MemBudget::limited(100);
        let mut t = CooTensor3::new([10, 10, 10]);
        assert!(t.reserve(1000, &budget).is_err());
    }

    #[test]
    fn budget_exceeded_in_mttkrp_temp() {
        let mut rng = Pcg64::seed(83);
        let mut t = random_tensor(&mut rng, [4, 4, 4], 50);
        let f = Mat::rand_normal(4, 2, &mut rng);
        // budget covers the output but not the nnz-length temp
        let budget = MemBudget::limited((4 * 2 * 8 + 100) as u64);
        assert!(t.mttkrp(0, [&f, &f, &f], &budget).is_err());
    }

    #[test]
    fn to_dense_roundtrip() {
        let mut t = CooTensor3::new([2, 3, 2]);
        t.push(0, 1, 0, 5.0);
        t.push(1, 2, 1, -2.0);
        let d = t.to_dense();
        assert_eq!(d[0][(0, 1)], 5.0);
        assert_eq!(d[1][(1, 2)], -2.0);
        assert_eq!(d[0][(1, 2)], 0.0);
    }
}
