//! Compressed Sparse Row matrix — the storage format for the input slices
//! `X_k` and (in the baseline) anything derived from them.
//!
//! Column indices are `u32` (the variable mode J tops out in the tens of
//! thousands here and in the paper), values are `f64` to match the Matlab
//! double-precision reference.

use crate::linalg::Mat;

/// Immutable CSR sparse matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// Row pointers, `rows + 1` entries.
    indptr: Vec<usize>,
    /// Column index per nonzero, sorted within each row.
    indices: Vec<u32>,
    /// Value per nonzero.
    values: Vec<f64>,
}

impl Csr {
    /// Build from COO triplets (duplicates are summed, zeros dropped).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: impl IntoIterator<Item = (usize, usize, f64)>,
    ) -> Csr {
        let mut items: Vec<(usize, u32, f64)> = triplets
            .into_iter()
            .inspect(|&(r, c, _)| {
                assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds {rows}x{cols}")
            })
            .map(|(r, c, v)| (r, c as u32, v))
            .collect();
        items.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<u32> = Vec::with_capacity(items.len());
        let mut values: Vec<f64> = Vec::with_capacity(items.len());
        let mut prev: Option<(usize, u32)> = None;
        for (r, c, v) in items {
            if prev == Some((r, c)) {
                // duplicate coordinate: accumulate
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(c);
                values.push(v);
                indptr[r + 1] += 1;
                prev = Some((r, c));
            }
        }
        // prefix-sum the per-row counts
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        // drop explicit zeros
        let mut out = Csr { rows, cols, indptr, indices, values };
        out.prune_zeros();
        out
    }

    fn prune_zeros(&mut self) {
        if self.values.iter().all(|&v| v != 0.0) {
            return;
        }
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(self.indices.len());
        let mut values = Vec::with_capacity(self.values.len());
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                if self.values[k] != 0.0 {
                    indices.push(self.indices[k]);
                    values.push(self.values[k]);
                }
            }
            indptr[r + 1] = indices.len();
        }
        self.indptr = indptr;
        self.indices = indices;
        self.values = values;
    }

    /// Build directly from raw CSR arrays, validating structure *and*
    /// values. Rejects a wrong-length or non-monotone `indptr`, unsorted
    /// or out-of-bounds column indices, and non-finite values — a NaN
    /// entering the fit would silently poison every factor, so it is
    /// refused at the trust boundary instead (the loaders surface this
    /// as an input error; the daemon as `invalid_data` on the wire).
    pub fn try_from_raw(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Result<Csr, String> {
        if indptr.len() != rows + 1 {
            return Err(format!("indptr has {} entries, want rows+1 = {}", indptr.len(), rows + 1));
        }
        if indptr[0] != 0 {
            return Err(format!("indptr[0] = {} (want 0)", indptr[0]));
        }
        if *indptr.last().unwrap() != indices.len() {
            return Err(format!(
                "indptr ends at {} but there are {} column indices",
                indptr.last().unwrap(),
                indices.len()
            ));
        }
        if indices.len() != values.len() {
            return Err(format!("{} column indices vs {} values", indices.len(), values.len()));
        }
        // monotonicity first: it bounds every row slice taken below
        for r in 0..rows {
            if indptr[r] > indptr[r + 1] {
                return Err(format!("indptr not monotone at row {r}"));
            }
        }
        for r in 0..rows {
            let row = &indices[indptr[r]..indptr[r + 1]];
            for w in row.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("columns not strictly sorted in row {r}"));
                }
            }
            if let Some(&last) = row.last() {
                if (last as usize) >= cols {
                    return Err(format!("column {last} out of bounds (J = {cols}) in row {r}"));
                }
            }
        }
        if let Some(p) = values.iter().position(|v| !v.is_finite()) {
            return Err(format!("value at nonzero {p} is not finite ({})", values[p]));
        }
        Ok(Csr { rows, cols, indptr, indices, values })
    }

    /// Build directly from raw CSR arrays; panics on invalid input — use
    /// [`Csr::try_from_raw`] for untrusted data.
    pub fn from_raw(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Csr {
        match Csr::try_from_raw(rows, cols, indptr, indices, values) {
            Ok(m) => m,
            Err(e) => panic!("Csr::from_raw: {e}"),
        }
    }

    /// Dense → CSR (tests and small examples).
    pub fn from_dense(m: &Mat) -> Csr {
        let mut trips = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    trips.push((i, j, v));
                }
            }
        }
        Csr::from_triplets(m.rows(), m.cols(), trips)
    }

    /// CSR → dense (tests and small examples).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for (c, v) in self.row_iter(r) {
                m[(r, c as usize)] = v;
            }
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Number of nonzeros in row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Iterate `(col, value)` over row `r`.
    #[inline]
    pub fn row_iter(&self, r: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// Raw slices of row `r`: (column indices, values).
    #[inline]
    pub fn row_parts(&self, r: usize) -> (&[u32], &[f64]) {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }

    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Sorted list of columns that contain at least one nonzero — the
    /// "column support" whose exploitation is SPARTan's core trick.
    pub fn col_support(&self) -> Vec<u32> {
        let mut seen = vec![false; self.cols];
        for &c in &self.indices {
            seen[c as usize] = true;
        }
        (0..self.cols as u32).filter(|&c| seen[c as usize]).collect()
    }

    /// Count of nonzero columns without materializing the support.
    pub fn col_support_size(&self) -> usize {
        let mut seen = vec![false; self.cols];
        let mut n = 0;
        for &c in &self.indices {
            if !seen[c as usize] {
                seen[c as usize] = true;
                n += 1;
            }
        }
        n
    }

    /// Drop all-zero rows (the paper filters them: every retained
    /// observation has at least one recorded event). Returns the new
    /// matrix and the kept original row ids.
    pub fn filter_zero_rows(&self) -> (Csr, Vec<usize>) {
        let kept: Vec<usize> = (0..self.rows).filter(|&r| self.row_nnz(r) > 0).collect();
        let mut indptr = Vec::with_capacity(kept.len() + 1);
        indptr.push(0);
        let mut indices = Vec::with_capacity(self.nnz());
        let mut values = Vec::with_capacity(self.nnz());
        for &r in &kept {
            let (cs, vs) = self.row_parts(r);
            indices.extend_from_slice(cs);
            values.extend_from_slice(vs);
            indptr.push(indices.len());
        }
        (
            Csr { rows: kept.len(), cols: self.cols, indptr, indices, values },
            kept,
        )
    }

    /// `self · dense` → dense (rows × dense.cols()); streams CSR rows on
    /// the shape-A register-blocked micro-kernel
    /// ([`crate::linalg::kernels::sparse_row_axpy`]: 4 nonzeros in flight,
    /// R-unrolled panel; bitwise identical to the scalar row loop). This
    /// is the `C_k = X_k V` stage of every Procrustes target.
    pub fn matmul_dense(&self, dense: &Mat) -> Mat {
        assert_eq!(self.cols, dense.rows(), "spmm dim mismatch");
        let mut out = Mat::zeros(self.rows, dense.cols());
        for r in 0..self.rows {
            let (cols, vals) = self.row_parts(r);
            crate::linalg::kernels::sparse_row_axpy(vals, cols, dense, out.row_mut(r));
        }
        out
    }

    /// `selfᵀ · dense` → dense (cols × dense.cols()); scatter over rows.
    pub fn t_matmul_dense(&self, dense: &Mat) -> Mat {
        assert_eq!(self.rows, dense.rows(), "spmm^T dim mismatch");
        let mut out = Mat::zeros(self.cols, dense.cols());
        for r in 0..self.rows {
            let drow = dense.row(r);
            for (c, v) in self.row_iter(r) {
                let orow = out.row_mut(c as usize);
                for (o, &d) in orow.iter_mut().zip(drow) {
                    *o += v * d;
                }
            }
        }
        out
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Heap bytes used (for the memory-budget accounting).
    pub fn heap_bytes(&self) -> u64 {
        (self.indptr.capacity() * std::mem::size_of::<usize>()
            + self.indices.capacity() * std::mem::size_of::<u32>()
            + self.values.capacity() * std::mem::size_of::<f64>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_triplets_basic() {
        let m = Csr::from_triplets(3, 4, vec![(0, 1, 2.0), (2, 3, 5.0), (0, 0, 1.0)]);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(1), 0);
        let d = m.to_dense();
        assert_eq!(d[(0, 0)], 1.0);
        assert_eq!(d[(0, 1)], 2.0);
        assert_eq!(d[(2, 3)], 5.0);
    }

    #[test]
    fn duplicates_are_summed() {
        let m = Csr::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.5), (1, 1, -1.0), (1, 1, 1.0)]);
        assert_eq!(m.to_dense()[(0, 0)], 3.5);
        // (1,1) summed to zero → pruned
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn dense_roundtrip() {
        let d = Mat::from_rows(&[&[0.0, 1.5, 0.0], &[2.0, 0.0, 0.0]]);
        let s = Csr::from_dense(&d);
        assert_eq!(s.to_dense(), d);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn col_support_detects() {
        let m = Csr::from_triplets(3, 5, vec![(0, 4, 1.0), (1, 1, 1.0), (2, 4, 2.0)]);
        assert_eq!(m.col_support(), vec![1, 4]);
        assert_eq!(m.col_support_size(), 2);
    }

    #[test]
    fn filter_zero_rows_keeps_ids() {
        let m = Csr::from_triplets(4, 2, vec![(1, 0, 1.0), (3, 1, 2.0)]);
        let (f, kept) = m.filter_zero_rows();
        assert_eq!(kept, vec![1, 3]);
        assert_eq!(f.rows(), 2);
        assert_eq!(f.to_dense()[(0, 0)], 1.0);
        assert_eq!(f.to_dense()[(1, 1)], 2.0);
    }

    #[test]
    fn spmm_matches_dense() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seed(71);
        let dense_a = Mat::from_fn(6, 8, |_, _| {
            if rng.chance(0.3) {
                rng.normal()
            } else {
                0.0
            }
        });
        let a = Csr::from_dense(&dense_a);
        let b = Mat::rand_normal(8, 5, &mut rng);
        let want = crate::linalg::matmul(&dense_a, &b);
        assert!(a.matmul_dense(&b).max_abs_diff(&want) < 1e-12);
        let c = Mat::rand_normal(6, 4, &mut rng);
        let want_t = crate::linalg::matmul(&dense_a.transpose(), &c);
        assert!(a.t_matmul_dense(&c).max_abs_diff(&want_t) < 1e-12);
    }

    #[test]
    fn from_raw_validates() {
        let m = Csr::from_raw(2, 3, vec![0, 1, 2], vec![2, 0], vec![1.0, 2.0]);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    #[should_panic]
    fn from_raw_rejects_unsorted() {
        Csr::from_raw(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 2.0]);
    }

    #[test]
    fn try_from_raw_rejects_structurally_bad_arrays() {
        // non-monotone indptr (terminal entry still matches nnz)
        let e = Csr::try_from_raw(2, 3, vec![0, 2, 1], vec![0], vec![1.0]).unwrap_err();
        assert!(e.contains("monotone"), "{e}");
        // wrong indptr length
        assert!(Csr::try_from_raw(2, 3, vec![0, 1], vec![0], vec![1.0]).is_err());
        // nonzero first entry
        assert!(Csr::try_from_raw(1, 3, vec![1, 1], vec![], vec![]).is_err());
        // terminal entry disagrees with nnz
        let e = Csr::try_from_raw(1, 3, vec![0, 2], vec![0], vec![1.0]).unwrap_err();
        assert!(e.contains("column indices"), "{e}");
        // indices/values length mismatch
        assert!(Csr::try_from_raw(1, 3, vec![0, 2], vec![0, 1], vec![1.0]).is_err());
        // column out of bounds
        let e = Csr::try_from_raw(1, 2, vec![0, 1], vec![5], vec![1.0]).unwrap_err();
        assert!(e.contains("out of bounds"), "{e}");
    }

    #[test]
    fn try_from_raw_rejects_non_finite_values() {
        let e = Csr::try_from_raw(1, 2, vec![0, 2], vec![0, 1], vec![1.0, f64::NAN]).unwrap_err();
        assert!(e.contains("not finite"), "{e}");
        let e = Csr::try_from_raw(1, 2, vec![0, 1], vec![0], vec![f64::INFINITY]).unwrap_err();
        assert!(e.contains("not finite"), "{e}");
        let e = Csr::try_from_raw(1, 2, vec![0, 1], vec![1], vec![f64::NEG_INFINITY]);
        assert!(e.is_err());
        // -0.0 and subnormals are finite — they must pass
        let ok = Csr::try_from_raw(1, 2, vec![0, 2], vec![0, 1], vec![-0.0, 5e-324]);
        assert!(ok.is_ok());
    }

    #[test]
    fn fro_norm_sq() {
        let m = Csr::from_triplets(2, 2, vec![(0, 0, 3.0), (1, 1, 4.0)]);
        assert_eq!(m.fro_norm_sq(), 25.0);
    }
}
