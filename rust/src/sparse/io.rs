//! Binary + text serialization for irregular tensors.
//!
//! Format `SPT1` (little-endian):
//! ```text
//! magic   b"SPT1"
//! u64     K (number of subjects)
//! u64     J (shared variable count)
//! per subject k:
//!   u64   I_k (rows)
//!   u64   nnz_k
//!   u64 × (I_k + 1)  indptr
//!   u32 × nnz_k      column indices
//!   f64 × nnz_k      values
//! ```
//! Plus a simple text loader for triplet files
//! (`k i j value` per line, whitespace-separated, `#` comments) so users
//! can bring their own data without writing the binary format.

use super::csr::Csr;
use super::irregular::IrregularTensor;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SPT1";

/// Write an irregular tensor in SPT1 binary format.
pub fn save_binary(t: &IrregularTensor, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(t.k() as u64).to_le_bytes())?;
    w.write_all(&(t.j() as u64).to_le_bytes())?;
    for k in 0..t.k() {
        let s = t.slice(k);
        w.write_all(&(s.rows() as u64).to_le_bytes())?;
        w.write_all(&(s.nnz() as u64).to_le_bytes())?;
        for &p in s.indptr() {
            w.write_all(&(p as u64).to_le_bytes())?;
        }
        for &c in s.indices() {
            w.write_all(&c.to_le_bytes())?;
        }
        for &v in s.values() {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read an irregular tensor in SPT1 binary format.
pub fn load_binary(path: &Path) -> Result<IrregularTensor> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not an SPT1 file (bad magic)", path.display());
    }
    let k = read_u64(&mut r)? as usize;
    let j = read_u64(&mut r)? as usize;
    if k == 0 {
        bail!("{}: zero subjects", path.display());
    }
    let mut slices = Vec::with_capacity(k);
    for idx in 0..k {
        let rows = read_u64(&mut r)? as usize;
        let nnz = read_u64(&mut r)? as usize;
        let mut indptr = Vec::with_capacity(rows + 1);
        for _ in 0..=rows {
            indptr.push(read_u64(&mut r)? as usize);
        }
        let mut indices = Vec::with_capacity(nnz);
        let mut buf4 = [0u8; 4];
        for _ in 0..nnz {
            r.read_exact(&mut buf4)?;
            indices.push(u32::from_le_bytes(buf4));
        }
        let mut values = Vec::with_capacity(nnz);
        let mut buf8 = [0u8; 8];
        for _ in 0..nnz {
            r.read_exact(&mut buf8)?;
            values.push(f64::from_le_bytes(buf8));
        }
        if *indptr.last().unwrap_or(&0) != nnz {
            bail!("{}: slice {idx} indptr/nnz mismatch", path.display());
        }
        // full structural + value validation: non-monotone indptr,
        // unsorted/out-of-range columns, and NaN/Inf values are load
        // errors here, never a corrupted fit later
        let slice = Csr::try_from_raw(rows, j, indptr, indices, values)
            .map_err(|e| anyhow!("{}: slice {idx}: {e}", path.display()))?;
        slices.push(slice);
    }
    Ok(IrregularTensor::new_unchecked(slices))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

/// Load a whitespace-separated triplet file: `k i j value` per line
/// (0-based indices). Lines starting with `#` are comments. Dimensions are
/// inferred; subjects are compacted to the observed max index + 1.
pub fn load_triplets_text(path: &Path) -> Result<IrregularTensor> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let r = BufReader::new(f);
    let mut per_subject: Vec<Vec<(usize, usize, f64)>> = Vec::new();
    let mut max_j = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let mut it = trimmed.split_whitespace();
        let parse = |tok: Option<&str>, what: &str| -> Result<f64> {
            tok.with_context(|| format!("line {}: missing {what}", lineno + 1))?
                .parse::<f64>()
                .with_context(|| format!("line {}: bad {what}", lineno + 1))
        };
        let k = parse(it.next(), "subject")? as usize;
        let i = parse(it.next(), "row")? as usize;
        let j = parse(it.next(), "col")? as usize;
        let v = parse(it.next(), "value")?;
        if !v.is_finite() {
            bail!("{}: line {}: value {v} is not finite", path.display(), lineno + 1);
        }
        if k >= per_subject.len() {
            per_subject.resize_with(k + 1, Vec::new);
        }
        max_j = max_j.max(j);
        per_subject[k].push((i, j, v));
    }
    if per_subject.is_empty() {
        bail!("{}: no triplets found", path.display());
    }
    let j_dim = max_j + 1;
    let slices: Vec<Csr> = per_subject
        .into_iter()
        .map(|trips| {
            let rows = trips.iter().map(|&(i, _, _)| i + 1).max().unwrap_or(0);
            Csr::from_triplets(rows.max(1), j_dim, trips)
        })
        .collect();
    Ok(IrregularTensor::new(slices))
}

/// Write an irregular tensor as a triplet text file (inverse of
/// [`load_triplets_text`]).
pub fn save_triplets_text(t: &IrregularTensor, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# SPARTan irregular tensor: k i j value ({} subjects, J={})", t.k(), t.j())?;
    for k in 0..t.k() {
        let s = t.slice(k);
        for i in 0..s.rows() {
            for (j, v) in s.row_iter(i) {
                writeln!(w, "{k} {i} {j} {v}")?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_irregular(seed: u64) -> IrregularTensor {
        let mut rng = Pcg64::seed(seed);
        let j = 12;
        let slices: Vec<Csr> = (0..5)
            .map(|_| {
                let rows = rng.range(1, 8);
                let nnz = rng.range(1, rows * 3 + 1);
                let trips: Vec<(usize, usize, f64)> = (0..nnz)
                    .map(|_| (rng.range(0, rows), rng.range(0, j), rng.normal()))
                    .collect();
                Csr::from_triplets(rows, j, trips)
            })
            .collect();
        IrregularTensor::new(slices)
    }

    #[test]
    fn binary_roundtrip() {
        let t = random_irregular(91);
        let dir = std::env::temp_dir();
        let path = dir.join("spartan_io_test.spt");
        save_binary(&t, &path).unwrap();
        let t2 = load_binary(&path).unwrap();
        assert_eq!(t.k(), t2.k());
        assert_eq!(t.j(), t2.j());
        assert_eq!(t.nnz(), t2.nnz());
        for k in 0..t.k() {
            assert_eq!(t.slice(k), t2.slice(k));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn text_roundtrip() {
        let t = random_irregular(92);
        let dir = std::env::temp_dir();
        let path = dir.join("spartan_io_test.txt");
        save_triplets_text(&t, &path).unwrap();
        let t2 = load_triplets_text(&path).unwrap();
        assert_eq!(t.k(), t2.k());
        assert_eq!(t.nnz(), t2.nnz());
        for k in 0..t.k() {
            // dense compare handles any J-dim inference differences
            let a = t.slice(k).to_dense();
            let b = t2.slice(k).to_dense();
            for i in 0..a.rows() {
                for j in 0..a.cols().min(b.cols()) {
                    assert!((a[(i, j)] - b[(i, j)]).abs() < 1e-12);
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir();
        let path = dir.join("spartan_io_bad.spt");
        std::fs::write(&path, b"NOPE123456").unwrap();
        assert!(load_binary(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn binary_load_rejects_nan_values() {
        // corrupt a valid file: overwrite slice 0's first value with NaN
        let t = IrregularTensor::new(vec![Csr::from_triplets(
            2,
            3,
            vec![(0, 0, 1.0), (1, 2, 2.0)],
        )]);
        let dir = std::env::temp_dir();
        let path = dir.join("spartan_io_nan.spt");
        save_binary(&t, &path).unwrap();
        // layout: magic 4 + K 8 + J 8 + rows 8 + nnz 8 + indptr 3×8 + indices 2×4
        let off = 4 + 8 + 8 + 8 + 8 + 3 * 8 + 2 * 4;
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[off..off + 8].copy_from_slice(&f64::NAN.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_binary(&path).unwrap_err().to_string();
        assert!(err.contains("not finite"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn binary_load_rejects_non_monotone_indptr() {
        let t = IrregularTensor::new(vec![Csr::from_triplets(
            2,
            3,
            vec![(0, 0, 1.0), (1, 2, 2.0)],
        )]);
        let dir = std::env::temp_dir();
        let path = dir.join("spartan_io_indptr.spt");
        save_binary(&t, &path).unwrap();
        // indptr starts after magic 4 + K 8 + J 8 + rows 8 + nnz 8; bump
        // the middle entry above the terminal one → non-monotone
        let off = 4 + 8 + 8 + 8 + 8 + 8;
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[off..off + 8].copy_from_slice(&5u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_binary(&path).unwrap_err().to_string();
        assert!(err.contains("monotone"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn text_load_rejects_non_finite_values() {
        let dir = std::env::temp_dir();
        let path = dir.join("spartan_io_nonfinite.txt");
        std::fs::write(&path, "0 0 0 1.0\n0 1 1 nan\n").unwrap();
        let err = load_triplets_text(&path).unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("not finite"), "{err}");
        std::fs::write(&path, "0 0 0 inf\n").unwrap();
        assert!(load_triplets_text(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn text_comments_and_blank_lines() {
        let dir = std::env::temp_dir();
        let path = dir.join("spartan_io_comments.txt");
        std::fs::write(&path, "# header\n\n0 0 2 1.5\n0 1 0 2.0\n1 0 1 3.0\n").unwrap();
        let t = load_triplets_text(&path).unwrap();
        assert_eq!(t.k(), 2);
        assert_eq!(t.j(), 3);
        assert_eq!(t.nnz(), 3);
        std::fs::remove_file(&path).ok();
    }
}
