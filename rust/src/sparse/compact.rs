//! Resident compact-X arena: the data-side twin of the packed-`Y` arena.
//!
//! The values and column supports of the input slices `X_k` are
//! **iteration-invariant** — only the factors change across ALS sweeps —
//! yet the pre-arena Procrustes step re-streamed each original CSR slice
//! twice per iteration: once for the target stage `C_k = X_k·V` and once
//! for the repack `Y_k = Q_kᵀX_k`. DPar2 (Jang & Kang, 2022) and COPA
//! (Afshar et al., 2018) both show that packing the irregular slices
//! *once* into a support-compact reusable form and running every
//! per-iteration product off that residency is where the next constant
//! factor lives.
//!
//! [`CompactSlice`] stores, per subject, exactly what the two Procrustes
//! stages need and nothing else:
//!
//! * `values` — the stored nonzeros in CSR order (bit-copies of the
//!   originals, so every product is bitwise identical to the CSR path),
//! * `local_cols` — for each nonzero, the *local* index of its column in
//!   the slice's sorted `support` (the same mapping
//!   `parafac2::intermediate::PackedSlice` uses, computed once here),
//! * `support` — the sorted nonzero column ids (`c_k` of paper §3.3),
//! * `row_ptr` — CSR row boundaries, so the repack can recover the row
//!   index of each entry.
//!
//! Per iteration the target stage gathers the support rows of `V` into a
//! contiguous `c_k × R` panel and runs `C_k = X̃_k·V` against it on the
//! existing shape-A micro-kernel ([`crate::linalg::kernels::sparse_row_axpy`]
//! with local column ids — same per-entry accumulation order as
//! `Csr::matmul_dense`, hence bitwise identical); the repack then reads
//! the *same* cache-resident compact values instead of re-streaming the
//! original CSR. That makes **one cold pass over each subject's data per
//! iteration**, counted by the per-slice [`x_traversals`] tally exactly
//! like the packed-`Y` arena counts its cold traversals: the pack and the
//! cold `C_k` read tally, the pack-riding repack read does not, and a
//! standalone repack (the unfused two-sweep reference structure) does —
//! so the 2→1 drop is assertable, not just claimed (`metrics::flops`).
//!
//! [`x_traversals`]: CompactX::x_traversals

use crate::linalg::{kernels, Mat};
use crate::sparse::{Csr, IrregularTensor};
use crate::threadpool::{ChunkPlan, Pool};
use std::sync::atomic::{AtomicU64, Ordering};

/// One subject's support-compact resident copy of `X_k`.
#[derive(Debug)]
pub struct CompactSlice {
    /// Observation count `I_k`.
    rows: usize,
    /// Sorted original column ids with at least one nonzero (length `c_k`).
    pub support: Vec<u32>,
    /// Per-nonzero local support index, CSR entry order (length `nnz_k`).
    pub local_cols: Vec<u32>,
    /// Per-nonzero value, CSR entry order (bit-copies of the originals).
    pub values: Vec<f64>,
    /// Row boundaries into `values`/`local_cols` (`rows + 1` entries).
    pub row_ptr: Vec<usize>,
    /// `‖X_k‖²_F`, summed in CSR entry order at pack time — bitwise
    /// identical to `Csr::fro_norm_sq`, so the fit's constant term never
    /// needs another pass over the original CSR.
    norm_sq: f64,
    /// Lifetime tally of **cold streaming passes** over this subject's X
    /// data: the one-time pack from CSR, each per-iteration `C_k = X̃_k·V`
    /// read, and any *standalone* repack read
    /// ([`CompactSlice::repack_y`]). The pack-riding repack
    /// ([`CompactSlice::repack_y_fused`]) is not a traversal — it consumes
    /// the values the `C_k` stage just streamed, which is the whole point
    /// of the arena (mirrors `PackedSlice`'s `yk_times_v_fused`
    /// convention).
    x_traversal_count: AtomicU64,
}

impl Clone for CompactSlice {
    fn clone(&self) -> CompactSlice {
        CompactSlice {
            rows: self.rows,
            support: self.support.clone(),
            local_cols: self.local_cols.clone(),
            values: self.values.clone(),
            row_ptr: self.row_ptr.clone(),
            norm_sq: self.norm_sq,
            x_traversal_count: AtomicU64::new(self.x_traversal_count.load(Ordering::Relaxed)),
        }
    }
}

impl CompactSlice {
    /// Pack one CSR slice (the one-time cold stream over the original;
    /// tallied as a traversal).
    pub fn pack(xk: &Csr) -> CompactSlice {
        // `col_support` collects through a filter, which can over-allocate;
        // every other buffer below collects with exact capacity. Shrink so
        // [`CompactX::estimate_heap_bytes`]'s admission bound holds on
        // *capacities* (what [`CompactSlice::heap_bytes`] reports), not
        // just lengths.
        let mut support = xk.col_support();
        support.shrink_to_fit();
        // column id → local index scratch, only needed here
        let mut local = vec![u32::MAX; xk.cols()];
        for (c, &j) in support.iter().enumerate() {
            local[j as usize] = c as u32;
        }
        let local_cols: Vec<u32> = xk.indices().iter().map(|&j| local[j as usize]).collect();
        let values = xk.values().to_vec();
        let norm_sq: f64 = values.iter().map(|v| v * v).sum();
        CompactSlice {
            rows: xk.rows(),
            support,
            local_cols,
            values,
            row_ptr: xk.indptr().to_vec(),
            norm_sq,
            x_traversal_count: AtomicU64::new(1),
        }
    }

    /// `I_k`.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// `nnz(X_k)`.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Support size `c_k`.
    #[inline]
    pub fn c_k(&self) -> usize {
        self.support.len()
    }

    /// `‖X_k‖²_F` from the pack-time cache (bitwise identical to
    /// `Csr::fro_norm_sq` on the source slice).
    #[inline]
    pub fn norm_sq(&self) -> f64 {
        self.norm_sq
    }

    /// Entry range of row `i`: `(local column ids, values)`.
    #[inline]
    pub fn row_parts(&self, i: usize) -> (&[u32], &[f64]) {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        (&self.local_cols[lo..hi], &self.values[lo..hi])
    }

    /// Gather the support rows of a `J × R` factor into a contiguous
    /// `c_k × R` panel (`V_c` of the paper's Fig. 2), reusing `panel`'s
    /// buffer. Rows are bit-copies, so products against the panel are
    /// bitwise identical to indexing the full factor.
    pub fn gather_v_into(&self, v: &Mat, panel: &mut Mat) {
        // every panel row is copied in full, so skip the zero-fill pass
        panel.reset_for_overwrite(self.support.len(), v.cols());
        for (c, &j) in self.support.iter().enumerate() {
            panel.row_mut(c).copy_from_slice(v.row(j as usize));
        }
    }

    /// `C_k = X̃_k · V_c` — the Procrustes target's data stage, and the
    /// iteration's **one cold pass** over this subject's values (tallied).
    /// `panel` must be the [`CompactSlice::gather_v_into`] panel of the
    /// factor; each row streams on the shape-A register-blocked
    /// micro-kernel with the precomputed local column ids — the identical
    /// per-entry floating-point sequence `Csr::matmul_dense` produces
    /// against the full factor.
    pub fn times_v_into(&self, panel: &Mat, out: &mut Mat) {
        debug_assert_eq!(panel.rows(), self.support.len(), "panel/support mismatch");
        self.x_traversal_count.fetch_add(1, Ordering::Relaxed);
        out.reset_to_zeros(self.rows, panel.cols());
        for i in 0..self.rows {
            let (cols, vals) = self.row_parts(i);
            kernels::sparse_row_axpy(vals, cols, panel, out.row_mut(i));
        }
    }

    /// Standalone repack `Y_k = Q_kᵀX̃_k` into the packed-`Y` arena slot —
    /// a **cold** re-stream of the compact values (tallied): the unfused
    /// reference structure where the repack runs in its own sweep instead
    /// of riding the `C_k` pass.
    pub fn repack_y(&self, qk: &Mat, slot: &mut crate::parafac2::intermediate::PackedSlice) {
        self.x_traversal_count.fetch_add(1, Ordering::Relaxed);
        slot.repack_from_compact(self, qk);
    }

    /// Repack `Y_k = Q_kᵀX̃_k` **fused into the `C_k` pass**: call
    /// immediately after [`CompactSlice::times_v_into`] on the same slice,
    /// while the compact values are still cache-resident — same
    /// arithmetic, same accumulation order, *not* a traversal.
    pub fn repack_y_fused(
        &self,
        qk: &Mat,
        slot: &mut crate::parafac2::intermediate::PackedSlice,
    ) {
        slot.repack_from_compact(self, qk);
    }

    /// Record one cold streaming pass (callers that consume the raw
    /// compact buffers directly).
    pub fn note_traversal(&self) {
        self.x_traversal_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Lifetime cold-pass tally of this slice.
    pub fn x_traversals(&self) -> u64 {
        self.x_traversal_count.load(Ordering::Relaxed)
    }

    /// Heap bytes of the resident copy (memory accounting; the arena is a
    /// deliberate residency-for-traffic trade, so its footprint is
    /// first-class in the bench counters).
    pub fn heap_bytes(&self) -> u64 {
        (self.support.capacity() * 4
            + self.local_cols.capacity() * 4
            + self.values.capacity() * 8
            + self.row_ptr.capacity() * std::mem::size_of::<usize>()) as u64
    }
}

/// The per-fit resident arena: one [`CompactSlice`] per subject, packed
/// once at fit start (pool-parallel over the fit's chunk plan) and read by
/// every subsequent Procrustes sweep.
#[derive(Clone, Debug)]
pub struct CompactX {
    pub slices: Vec<CompactSlice>,
    /// Shared variable count J.
    j_dim: usize,
}

impl CompactX {
    /// Pack every slice of `data` (chunked on the pool; per-slice packs
    /// are independent, so the result is identical for any worker count).
    pub fn pack(data: &IrregularTensor, pool: &Pool, plan: &ChunkPlan) -> CompactX {
        let per_chunk: Vec<Vec<CompactSlice>> = pool.par_plan_results(plan, |range| {
            range.map(|k| CompactSlice::pack(data.slice(k))).collect()
        });
        let mut slices = Vec::with_capacity(data.k());
        for chunk in per_chunk {
            slices.extend(chunk);
        }
        CompactX { slices, j_dim: data.j() }
    }

    /// Serial pack (tests / small tools).
    pub fn pack_serial(data: &IrregularTensor) -> CompactX {
        CompactX {
            slices: (0..data.k()).map(|k| CompactSlice::pack(data.slice(k))).collect(),
            j_dim: data.j(),
        }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.slices.len()
    }

    #[inline]
    pub fn j(&self) -> usize {
        self.j_dim
    }

    /// Total resident nonzeros.
    pub fn nnz(&self) -> usize {
        self.slices.iter().map(|s| s.nnz()).sum()
    }

    /// `Σ_k ‖X_k‖²_F` — bitwise identical to
    /// [`IrregularTensor::fro_norm_sq`] (same per-slice entry order, same
    /// ascending-`k` fold).
    pub fn norm_sq(&self) -> f64 {
        self.slices.iter().map(|s| s.norm_sq()).sum()
    }

    /// Total cold X passes ever performed through this arena (see
    /// [`CompactSlice`] for what counts). The arena-backed ALS iteration
    /// performs exactly **one** per subject — asserted in
    /// `metrics::flops` and end-to-end in `parafac2::als`.
    pub fn x_traversals(&self) -> u64 {
        self.slices.iter().map(|s| s.x_traversals()).sum()
    }

    /// Resident footprint of the whole arena.
    pub fn heap_bytes(&self) -> u64 {
        self.slices.iter().map(|s| s.heap_bytes()).sum()
    }

    /// Upper bound on [`CompactX::heap_bytes`] computable **without
    /// packing** — the admission estimate a fit charges against its
    /// [`crate::util::membudget::MemBudget`] *before* the arena exists, so
    /// an over-budget fit is rejected structurally instead of discovering
    /// OOM mid-pack. Per slice: `support ≤ min(nnz_k, J)` ids (exact when
    /// every nonzero hits a distinct column), `nnz_k` local ids, `nnz_k`
    /// values, `rows_k + 1` row pointers — all packed via exact-size
    /// collects, so the bound is tight up to support overcount.
    pub fn estimate_heap_bytes(data: &IrregularTensor) -> u64 {
        let j = data.j();
        (0..data.k())
            .map(|k| {
                let s = data.slice(k);
                let nnz = s.nnz();
                (nnz.min(j) * 4
                    + nnz * 4
                    + nnz * 8
                    + (s.rows() + 1) * std::mem::size_of::<usize>()) as u64
            })
            .sum()
    }

    /// Largest `I_k` (scratch sizing diagnostics).
    pub fn max_i_k(&self) -> usize {
        self.slices.iter().map(|s| s.rows()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trips = vec![(0, 0, 1.0)];
        for i in 0..rows {
            for j in 0..cols {
                if rng.chance(density) {
                    trips.push((i, j, rng.normal()));
                }
            }
        }
        Csr::from_triplets(rows, cols, trips)
    }

    #[test]
    fn pack_preserves_structure_and_values() {
        let mut rng = Pcg64::seed(211);
        let xk = random_sparse(&mut rng, 9, 14, 0.2);
        let c = CompactSlice::pack(&xk);
        assert_eq!(c.rows(), xk.rows());
        assert_eq!(c.nnz(), xk.nnz());
        assert_eq!(c.support, xk.col_support());
        assert_eq!(c.row_ptr, xk.indptr());
        // values are bit-copies in CSR order
        for (a, b) in c.values.iter().zip(xk.values()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // local ids map back to the original columns
        for (pos, &j) in xk.indices().iter().enumerate() {
            assert_eq!(c.support[c.local_cols[pos] as usize], j);
        }
        // pack counts as the one-time cold stream
        assert_eq!(c.x_traversals(), 1);
    }

    #[test]
    fn norm_sq_bitwise_matches_csr() {
        let mut rng = Pcg64::seed(212);
        let slices: Vec<Csr> = (0..6).map(|_| random_sparse(&mut rng, 7, 11, 0.3)).collect();
        let data = IrregularTensor::new(slices);
        let cx = CompactX::pack_serial(&data);
        assert_eq!(cx.norm_sq().to_bits(), data.fro_norm_sq().to_bits());
        for k in 0..data.k() {
            assert_eq!(cx.slices[k].norm_sq().to_bits(), data.slice(k).fro_norm_sq().to_bits());
        }
    }

    #[test]
    fn times_v_bitwise_matches_csr_matmul_dense() {
        // THE arena contract: the gathered-panel product must reproduce
        // the CSR product bit for bit, across the kernel layer's
        // monomorphized and runtime-width paths.
        let mut rng = Pcg64::seed(213);
        for &r in &[1usize, 3, 8, 17] {
            let xk = random_sparse(&mut rng, 10, 20 + r, 0.25);
            let v = Mat::rand_normal(20 + r, r, &mut rng);
            let c = CompactSlice::pack(&xk);
            let mut panel = Mat::zeros(0, 0);
            let mut out = Mat::zeros(0, 0);
            c.gather_v_into(&v, &mut panel);
            c.times_v_into(&panel, &mut out);
            let want = xk.matmul_dense(&v);
            assert_eq!(out.shape(), want.shape(), "R={r}");
            for (a, b) in out.data().iter().zip(want.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "R={r}");
            }
        }
    }

    #[test]
    fn traversal_tallies_pack_cold_and_standalone_only() {
        let mut rng = Pcg64::seed(214);
        let xk = random_sparse(&mut rng, 6, 9, 0.4);
        let c = CompactSlice::pack(&xk); // +1 (pack)
        let v = Mat::rand_normal(9, 3, &mut rng);
        let qk = crate::linalg::random_orthonormal(6, 3, &mut rng);
        let mut panel = Mat::zeros(0, 0);
        let mut out = Mat::zeros(0, 0);
        c.gather_v_into(&v, &mut panel); // gather is factor-side: no tally
        assert_eq!(c.x_traversals(), 1);
        c.times_v_into(&panel, &mut out); // +1 (cold C_k pass)
        assert_eq!(c.x_traversals(), 2);
        let mut slot = crate::parafac2::intermediate::PackedSlice::empty();
        c.repack_y_fused(&qk, &mut slot); // rides the pass: no tally
        assert_eq!(c.x_traversals(), 2);
        c.repack_y(&qk, &mut slot); // standalone re-stream: +1
        assert_eq!(c.x_traversals(), 3);
    }

    #[test]
    fn parallel_pack_matches_serial() {
        let mut rng = Pcg64::seed(215);
        let slices: Vec<Csr> = (0..30)
            .map(|kk| {
                let (rows, dens) = if kk == 0 { (25, 0.8) } else { (5, 0.15) };
                random_sparse(&mut rng, rows, 18, dens)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let weights: Vec<u64> = (0..data.k()).map(|k| data.slice(k).nnz() as u64).collect();
        let plan = ChunkPlan::balanced(&weights);
        let par = CompactX::pack(&data, &Pool::new(4), &plan);
        let ser = CompactX::pack_serial(&data);
        assert_eq!(par.k(), ser.k());
        for k in 0..ser.k() {
            assert_eq!(par.slices[k].support, ser.slices[k].support);
            assert_eq!(par.slices[k].local_cols, ser.slices[k].local_cols);
            assert_eq!(par.slices[k].row_ptr, ser.slices[k].row_ptr);
            for (a, b) in par.slices[k].values.iter().zip(&ser.slices[k].values) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert!(par.heap_bytes() > 0);
        assert_eq!(par.nnz(), data.nnz());
    }

    #[test]
    fn estimate_bounds_actual_heap_bytes() {
        // The admission estimate must never under-charge: every packed
        // arena fits inside what was reserved for it. Dense-ish slices
        // make the support overcount bite (nnz > c_k), sparse ones make
        // it tight.
        let mut rng = Pcg64::seed(217);
        for &dens in &[0.05, 0.3, 0.9] {
            let slices: Vec<Csr> =
                (0..12).map(|_| random_sparse(&mut rng, 10, 15, dens)).collect();
            let data = IrregularTensor::new(slices);
            let est = CompactX::estimate_heap_bytes(&data);
            let actual = CompactX::pack_serial(&data).heap_bytes();
            assert!(est >= actual, "density {dens}: estimate {est} < actual {actual}");
        }
    }

    #[test]
    fn heap_bytes_accounts_every_buffer() {
        let mut rng = Pcg64::seed(216);
        let xk = random_sparse(&mut rng, 8, 12, 0.3);
        let c = CompactSlice::pack(&xk);
        let floor = (c.support.len() * 4
            + c.local_cols.len() * 4
            + c.values.len() * 8
            + c.row_ptr.len() * std::mem::size_of::<usize>()) as u64;
        assert!(c.heap_bytes() >= floor, "{} < {floor}", c.heap_bytes());
    }
}
