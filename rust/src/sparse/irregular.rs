//! The "irregular tensor": a collection of K sparse slices
//! `{X_k ∈ R^{I_k × J}}` sharing the variable mode J but with unaligned
//! observation counts `I_k` — the input object of PARAFAC2 (paper Fig. 1).

use super::csr::Csr;
use crate::linalg::Mat;

/// Collection of CSR slices with a shared column (variable) dimension.
#[derive(Clone, Debug)]
pub struct IrregularTensor {
    j: usize,
    slices: Vec<Csr>,
}

impl IrregularTensor {
    /// Build from slices; validates the shared J and filters all-zero rows
    /// (the paper: "all their I_k rows will contain at least one non-zero
    /// element; if this is not the case, we can simply filter").
    pub fn new(slices: Vec<Csr>) -> IrregularTensor {
        assert!(!slices.is_empty(), "need at least one slice");
        let j = slices[0].cols();
        let filtered: Vec<Csr> = slices
            .into_iter()
            .enumerate()
            .map(|(k, s)| {
                assert_eq!(s.cols(), j, "slice {k} has J={} expected {j}", s.cols());
                let (f, _) = s.filter_zero_rows();
                f
            })
            .collect();
        IrregularTensor { j, slices: filtered }
    }

    /// Build without filtering (when the caller guarantees no zero rows).
    pub fn new_unchecked(slices: Vec<Csr>) -> IrregularTensor {
        let j = slices.first().map(|s| s.cols()).unwrap_or(0);
        IrregularTensor { j, slices }
    }

    /// Number of subjects K.
    #[inline]
    pub fn k(&self) -> usize {
        self.slices.len()
    }

    /// Shared variable count J.
    #[inline]
    pub fn j(&self) -> usize {
        self.j
    }

    /// Observation count I_k of subject `k`.
    #[inline]
    pub fn i_k(&self, k: usize) -> usize {
        self.slices[k].rows()
    }

    #[inline]
    pub fn slice(&self, k: usize) -> &Csr {
        &self.slices[k]
    }

    pub fn slices(&self) -> &[Csr] {
        &self.slices
    }

    /// Total nonzeros across all slices.
    pub fn nnz(&self) -> usize {
        self.slices.iter().map(|s| s.nnz()).sum()
    }

    /// Largest observation count.
    pub fn max_i_k(&self) -> usize {
        self.slices.iter().map(|s| s.rows()).max().unwrap_or(0)
    }

    /// Mean observation count.
    pub fn mean_i_k(&self) -> f64 {
        if self.slices.is_empty() {
            return 0.0;
        }
        self.slices.iter().map(|s| s.rows()).sum::<usize>() as f64 / self.k() as f64
    }

    /// Σ_k ‖X_k‖²_F — the constant term of the ALS objective.
    pub fn fro_norm_sq(&self) -> f64 {
        self.slices.iter().map(|s| s.fro_norm_sq()).sum()
    }

    /// Restrict to the first `k` subjects (subject-sweep experiments).
    pub fn take_subjects(&self, k: usize) -> IrregularTensor {
        assert!(k >= 1 && k <= self.k());
        IrregularTensor { j: self.j, slices: self.slices[..k].to_vec() }
    }

    /// Restrict to the first `j` variables, dropping out-of-range nonzeros
    /// and then re-filtering empty rows (variable-sweep experiments,
    /// paper Fig. 7).
    pub fn take_variables(&self, j: usize) -> IrregularTensor {
        assert!(j >= 1 && j <= self.j);
        let slices: Vec<Csr> = self
            .slices
            .iter()
            .map(|s| {
                let trips: Vec<(usize, usize, f64)> = (0..s.rows())
                    .flat_map(|r| {
                        s.row_iter(r)
                            .filter(|&(c, _)| (c as usize) < j)
                            .map(move |(c, v)| (r, c as usize, v))
                            .collect::<Vec<_>>()
                    })
                    .collect();
                Csr::from_triplets(s.rows(), j, trips)
            })
            .collect();
        // keep only subjects that still have nonzeros, filter zero rows
        let nonempty: Vec<Csr> = slices.into_iter().filter(|s| s.nnz() > 0).collect();
        assert!(!nonempty.is_empty(), "variable restriction removed all data");
        IrregularTensor::new(nonempty)
    }

    /// Dense materialization of slice k (tests only).
    pub fn slice_dense(&self, k: usize) -> Mat {
        self.slices[k].to_dense()
    }

    /// Heap footprint of the whole collection.
    pub fn heap_bytes(&self) -> u64 {
        self.slices.iter().map(|s| s.heap_bytes()).sum()
    }

    /// Summary line for logs (matches the paper's Table 3 fields).
    pub fn summary(&self) -> String {
        format!(
            "K={} J={} max(I_k)={} mean(I_k)={:.1} nnz={}",
            self.k(),
            self.j(),
            self.max_i_k(),
            self.mean_i_k(),
            crate::util::humansize::count(self.nnz() as u64)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> IrregularTensor {
        let x0 = Csr::from_triplets(3, 4, vec![(0, 0, 1.0), (1, 2, 2.0), (2, 3, 3.0)]);
        let x1 = Csr::from_triplets(2, 4, vec![(0, 1, 4.0), (1, 1, 5.0)]);
        IrregularTensor::new(vec![x0, x1])
    }

    #[test]
    fn basic_stats() {
        let t = tiny();
        assert_eq!(t.k(), 2);
        assert_eq!(t.j(), 4);
        assert_eq!(t.i_k(0), 3);
        assert_eq!(t.i_k(1), 2);
        assert_eq!(t.nnz(), 5);
        assert_eq!(t.max_i_k(), 3);
        assert!((t.mean_i_k() - 2.5).abs() < 1e-12);
        assert!((t.fro_norm_sq() - (1.0 + 4.0 + 9.0 + 16.0 + 25.0)).abs() < 1e-12);
    }

    #[test]
    fn zero_rows_filtered_on_construction() {
        let x = Csr::from_triplets(5, 3, vec![(1, 0, 1.0), (4, 2, 1.0)]);
        let t = IrregularTensor::new(vec![x]);
        assert_eq!(t.i_k(0), 2);
    }

    #[test]
    fn take_subjects_prefix() {
        let t = tiny();
        let t1 = t.take_subjects(1);
        assert_eq!(t1.k(), 1);
        assert_eq!(t1.nnz(), 3);
    }

    #[test]
    fn take_variables_drops_and_refilters() {
        let t = tiny();
        let tv = t.take_variables(2);
        // slice 0 keeps only (0,0); slice 1 keeps both (col 1)
        assert_eq!(tv.k(), 2);
        assert_eq!(tv.j(), 2);
        assert_eq!(tv.i_k(0), 1); // rows 1,2 of slice 0 became empty
        assert_eq!(tv.nnz(), 3);
    }

    #[test]
    #[should_panic]
    fn mismatched_j_rejected() {
        let x0 = Csr::from_triplets(1, 3, vec![(0, 0, 1.0)]);
        let x1 = Csr::from_triplets(1, 4, vec![(0, 0, 1.0)]);
        IrregularTensor::new(vec![x0, x1]);
    }
}
