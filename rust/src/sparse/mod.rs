//! Sparse data structures for PARAFAC2's "irregular tensors": CSR slices,
//! the K-slice collection, the resident compact-X arena the ALS loop
//! streams per iteration, the COO tensor the baseline materializes, and
//! file I/O.

pub mod compact;
pub mod coo;
pub mod csr;
pub mod io;
pub mod irregular;

pub use compact::{CompactSlice, CompactX};
pub use coo::CooTensor3;
pub use csr::Csr;
pub use irregular::IrregularTensor;
