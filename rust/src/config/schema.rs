//! Typed run configuration with defaults, file loading, and validation.

use super::toml::{self, Doc};
use crate::parafac2::als::{Backend, Parafac2Config};
use crate::parafac2::init::InitMethod;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Everything a `spartan decompose` run needs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub fit: Parafac2Config,
    /// "native" | "baseline" | "pjrt"
    pub engine: Engine,
    /// Artifact directory for the pjrt engine.
    pub artifacts_dir: String,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Engine {
    Native,
    Baseline,
    Pjrt,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "spartan" => Some(Engine::Native),
            "baseline" | "sparse-parafac2" => Some(Engine::Baseline),
            "pjrt" | "xla" => Some(Engine::Pjrt),
            _ => None,
        }
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            fit: Parafac2Config::default(),
            engine: Engine::Native,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl RunConfig {
    /// Load from a TOML file ([fit] / [runtime] sections).
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = toml::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &Doc) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(v) = doc.get("fit", "rank").and_then(|v| v.as_int()) {
            cfg.fit.rank = v as usize;
        }
        if let Some(v) = doc.get("fit", "max_iters").and_then(|v| v.as_int()) {
            cfg.fit.max_iters = v as usize;
        }
        if let Some(v) = doc.get("fit", "tol").and_then(|v| v.as_float()) {
            cfg.fit.tol = v;
        }
        if let Some(v) = doc.get("fit", "nonneg").and_then(|v| v.as_bool()) {
            cfg.fit.nonneg = v;
        }
        if let Some(v) = doc.get("fit", "seed").and_then(|v| v.as_int()) {
            cfg.fit.seed = v as u64;
        }
        if let Some(v) = doc.get("fit", "init").and_then(|v| v.as_str()) {
            cfg.fit.init = InitMethod::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown init method `{v}`"))?;
        }
        if let Some(v) = doc.get("runtime", "workers").and_then(|v| v.as_int()) {
            cfg.fit.workers = v as usize;
        }
        if let Some(v) = doc.get("runtime", "engine").and_then(|v| v.as_str()) {
            cfg.engine =
                Engine::parse(v).ok_or_else(|| anyhow::anyhow!("unknown engine `{v}`"))?;
        }
        if let Some(v) = doc.get("runtime", "artifacts_dir").and_then(|v| v.as_str()) {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get("runtime", "mem_budget").and_then(|v| v.as_str()) {
            cfg.fit.mem_budget = Some(
                crate::util::humansize::parse_bytes(v)
                    .ok_or_else(|| anyhow::anyhow!("bad mem_budget `{v}`"))?,
            );
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.fit.rank == 0 {
            bail!("fit.rank must be ≥ 1");
        }
        if self.fit.max_iters == 0 {
            bail!("fit.max_iters must be ≥ 1");
        }
        if !(self.fit.tol >= 0.0) {
            bail!("fit.tol must be ≥ 0");
        }
        // keep Backend consistent with engine for the native driver
        Ok(())
    }

    /// The `Backend` enum for the native ALS driver (Pjrt handled apart).
    pub fn native_backend(&self) -> Backend {
        match self.engine {
            Engine::Baseline => Backend::Baseline,
            _ => Backend::Spartan,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn full_file_roundtrip() {
        let text = r#"
            [fit]
            rank = 7
            max_iters = 33
            tol = 1e-5
            nonneg = false
            seed = 99
            init = "svd-warm"
            [runtime]
            engine = "pjrt"
            workers = 2
            artifacts_dir = "my_artifacts"
            mem_budget = "512MiB"
        "#;
        let doc = toml::parse(text).unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.fit.rank, 7);
        assert_eq!(cfg.fit.max_iters, 33);
        assert_eq!(cfg.fit.tol, 1e-5);
        assert!(!cfg.fit.nonneg);
        assert_eq!(cfg.fit.seed, 99);
        assert_eq!(cfg.fit.init, InitMethod::SvdWarm);
        assert_eq!(cfg.engine, Engine::Pjrt);
        assert_eq!(cfg.fit.workers, 2);
        assert_eq!(cfg.artifacts_dir, "my_artifacts");
        assert_eq!(cfg.fit.mem_budget, Some(512 << 20));
    }

    #[test]
    fn invalid_rejected() {
        let doc = toml::parse("[fit]\nrank = 0").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
        let doc = toml::parse("[runtime]\nengine = \"gpu\"").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn engine_parse_aliases() {
        assert_eq!(Engine::parse("spartan"), Some(Engine::Native));
        assert_eq!(Engine::parse("XLA"), Some(Engine::Pjrt));
        assert_eq!(Engine::parse("sparse-parafac2"), Some(Engine::Baseline));
    }
}
