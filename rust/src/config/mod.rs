//! Run configuration: a minimal TOML-subset parser (no `serde`/`toml` in
//! the offline crate set) plus the typed [`RunConfig`] schema with
//! validation and CLI overrides.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! (`"..."`), integer, float, and boolean values, `#` comments. That
//! covers every knob the launcher exposes.

pub mod schema;
pub mod toml;

pub use schema::RunConfig;
