//! Minimal TOML-subset parser (see module docs in `config/mod.rs`).

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section.key → value`. Keys before any `[section]`
/// live in the empty section `""`.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    values: BTreeMap<(String, String), Value>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    pub fn sections(&self) -> Vec<&str> {
        let mut out: Vec<&str> = self.values.keys().map(|(s, _)| s.as_str()).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.values
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect()
    }
}

/// Parse a document; errors carry 1-based line numbers.
pub fn parse(input: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (ln, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", ln + 1))?;
            section = name.trim().to_string();
            if section.is_empty() {
                return Err(format!("line {}: empty section name", ln + 1));
            }
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
        let key = line[..eq].trim();
        let val_str = line[eq + 1..].trim();
        if key.is_empty() || val_str.is_empty() {
            return Err(format!("line {}: empty key or value", ln + 1));
        }
        let value = parse_value(val_str).map_err(|e| format!("line {}: {e}", ln + 1))?;
        doc.values.insert((section.clone(), key.to_string()), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
            # top comment
            name = "run1"
            [fit]
            rank = 10
            tol = 1e-6
            nonneg = true
            [data]
            kind = "ehr"  # inline comment
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("run1"));
        assert_eq!(doc.get("fit", "rank").unwrap().as_int(), Some(10));
        assert_eq!(doc.get("fit", "tol").unwrap().as_float(), Some(1e-6));
        assert_eq!(doc.get("fit", "nonneg").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("data", "kind").unwrap().as_str(), Some("ehr"));
        assert!(doc.get("fit", "missing").is_none());
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        assert!(parse("[broken").unwrap_err().contains("line 1"));
        assert!(parse("\njust a line").unwrap_err().contains("line 2"));
        assert!(parse("x = @@").unwrap_err().contains("line 1"));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse(r##"x = "a#b""##).unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_str(), Some("a#b"));
    }
}
