//! # SPARTan — Scalable PARAFAC2 for Large & Sparse Data
//!
//! A production-grade reproduction of *SPARTan: Scalable PARAFAC2 for
//! Large & Sparse Data* (Perros et al., KDD 2017) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: sparse irregular-tensor
//!   storage, the PARAFAC2-ALS outer loop, SPARTan's specialized MTTKRP
//!   (paper Algorithm 3) and the Tensor-Toolbox-style baseline it is
//!   evaluated against, a subject-parallel scheduler, dataset generators,
//!   phenotyping reports, CLI/config/metrics, and a PJRT runtime that can
//!   execute the AOT-compiled JAX/Pallas compute path.
//! * **L2 (`python/compile/model.py`)** — the per-slice-batch compute
//!   graphs in JAX, lowered once to HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — the Pallas kernel for the packed
//!   per-slice MTTKRP hot-spot.
//!
//! ## Dataflow: one cold pass over X *and* Y per subject per iteration
//!
//! The ALS loop owns two resident arenas, both packed once per fit and
//! refilled/streamed in place by every iteration:
//!
//! * **Compact-X arena** ([`sparse::CompactX`]) — each subject's
//!   iteration-invariant values in CSR order plus the entry→support
//!   mapping (`local_cols`) and support list. The Procrustes sweep makes
//!   exactly **one** cold pass over it per subject per iteration: the
//!   target stage `C_k = X̃_k·V` streams the compact values against a
//!   gathered `V`-support panel, and the repack `Y_k = Q_kᵀX̃_k` rides
//!   that pass (re-reading the same cache-resident values). The pre-arena
//!   structure re-streamed the original CSR twice. Counted by
//!   `x_traversals` (pack + cold reads tally, pass-riding reads don't).
//! * **Packed-Y arena** ([`parafac2::intermediate::PackedY`]) — the
//!   `Y_k = Q_kᵀX_k` slices in support-compact transposed layout. The
//!   pack-fused sweep emits the mode-1 MTTKRP while each slice is
//!   cache-hot from its repack, mode 2 is the iteration's only cold Y
//!   traversal (caching `Z_k = Y_kᵀH`), and mode 3 is an epilogue over
//!   that cache. Counted by `traversals`/`yv_products`.
//!
//! Per-subject temporaries (gathered panel, `C_k`, `B_k`, `D = S_kHᵀ`,
//! `Q_k`, the polar factor's internals) live in per-chunk
//! [`parafac2::procrustes::SubjectScratch`] arenas: steady-state
//! iterations allocate nothing in the Procrustes phase (pinned by the
//! `arena_memory` integration test with a counting global allocator).
//! Every count above is asserted exactly in `metrics::flops` (2→1 against
//! the unfused reference structures) and end-to-end through real fits in
//! `parafac2::als`.
//!
//! **Adding an arena-backed stage:** read operands from the arena (never
//! the original CSR) preserving the CSR entry order so the stage stays
//! bitwise identical to its streaming reference; put every temporary in a
//! per-chunk scratch sized by `Mat::reset_to_zeros`; tally a cold pass
//! (`note_traversal`) only when the stage streams a slice that is not
//! already cache-resident from the same subject's preceding stage; then
//! extend the `metrics::flops` count assertions, the bitwise
//! fused-vs-separate test in `parafac2::procrustes`, and the
//! `ablations --filter xfuse` A/B with the new stage.
//!
//! ## Fit sessions & the service
//!
//! The ALS loop is inverted into a resumable [`parafac2::FitSession`]:
//! construction validates the config, charges the session's arena
//! estimate against a (shareable) [`util::membudget::MemBudget`] via an
//! RAII `SharedCharge` (admission *enforced* — construction fails with
//! `FitError::OutOfMemory` before packing when it can't fit), packs the
//! compact-X arena, and runs init (or adopts a caller-supplied
//! [`parafac2::WarmStart`], e.g. a previous model's `H/V/W`). Each
//! [`FitSession::step`](parafac2::FitSession::step) is one ALS iteration
//! returning an `IterationRecord`; a cancel flag is honored at iteration
//! boundaries (within one iteration, leaving the trajectory at the last
//! completed iterate — resumable bitwise);
//! [`FitSession::finish`](parafac2::FitSession::finish) runs the final
//! Q-pass and yields the model. `fit_parafac2` is now a thin driver over
//! this, bitwise identical to the old batch loop (golden gate unchanged).
//! Fit-only sessions that own their data drop the original CSR slices
//! after the pack (the arena serves every fit-path read) and shrink
//! their charge accordingly — the memory diet is asserted through
//! `MemBudget::peak()`.
//!
//! [`service`] builds the "heavy traffic" layer on top: a resident
//! [`service::Service`] multiplexes many concurrent fits over **one**
//! shared [`threadpool::Pool`] (the pool's FIFO job queue interleaves
//! chunk grants; per-job `ChunkPlan`s, subjects never shard across jobs,
//! so every fit stays bitwise identical to running alone) with
//! membudget admission (structured reject when a job could never fit,
//! FIFO queueing when it merely doesn't fit *now*), a bounded queue, a
//! job-state API (submit / status with per-iteration progress / cancel /
//! result), and a warm-model cache keyed by cohort id so re-fits skip
//! init. `spartan serve` exposes it as a newline-delimited-JSON TCP
//! daemon ([`service::server`], std `TcpListener`, no new deps); factor
//! matrices travel as IEEE-754 bit patterns ([`service::protocol`]), so
//! a model fetched over the wire is bit-identical to the fit. End-to-end
//! coverage: `rust/tests/service_e2e.rs` and CI's `service-smoke` step.
//!
//! The fit also shards **across processes** ([`service::shard`]):
//! `spartan shard-worker` processes own contiguous subject ranges (each
//! packs its own compact-X arena) and a coordinator — `spartan decompose
//! --shards …` or a daemon job submitted with `shards` — streams only
//! `R×R`/`J×R` partials per iteration and replays the single-process
//! merge, so the sharded trajectory is **bitwise identical** to a local
//! fit (pinned by `rust/tests/shard_e2e.rs` and CI's `shard-smoke` job).
//!
//! ## Documentation map
//!
//! Three books under `docs/` go deeper than any one module doc:
//!
//! * `docs/ARCHITECTURE.md` — the layer map (sparse arenas → kernels →
//!   ALS/FitSession → pool → service/shards), the one-cold-pass dataflow
//!   with its counter names, and the bitwise-determinism contract.
//! * `docs/PROTOCOL.md` — the **normative** wire spec for `spartan
//!   serve` and `spartan shard-worker`: framing, every verb, payload
//!   schemas, the hex-bit f64 rule, error slugs, version handshake.
//! * `docs/OPERATIONS.md` — running the daemon and sharded fits:
//!   membudget sizing, queue/admission semantics, warm-cache behavior,
//!   shard topologies, and how to read the fit counters.
//!
//! ## Benchmarks
//!
//! The paper-reproduction benches live under `rust/benches/` and run with
//! `cargo bench` (individually: `cargo bench --bench table1_synthetic`,
//! `fig5_rank_sweep`, `fig6_subject_sweep`, `fig7_variable_sweep`,
//! `micro_linalg`, `ablations`). Two knobs matter:
//!
//! * **`SPARTAN_BENCH_FAST=1`** shrinks every workload to smoke size
//!   (seconds, not minutes) — what CI's `bench-smoke` lane runs on every
//!   PR, so a bench that panics or regresses structurally fails the build.
//! * **`bench_results/*.json`** — every bench binary creates the directory
//!   on demand and writes one JSON file per run:
//!   `{"bench", "context": {"config": ...}, "measurements": [...]}`, where
//!   each measurement carries summary stats, the raw `iter_secs` wall time
//!   of every measured iteration, and (for ALS fits) exact fit-wide work
//!   `counters` normalized by their `fit_iters` entry — `yv_products`
//!   (one `Y_k·V` per subject per fit iteration) and `traversals` (one
//!   cold packed-slice sweep per subject per fit iteration, down from two
//!   before the pack-fused Procrustes→mode-1 sweep, plus one final
//!   report pass). CI uploads the directory as the `bench-results-<sha>`
//!   artifact, so the repo accumulates a machine-readable perf trajectory
//!   instead of hand-written claims. See [`bench`] for the schema and
//!   `metrics::flops` for the counter invariants.
//! * **The trend gate** — CI's `bench-trend` job diffs the current
//!   artifact against the previous run's (`spartan bench-diff`,
//!   [`bench::trend`]): any cell whose `iter_secs` **median** regresses
//!   more than 10% fails the build (cells with fewer than 5 measured
//!   iterations warn only). Committed `bench_results/BENCH_*.json` files
//!   seed the history when no artifact exists yet.
//!
//! ## Kernel layer
//!
//! The ALS hot loops run on register-blocked, R-unrolled micro-kernels
//! behind **one dispatch point**, [`linalg::kernels`] — two shapes:
//! sparse-support rows × dense panel (`Y_k·V`, `X_k·V`) and
//! dense-transpose × dense panel (`Z_k = Y_kᵀH`, `gram`, `AᵀB`). Callers
//! (`parafac2::intermediate`, `parafac2::mttkrp`, `sparse::csr`,
//! `linalg::blas`) never select variants themselves.
//!
//! Behind the dispatch point sit explicit SIMD backends
//! ([`linalg::kernels::KernelBackend`]): portable `scalar`/`blocked`
//! plus `core::arch` implementations for AVX2, AVX-512F, and NEON. The
//! backend is selected **once per process** at the first kernel call —
//! precedence: `--kernel` CLI flag (`decompose`/`serve`/`shard-worker`)
//! > `SPARTAN_KERNEL` env var > auto-detection of the best *bitwise*
//! backend (`avx2` → `neon` → `blocked`); an unknown or undetected name
//! is a loud startup error, never a silent fallback.
//!
//! The determinism contract is stated **per lane family**:
//! `scalar`/`blocked`/`avx2`/`neon` vectorize the panel-width axis with
//! unfused multiply-then-add per lane, replaying the scalar reference's
//! per-element FP order — **bitwise** identical, so the golden
//! trajectory, serial≡parallel, and sharded≡local gates hold under any
//! of them. `avx512` uses 8-wide fused multiply-add — a genuinely
//! **reordered** family (like the pre-existing `dot`): ULP-bounded,
//! opt-in only (never auto-selected), recorded in
//! `FitStats::kernel_backend`, and refused by the shard `hello`
//! handshake when coordinator and worker backends differ. All of this
//! is pinned by the per-backend differential harness
//! `rust/tests/kernel_conformance.rs`; a checked-in golden-trajectory
//! fixture (`bench::als_runner::golden`) additionally pins the exact
//! summation order of a full fit; CI's `kernel-matrix` lane re-runs the
//! whole suite under each runner-available backend; and `cargo bench
//! --bench micro_linalg` publishes per-backend A/B cells for both
//! shapes plus an end-to-end ALS cell per backend. To add a kernel
//! shape or a backend, see the recipes in [`linalg::kernels`].

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datagen;
pub mod linalg;
pub mod metrics;
pub mod parafac2;
pub mod pheno;
pub mod runtime;
pub mod service;
pub mod sparse;
pub mod threadpool;
pub mod util;

pub use parafac2::model::Parafac2Model;
pub use sparse::{Csr, IrregularTensor};
