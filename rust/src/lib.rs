//! # SPARTan — Scalable PARAFAC2 for Large & Sparse Data
//!
//! A production-grade reproduction of *SPARTan: Scalable PARAFAC2 for
//! Large & Sparse Data* (Perros et al., KDD 2017) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: sparse irregular-tensor
//!   storage, the PARAFAC2-ALS outer loop, SPARTan's specialized MTTKRP
//!   (paper Algorithm 3) and the Tensor-Toolbox-style baseline it is
//!   evaluated against, a subject-parallel scheduler, dataset generators,
//!   phenotyping reports, CLI/config/metrics, and a PJRT runtime that can
//!   execute the AOT-compiled JAX/Pallas compute path.
//! * **L2 (`python/compile/model.py`)** — the per-slice-batch compute
//!   graphs in JAX, lowered once to HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — the Pallas kernel for the packed
//!   per-slice MTTKRP hot-spot.
//!
//! ## Benchmarks
//!
//! The paper-reproduction benches live under `rust/benches/` and run with
//! `cargo bench` (individually: `cargo bench --bench table1_synthetic`,
//! `fig5_rank_sweep`, `fig6_subject_sweep`, `fig7_variable_sweep`,
//! `micro_linalg`, `ablations`). Two knobs matter:
//!
//! * **`SPARTAN_BENCH_FAST=1`** shrinks every workload to smoke size
//!   (seconds, not minutes) — what CI's `bench-smoke` lane runs on every
//!   PR, so a bench that panics or regresses structurally fails the build.
//! * **`bench_results/*.json`** — every bench binary creates the directory
//!   on demand and writes one JSON file per run:
//!   `{"bench", "context": {"config": ...}, "measurements": [...]}`, where
//!   each measurement carries summary stats, the raw `iter_secs` wall time
//!   of every measured iteration, and (for ALS fits) exact fit-wide work
//!   `counters` normalized by their `fit_iters` entry — `yv_products`
//!   (one `Y_k·V` per subject per fit iteration) and `traversals` (one
//!   cold packed-slice sweep per subject per fit iteration, down from two
//!   before the pack-fused Procrustes→mode-1 sweep, plus one final
//!   report pass). CI uploads the directory as the `bench-results-<sha>`
//!   artifact, so the repo accumulates a machine-readable perf trajectory
//!   instead of hand-written claims. See [`bench`] for the schema and
//!   `metrics::flops` for the counter invariants.
//! * **The trend gate** — CI's `bench-trend` job diffs the current
//!   artifact against the previous run's (`spartan bench-diff`,
//!   [`bench::trend`]): any cell whose `iter_secs` **median** regresses
//!   more than 10% fails the build (cells with fewer than 5 measured
//!   iterations warn only). Committed `bench_results/BENCH_*.json` files
//!   seed the history when no artifact exists yet.
//!
//! ## Kernel layer
//!
//! The ALS hot loops run on register-blocked, R-unrolled micro-kernels
//! behind **one dispatch point**, [`linalg::kernels`] — two shapes:
//! sparse-support rows × dense panel (`Y_k·V`, `X_k·V`) and
//! dense-transpose × dense panel (`Z_k = Y_kᵀH`, `gram`, `AᵀB`). Callers
//! (`parafac2::intermediate`, `parafac2::mttkrp`, `sparse::csr`,
//! `linalg::blas`) never select variants themselves. The determinism
//! contract — which kernels are **bitwise** identical to their scalar
//! references (the order-preserving blocked family) and which are
//! **ULP-bounded** (the reordered `dot` family) — is documented in the
//! module and pinned by the differential harness
//! `rust/tests/kernel_conformance.rs`; a checked-in golden-trajectory
//! fixture (`bench::als_runner::golden`) additionally pins the exact
//! summation order of a full fit, and `cargo bench --bench micro_linalg`
//! publishes blocked-vs-scalar A/B cells for both shapes. To add a kernel
//! shape, see "Adding a kernel shape" in [`linalg::kernels`].

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datagen;
pub mod linalg;
pub mod metrics;
pub mod parafac2;
pub mod pheno;
pub mod runtime;
pub mod sparse;
pub mod threadpool;
pub mod util;

pub use parafac2::model::Parafac2Model;
pub use sparse::{Csr, IrregularTensor};
