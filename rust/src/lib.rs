//! # SPARTan — Scalable PARAFAC2 for Large & Sparse Data
//!
//! A production-grade reproduction of *SPARTan: Scalable PARAFAC2 for
//! Large & Sparse Data* (Perros et al., KDD 2017) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: sparse irregular-tensor
//!   storage, the PARAFAC2-ALS outer loop, SPARTan's specialized MTTKRP
//!   (paper Algorithm 3) and the Tensor-Toolbox-style baseline it is
//!   evaluated against, a subject-parallel scheduler, dataset generators,
//!   phenotyping reports, CLI/config/metrics, and a PJRT runtime that can
//!   execute the AOT-compiled JAX/Pallas compute path.
//! * **L2 (`python/compile/model.py`)** — the per-slice-batch compute
//!   graphs in JAX, lowered once to HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — the Pallas kernel for the packed
//!   per-slice MTTKRP hot-spot.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datagen;
pub mod linalg;
pub mod metrics;
pub mod parafac2;
pub mod pheno;
pub mod runtime;
pub mod sparse;
pub mod threadpool;
pub mod util;

pub use parafac2::model::Parafac2Model;
pub use sparse::{Csr, IrregularTensor};
