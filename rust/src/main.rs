//! `spartan` — the launcher CLI.
//!
//! Subcommands:
//! * `generate`        synthesize a dataset (synthetic | ehr | movielens)
//! * `decompose`       fit PARAFAC2 (native SPARTan | baseline | pjrt)
//! * `resume`          continue a checkpointed fit after a crash, bitwise
//! * `phenotype`       fit + emit Table-4/Fig-8 style phenotyping reports
//! * `inspect`         print dataset summary statistics
//! * `artifacts-check` validate + smoke-execute the AOT artifacts
//! * `bench-diff`      gate bench_results medians against a previous run
//! * `serve`           resident fit daemon (shared pool, admission, warm cache)
//! * `shard-worker`    own a subject range for a sharded fit (see docs/OPERATIONS.md)
//! * `submit`/`status`/`cancel`/`result`/`serve-stop`  clients for `serve`
//!
//! Run `spartan help` for options.

use anyhow::{anyhow, bail, Context, Result};
use spartan::cli::Args;
use spartan::config::{schema::Engine, RunConfig};
use spartan::coordinator::{PjrtDriver, PjrtFitConfig};
use spartan::datagen::{ehr, movielens, synthetic, vocab::Feature};
use spartan::linalg::kernels::{self, KernelBackend};
use spartan::parafac2::{fit_parafac2, FitError, Parafac2Model};
use spartan::runtime::{ArtifactRegistry, PjrtContext};
use spartan::sparse::{io as tio, IrregularTensor};
use spartan::util::humansize;
use spartan::util::json::Json;
use std::path::{Path, PathBuf};

fn main() {
    spartan::util::logger::init_from_env();
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("generate") => cmd_generate(args),
        Some("decompose") => cmd_decompose(args),
        Some("resume") => cmd_resume(args),
        Some("compare") => cmd_compare(args),
        Some("phenotype") => cmd_phenotype(args),
        Some("inspect") => cmd_inspect(args),
        Some("artifacts-check") => cmd_artifacts_check(args),
        Some("bench-diff") => cmd_bench_diff(args),
        Some("serve") => cmd_serve(args),
        Some("shard-worker") => cmd_shard_worker(args),
        Some("serve-stop") => cmd_serve_stop(args),
        Some("submit") => cmd_submit(args),
        Some("status") => cmd_status(args),
        Some("cancel") => cmd_cancel(args),
        Some("result") => cmd_result(args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand `{other}` (see `spartan help`)"),
    }
}

const HELP: &str = r#"spartan — Scalable PARAFAC2 for large & sparse data (KDD'17 reproduction)

USAGE: spartan <subcommand> [options]

  generate --kind synthetic|ehr|movielens --out FILE
           [--subjects K] [--variables J] [--max-obs I] [--nnz N]
           [--rank R] [--phenotypes P] [--seed S] [--noise X]
           (ehr also writes FILE.vocab.csv for phenotype reports)

  decompose --input FILE --rank R
           [--engine native|baseline|pjrt] [--config run.toml]
           [--max-iters N] [--tol T] [--nonneg] [--unconstrained]
           [--workers N] [--seed S] [--restarts N] [--mem-budget 4GiB]
           [--artifacts DIR] [--save-model DIR]
           [--kernel scalar|blocked|avx2|avx512|neon]
           [--shards host:port,host:port,...]
           [--shard-retries N] [--shard-backoff-ms MS]
           [--checkpoint FILE] [--checkpoint-every N] [--resume-from FILE]
           (--shards runs the fit as a coordinator over `shard-worker`
            processes — bitwise identical to the local fit; FILE must be
            readable by every worker. A lost worker is reconnected and
            re-attached mid-fit under --shard-retries attempts per
            incident with capped exponential backoff starting at
            --shard-backoff-ms; retries exhausted → shard_lost abort.
            --checkpoint commits a crash-safe snapshot every N completed
            iterations — default 1, atomic tmp+fsync+rename — that
            `spartan resume` or --resume-from continues bitwise)

  resume   CKPT [--input FILE] [--save-model DIR]
           [--checkpoint FILE] [--checkpoint-every N] [--workers N]
           [--shards host:port,...] [--shard-retries N]
           [--shard-backoff-ms MS] [--kernel BACKEND]
           (continue a checkpointed fit — local or sharded — after a
            crash, bitwise identical to the uninterrupted run. Re-packs
            the dataset (the checkpoint's recorded path unless --input)
            and refuses to continue when its per-slice ‖X_k‖² bits no
            longer match the checkpoint; requires the checkpoint's
            kernel backend. Keeps checkpointing to CKPT unless
            --checkpoint redirects it)

  compare  --input FILE --rank R [--max-iters N] [--workers N] [--seed S]
           (times one ALS iteration under every engine and prints speedups)

  phenotype --input FILE --rank R [--vocab FILE.vocab.csv]
           [--out-dir DIR] [--patients N] [--threshold T]

  inspect --input FILE

  artifacts-check [--artifacts DIR]

  bench-diff --old DIR --new DIR [--max-regress 0.10] [--min-iters 5]
           (diff per-cell bench_results/*.json iter_secs medians; exit 1
            when any cell with enough samples regresses past the gate —
            CI's bench-trend job)

  serve    [--addr 127.0.0.1:7473] [--workers N] [--mem-budget 4GiB]
           [--max-pending N] [--warm-cache N] [--journal DIR]
           [--kernel BACKEND]
           (resident fit daemon: many concurrent fits on one shared pool,
            membudget admission control, warm-started cohort re-fits;
            newline-delimited JSON over TCP. --journal makes jobs durable:
            an append-only journal + per-iteration checkpoints under DIR
            let a restarted daemon re-admit queued jobs and resume running
            ones bitwise; SIGTERM drains gracefully — running fits are
            checkpointed, nothing is lost)

  shard-worker [--addr 127.0.0.1:0] [--workers N] [--kernel BACKEND]
           (own one contiguous subject range of a sharded fit; announces
            its resolved address on stdout, serves coordinators until
            shut down — protocol in docs/PROTOCOL.md)

  submit   --input FILE --rank R [--addr A] [--engine spartan|baseline]
           [--max-iters N] [--tol T] [--nonneg] [--unconstrained]
           [--seed S] [--cohort ID] [--wait]
           [--shards host:port,host:port,...]
           [--shard-retries N] [--shard-backoff-ms MS]
           (queue a fit on the daemon; --cohort opts into warm-starting
            from that cohort's previous factors; --wait polls to completion;
            --shards makes the daemon coordinate shard-workers instead of
            fitting locally, with the same retry/backoff recovery as
            decompose --shards)

  status   --id N [--addr A]
  cancel   --id N [--addr A]       (stops within one ALS iteration)
  result   --id N [--addr A] [--save-model DIR]
  serve-stop [--addr A]            (ask the daemon to shut down)

Kernels: --kernel (or SPARTAN_KERNEL) pins the linear-algebra backend for
the process: scalar|blocked|avx2|avx512|neon. Unset → best detected
*bitwise* backend (avx2 → neon → blocked). scalar/blocked/avx2/neon
reproduce each other's fit trajectories bit-for-bit; avx512 uses fused
multiply-add and is opt-in only (never auto-selected). Sharded fits
require coordinator and every worker to run the same backend.

Environment: SPARTAN_LOG=debug|info|warn|error
             SPARTAN_KERNEL=scalar|blocked|avx2|avx512|neon
"#;

// ---------------------------------------------------------------------------

/// Apply `--kernel BACKEND` (if present) before any kernel runs. The
/// CLI flag outranks `SPARTAN_KERNEL`; an unsupported backend is a
/// startup error, not a silent fallback.
fn apply_kernel_flag(args: &Args) -> Result<()> {
    if let Some(name) = args.get("kernel") {
        let backend = KernelBackend::parse(name).map_err(|e| anyhow!("bad --kernel: {e}"))?;
        kernels::set_backend(backend).map_err(|e| anyhow!("bad --kernel: {e}"))?;
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    args.reject_unknown(&[
        "kind", "out", "subjects", "variables", "max-obs", "nnz", "rank", "phenotypes",
        "seed", "noise",
    ])
    .map_err(|e| anyhow!(e))?;
    let out = PathBuf::from(args.get("out").context("--out required")?);
    let kind = args.get_or("kind", "synthetic");
    let seed = args.get_u64("seed").map_err(|e| anyhow!(e))?.unwrap_or(2017);
    match kind {
        "synthetic" => {
            let spec = synthetic::SyntheticSpec {
                k: args.get_usize("subjects").map_err(|e| anyhow!(e))?.unwrap_or(10_000),
                j: args.get_usize("variables").map_err(|e| anyhow!(e))?.unwrap_or(1_000),
                max_i_k: args.get_usize("max-obs").map_err(|e| anyhow!(e))?.unwrap_or(100),
                target_nnz: args.get_usize("nnz").map_err(|e| anyhow!(e))?.unwrap_or(1_000_000),
                rank: args.get_usize("rank").map_err(|e| anyhow!(e))?.unwrap_or(40),
                noise: args.get_f64("noise").map_err(|e| anyhow!(e))?.unwrap_or(0.0),
                seed,
            };
            let data = synthetic::generate(&spec);
            tio::save_binary(&data.tensor, &out)?;
            println!("wrote {} ({})", out.display(), data.tensor.summary());
        }
        "ehr" => {
            let spec = ehr::EhrSpec {
                k: args.get_usize("subjects").map_err(|e| anyhow!(e))?.unwrap_or(4_000),
                n_phenotypes: args.get_usize("phenotypes").map_err(|e| anyhow!(e))?.unwrap_or(8),
                max_weeks: args.get_usize("max-obs").map_err(|e| anyhow!(e))?.unwrap_or(166),
                seed,
                ..Default::default()
            };
            let data = ehr::generate(&spec);
            tio::save_binary(&data.tensor, &out)?;
            write_vocab_csv(&data.vocab, &vocab_path(&out))?;
            println!(
                "wrote {} ({}) + vocab ({} features)",
                out.display(),
                data.tensor.summary(),
                data.vocab.len()
            );
        }
        "movielens" => {
            let spec = movielens::MovieLensSpec {
                k: args.get_usize("subjects").map_err(|e| anyhow!(e))?.unwrap_or(5_000),
                j: args.get_usize("variables").map_err(|e| anyhow!(e))?.unwrap_or(20_000),
                max_years: args.get_usize("max-obs").map_err(|e| anyhow!(e))?.unwrap_or(19),
                seed,
                ..Default::default()
            };
            let data = movielens::generate(&spec);
            tio::save_binary(&data, &out)?;
            println!("wrote {} ({})", out.display(), data.summary());
        }
        other => bail!("unknown --kind `{other}`"),
    }
    Ok(())
}

fn cmd_decompose(args: &Args) -> Result<()> {
    args.reject_unknown(&[
        "input", "rank", "engine", "config", "max-iters", "tol", "nonneg", "unconstrained",
        "workers", "seed", "restarts", "mem-budget", "artifacts", "save-model", "shards",
        "shard-retries", "shard-backoff-ms", "kernel", "checkpoint", "checkpoint-every",
        "resume-from",
    ])
    .map_err(|e| anyhow!(e))?;
    apply_kernel_flag(args)?;
    if let Some(ck) = args.get("resume-from") {
        // The checkpoint *is* the fit configuration — a resumed trajectory
        // is only bitwise if nothing about the fit changes mid-flight.
        for opt in ["rank", "engine", "config", "max-iters", "tol", "seed", "restarts"] {
            if args.get(opt).is_some() {
                bail!(
                    "--resume-from takes the fit configuration from the checkpoint; drop --{opt}"
                );
            }
        }
        if args.has_flag("nonneg") || args.has_flag("unconstrained") {
            bail!("--resume-from takes the constraint mode from the checkpoint");
        }
        return resume_fit(args, Path::new(ck));
    }
    let input = PathBuf::from(args.get("input").context("--input required")?);
    let data = load_data(&input)?;
    let mut cfg = match args.get("config") {
        Some(p) => RunConfig::from_file(Path::new(p))?,
        None => RunConfig::default(),
    };
    // CLI overrides
    if let Some(r) = args.get_usize("rank").map_err(|e| anyhow!(e))? {
        cfg.fit.rank = r;
    }
    if let Some(n) = args.get_usize("max-iters").map_err(|e| anyhow!(e))? {
        cfg.fit.max_iters = n;
    }
    if let Some(t) = args.get_f64("tol").map_err(|e| anyhow!(e))? {
        cfg.fit.tol = t;
    }
    if args.has_flag("nonneg") {
        cfg.fit.nonneg = true;
    }
    if args.has_flag("unconstrained") {
        cfg.fit.nonneg = false;
    }
    if let Some(w) = args.get_usize("workers").map_err(|e| anyhow!(e))? {
        cfg.fit.workers = w;
    }
    if let Some(s) = args.get_u64("seed").map_err(|e| anyhow!(e))? {
        cfg.fit.seed = s;
    }
    if let Some(b) = args.get("mem-budget") {
        cfg.fit.mem_budget = Some(humansize::parse_bytes(b).context("bad --mem-budget")?);
    }
    if let Some(e) = args.get("engine") {
        cfg.engine = Engine::parse(e).context("bad --engine")?;
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    cfg.validate()?;

    let every = args.get_usize("checkpoint-every").map_err(|e| anyhow!(e))?.unwrap_or(1).max(1);
    let plan = args.get("checkpoint").map(|p| CheckpointPlan { path: PathBuf::from(p), every });
    if plan.is_none() && args.get("checkpoint-every").is_some() {
        bail!("--checkpoint-every requires --checkpoint");
    }
    if plan.is_some() && matches!(cfg.engine, Engine::Pjrt) {
        bail!("--checkpoint is incompatible with --engine pjrt");
    }

    println!("data: {}", data.summary());

    // Sharded coordinator path: the subject-heavy phases run in
    // `spartan shard-worker` processes, bitwise identical to the local
    // fit (see docs/ARCHITECTURE.md § sharding).
    if let Some(list) = args.get("shards") {
        if matches!(cfg.engine, Engine::Pjrt) {
            bail!("--shards is incompatible with --engine pjrt");
        }
        let mut fit_cfg = cfg.fit.clone();
        fit_cfg.backend = cfg.native_backend();
        let mut spec = spartan::service::shard::ShardSpec::from_list(
            list,
            input.to_string_lossy().into_owned(),
        )
        .map_err(|e| anyhow!("--shards: {e}"))?;
        if let Some(n) = args.get_u64("shard-retries").map_err(|e| anyhow!(e))? {
            spec.max_retries = u32::try_from(n).context("--shard-retries out of range")?;
        }
        if let Some(ms) = args.get_u64("shard-backoff-ms").map_err(|e| anyhow!(e))? {
            spec.backoff_ms = ms;
        }
        println!("sharding over {} worker(s): {}", spec.addrs.len(), spec.addrs.join(", "));
        let model = run_sharded_fit(data, &fit_cfg, &spec, None, plan.as_ref())?;
        print_fit_summary(&model);
        if let Some(dir) = args.get("save-model") {
            save_model(&model, Path::new(dir))?;
            println!("model saved to {dir}/");
        }
        return Ok(());
    }

    let model = match cfg.engine {
        Engine::Pjrt => {
            let ctx = PjrtContext::cpu()?;
            let reg = ArtifactRegistry::load(Path::new(&cfg.artifacts_dir))?;
            let mut driver = PjrtDriver::new(&ctx, &reg);
            let pcfg = PjrtFitConfig {
                rank: cfg.fit.rank,
                max_iters: cfg.fit.max_iters,
                tol: cfg.fit.tol,
                nonneg: cfg.fit.nonneg,
                init: cfg.fit.init,
                seed: cfg.fit.seed,
                workers: cfg.fit.workers,
            };
            let model = driver.fit(&data, &pcfg)?;
            println!(
                "pjrt: {} kernel invocations, {:.2}s kernel time, {:.2}s pack time, {} fallback subjects",
                driver.metrics.kernel_invocations,
                driver.metrics.kernel_secs,
                driver.metrics.pack_secs,
                driver.metrics.native_fallback_subjects,
            );
            model
        }
        _ if plan.is_some() => {
            let mut fit_cfg = cfg.fit.clone();
            fit_cfg.backend = cfg.native_backend();
            let restarts = args.get_usize("restarts").map_err(|e| anyhow!(e))?.unwrap_or(1);
            if restarts > 1 {
                bail!("--checkpoint records one trajectory; drop --restarts");
            }
            // Same construction as the batch driver (`FitSession::new` is
            // exactly what `fit_parafac2` performs), so the checkpointed
            // run's trajectory is the uncheckpointed run's, bitwise.
            let session = spartan::parafac2::FitSession::new(&data, &fit_cfg)
                .map_err(|e| anyhow!("{e}"))?;
            let input_str = input.to_string_lossy().into_owned();
            run_local_fit_loop(session, &input_str, &fit_cfg, plan.as_ref())?
        }
        _ => {
            let mut fit_cfg = cfg.fit.clone();
            fit_cfg.backend = cfg.native_backend();
            let restarts = args.get_usize("restarts").map_err(|e| anyhow!(e))?.unwrap_or(1);
            match spartan::parafac2::fit_parafac2_restarts(&data, &fit_cfg, restarts.max(1)) {
                Ok(out) => {
                    if restarts > 1 {
                        for (i, r) in out.records.iter().enumerate() {
                            println!(
                                "restart {i} (seed {}): fit {:.5} ({} iters, {:.2}s){}",
                                r.seed,
                                r.final_fit,
                                r.iterations,
                                r.secs,
                                if i == out.best_index { "  ← best" } else { "" }
                            );
                        }
                    }
                    out.best
                }
                Err(FitError::OutOfMemory(e)) => bail!("baseline OoM: {e}"),
                Err(e) => bail!("{e}"),
            }
        }
    };
    print_fit_summary(&model);
    if let Some(dir) = args.get("save-model") {
        save_model(&model, Path::new(dir))?;
        println!("model saved to {dir}/");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    args.reject_unknown(&["input", "rank", "max-iters", "workers", "seed", "artifacts", "kernel"])
        .map_err(|e| anyhow!(e))?;
    apply_kernel_flag(args)?;
    let input = PathBuf::from(args.get("input").context("--input required")?);
    let data = load_data(&input)?;
    let rank = args.get_usize("rank").map_err(|e| anyhow!(e))?.unwrap_or(10);
    println!("data: {}", data.summary());
    println!("timing one ALS iteration per engine (mean of 3 after warmup)...\n");

    use spartan::bench::als_runner::{speedup, time_als, CellResult};
    use spartan::parafac2::Backend;
    let s = time_als(&data, rank, Backend::Spartan, None);
    let b = time_als(&data, rank, Backend::Baseline, None);
    let mut rows = vec![
        vec!["spartan (native)".to_string(), s.render(), "1.0×".to_string()],
        vec!["baseline (sparse PARAFAC2)".to_string(), b.render(), speedup(&s, &b)],
    ];
    // PJRT engine if artifacts are available
    let art_dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    if art_dir.join("manifest.json").exists() {
        let reg = ArtifactRegistry::load(&art_dir)?;
        if rank <= reg.rank {
            let ctx = PjrtContext::cpu()?;
            let mut driver = PjrtDriver::new(&ctx, &reg);
            let sw = spartan::util::timer::Stopwatch::start();
            let iters = 4;
            driver.fit(
                &data,
                &PjrtFitConfig {
                    rank,
                    max_iters: iters,
                    tol: 0.0,
                    workers: args.get_usize("workers").map_err(|e| anyhow!(e))?.unwrap_or(0),
                    ..Default::default()
                },
            )?;
            let per_iter = sw.elapsed_secs() / iters as f64;
            let p = CellResult::Time { secs_per_iter: per_iter, iters };
            rows.push(vec!["pjrt (AOT artifacts)".to_string(), p.render(), speedup(&s, &p)]);
        } else {
            println!("(pjrt skipped: rank {rank} > manifest rank {})", reg.rank);
        }
    } else {
        println!("(pjrt skipped: no artifacts — run `make artifacts`)");
    }
    println!(
        "{}",
        spartan::bench::table::render(&["engine", "s/iter", "vs spartan"], &rows)
    );
    Ok(())
}

fn cmd_phenotype(args: &Args) -> Result<()> {
    args.reject_unknown(&[
        "input", "rank", "vocab", "out-dir", "patients", "threshold", "max-iters", "seed",
        "workers",
    ])
    .map_err(|e| anyhow!(e))?;
    let input = PathBuf::from(args.get("input").context("--input required")?);
    let data = load_data(&input)?;
    let rank = args.get_usize("rank").map_err(|e| anyhow!(e))?.unwrap_or(5);
    let out_dir = PathBuf::from(args.get_or("out-dir", "pheno_reports"));
    std::fs::create_dir_all(&out_dir)?;
    let vocab_file = args
        .get("vocab")
        .map(PathBuf::from)
        .unwrap_or_else(|| vocab_path(&input));
    let vocab = read_vocab_csv(&vocab_file).with_context(|| {
        format!("reading vocab {} (generate with --kind ehr)", vocab_file.display())
    })?;
    if vocab.len() != data.j() {
        bail!("vocab has {} features but data has J={}", vocab.len(), data.j());
    }

    let cfg = spartan::parafac2::Parafac2Config {
        rank,
        max_iters: args.get_usize("max-iters").map_err(|e| anyhow!(e))?.unwrap_or(100),
        nonneg: true,
        seed: args.get_u64("seed").map_err(|e| anyhow!(e))?.unwrap_or(42),
        workers: args.get_usize("workers").map_err(|e| anyhow!(e))?.unwrap_or(0),
        ..Default::default()
    };
    let model = fit_parafac2(&data, &cfg).map_err(|e| anyhow!("{e}"))?;
    print_fit_summary(&model);

    let threshold = args.get_f64("threshold").map_err(|e| anyhow!(e))?.unwrap_or(0.15);
    let names: Vec<String> = (0..rank).map(|r| format!("Phenotype {}", r + 1)).collect();
    let table =
        spartan::pheno::report::render_definitions_table(&model, &vocab, &names, threshold);
    let table_path = out_dir.join("phenotype_definitions.txt");
    std::fs::write(&table_path, &table)?;
    println!("{table}");
    println!("definitions → {}", table_path.display());

    let n_patients = args.get_usize("patients").map_err(|e| anyhow!(e))?.unwrap_or(3);
    for k in 0..n_patients.min(data.k()) {
        let ev = out_dir.join(format!("patient{k}_events.csv"));
        let sig = out_dir.join(format!("patient{k}_signature.csv"));
        spartan::pheno::report::write_patient_events_csv(&data, k, &vocab, 5.0, &ev)?;
        spartan::pheno::report::write_patient_signature_csv(&model, k, &names, 2, &sig)?;
        println!("patient {k}: events → {}, signature → {}", ev.display(), sig.display());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.reject_unknown(&["input"]).map_err(|e| anyhow!(e))?;
    let input = PathBuf::from(args.get("input").context("--input required")?);
    let data = load_data(&input)?;
    println!("{}", data.summary());
    let supports = spartan::metrics::flops::support_sizes(&data);
    let mean_ck = supports.iter().sum::<usize>() as f64 / data.k() as f64;
    let max_ck = supports.iter().max().copied().unwrap_or(0);
    println!(
        "column support: mean c_k = {mean_ck:.1}, max c_k = {max_ck} (of J = {})",
        data.j()
    );
    println!("memory: {}", humansize::bytes(data.heap_bytes()));
    for rank in [10usize, 40] {
        let s = spartan::metrics::spartan_iteration_flops(&data, rank);
        let b = spartan::metrics::baseline_iteration_flops(&data, rank);
        println!(
            "R={rank}: est. step-2 flops — spartan {:.2e}, baseline {:.2e} ({:.1}×)",
            s.mttkrp,
            b.mttkrp,
            b.mttkrp / s.mttkrp
        );
    }
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    args.reject_unknown(&["artifacts"]).map_err(|e| anyhow!(e))?;
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let reg = ArtifactRegistry::load(&dir)?;
    println!(
        "manifest: batch={} rank={} i_buckets={:?} c_buckets={:?} ({} entries)",
        reg.batch,
        reg.rank,
        reg.i_buckets,
        reg.c_buckets,
        reg.entries().len()
    );
    let ctx = PjrtContext::cpu()?;
    println!("pjrt: platform = {}", ctx.platform_name());
    for entry in reg.entries() {
        let kernel = reg.kernel(&ctx, entry.kind, entry.i, entry.c)?;
        // smoke-execute with zeros
        use spartan::runtime::{HostTensor, Kind};
        let r = reg.rank;
        let b = reg.batch;
        let inputs = match entry.kind {
            Kind::ProcrustesPack => vec![
                HostTensor::zeros(vec![b, entry.i.unwrap(), entry.c]),
                HostTensor::zeros(vec![b, entry.c, r]),
                HostTensor::zeros(vec![r, r]),
                HostTensor::zeros(vec![b, r]),
            ],
            Kind::Mttkrp1 => vec![
                HostTensor::zeros(vec![b, entry.c, r]),
                HostTensor::zeros(vec![b, entry.c, r]),
                HostTensor::zeros(vec![b, r]),
            ],
            Kind::Mttkrp2 => vec![
                HostTensor::zeros(vec![b, entry.c, r]),
                HostTensor::zeros(vec![r, r]),
                HostTensor::zeros(vec![b, r]),
            ],
            Kind::Mttkrp3 => vec![
                HostTensor::zeros(vec![b, entry.c, r]),
                HostTensor::zeros(vec![b, entry.c, r]),
                HostTensor::zeros(vec![r, r]),
            ],
        };
        let out = kernel.run(&inputs)?;
        println!("  ok: {} → {} outputs", entry.name, out.len());
    }
    println!("all artifacts compile and execute");
    Ok(())
}

// ---------------------------------------------------------------------------
// Service daemon & clients

fn cmd_serve(args: &Args) -> Result<()> {
    use spartan::service::server::ServeConfig;
    args.reject_unknown(&[
        "addr", "workers", "mem-budget", "max-pending", "warm-cache", "journal", "kernel",
    ])
    .map_err(|e| anyhow!(e))?;
    apply_kernel_flag(args)?;
    let mut cfg = ServeConfig::default();
    if let Some(a) = args.get("addr") {
        cfg.addr = a.to_string();
    }
    if let Some(w) = args.get_usize("workers").map_err(|e| anyhow!(e))? {
        cfg.service.workers = w;
    }
    if let Some(b) = args.get("mem-budget") {
        cfg.service.mem_budget = Some(humansize::parse_bytes(b).context("bad --mem-budget")?);
    }
    if let Some(n) = args.get_usize("max-pending").map_err(|e| anyhow!(e))? {
        cfg.service.max_pending = n;
    }
    if let Some(n) = args.get_usize("warm-cache").map_err(|e| anyhow!(e))? {
        cfg.service.warm_cache = n;
    }
    if let Some(d) = args.get("journal") {
        cfg.service.journal = Some(PathBuf::from(d));
    }
    spartan::service::server::serve(&cfg).map_err(|e| anyhow!("{e}"))
}

fn cmd_shard_worker(args: &Args) -> Result<()> {
    args.reject_unknown(&["addr", "workers", "kernel"]).map_err(|e| anyhow!(e))?;
    apply_kernel_flag(args)?;
    let addr = args.get_or("addr", "127.0.0.1:0");
    let workers = args.get_usize("workers").map_err(|e| anyhow!(e))?.unwrap_or(0);
    spartan::service::shard::run_worker(addr, workers).map_err(|e| anyhow!("{e}"))
}

/// Where and how often a checkpointed fit persists its state.
struct CheckpointPlan {
    path: PathBuf,
    every: usize,
}

/// Assemble a durable checkpoint from a session's current iteration
/// boundary (factors + loop state + the re-pack identity bits).
fn build_checkpoint(
    input: &str,
    cfg: &spartan::parafac2::Parafac2Config,
    factors: (&spartan::linalg::Mat, &spartan::linalg::Mat, &spartan::linalg::Mat),
    state: spartan::parafac2::ResumeState,
    x_norm_bits: Vec<f64>,
    shards: Option<&spartan::service::shard::ShardSpec>,
) -> spartan::service::checkpoint::Checkpoint {
    spartan::service::checkpoint::Checkpoint {
        input: input.to_string(),
        cfg: cfg.clone(),
        kernel_backend: kernels::active_backend().name().to_string(),
        h: factors.0.clone(),
        v: factors.1.clone(),
        w: factors.2.clone(),
        state,
        x_norm_bits,
        shards: shards.map(spartan::service::checkpoint::ShardLayout::from_spec),
    }
}

/// `SPARTAN_FAULT=crash-after-iter:N` drill: once the checkpoint at
/// completed iteration N is committed, abort the coordinator with exit
/// code 86 — the chaos harness then proves `spartan resume` reproduces
/// the uninterrupted trajectory bitwise.
fn maybe_crash_after(crash_after: Option<u64>, done: usize) {
    if let Some(n) = crash_after {
        if done as u64 >= n {
            eprintln!("SPARTAN_FAULT: crash-after-iter:{n} — exiting 86 (checkpoint committed)");
            std::process::exit(86);
        }
    }
}

/// Drive a local [`FitSession`](spartan::parafac2::FitSession) to
/// completion, committing a checkpoint every `plan.every` completed
/// iterations and honoring the crash-after-iter drill.
fn run_local_fit_loop(
    mut session: spartan::parafac2::FitSession<'_>,
    input: &str,
    cfg: &spartan::parafac2::Parafac2Config,
    plan: Option<&CheckpointPlan>,
) -> Result<Parafac2Model> {
    use spartan::parafac2::StepOutcome;
    let crash_after = spartan::service::shard::coordinator_crash_iter_from_env();
    loop {
        match session.step().map_err(|e| anyhow!("{e}"))? {
            StepOutcome::Iterated(rec) => {
                let done = rec.iter + 1; // `rec.iter` is 0-based
                if let Some(p) = plan.filter(|p| done % p.every == 0) {
                    let ckpt = build_checkpoint(
                        input,
                        cfg,
                        session.factors(),
                        session.resume_state(),
                        session.slice_norm_sq(),
                        None,
                    );
                    spartan::service::checkpoint::save_checkpoint(&p.path, &ckpt)
                        .map_err(|e| anyhow!("checkpoint {}: {e}", p.path.display()))?;
                    maybe_crash_after(crash_after, done);
                }
            }
            StepOutcome::Done | StepOutcome::Cancelled => break,
        }
    }
    Ok(session.finish())
}

/// Drive a [`ShardedFitSession`](spartan::service::shard::ShardedFitSession)
/// to completion — the sharded counterpart of `fit_parafac2`, with the
/// same optional checkpoint cadence and crash drill as the local loop.
fn run_sharded_fit(
    data: IrregularTensor,
    cfg: &spartan::parafac2::Parafac2Config,
    spec: &spartan::service::shard::ShardSpec,
    resume: Option<spartan::service::shard::ShardedResume>,
    plan: Option<&CheckpointPlan>,
) -> Result<Parafac2Model> {
    use spartan::parafac2::StepOutcome;
    use spartan::service::shard::ShardedFitSession;
    let mut session = match resume {
        Some(from) => ShardedFitSession::resume(data, cfg, spec, None, from),
        None => ShardedFitSession::new(data, cfg, spec, None),
    }
    .map_err(|e| anyhow!("{e}"))?;
    let crash_after = spartan::service::shard::coordinator_crash_iter_from_env();
    loop {
        match session.step().map_err(|e| anyhow!("{e}"))? {
            StepOutcome::Iterated(rec) => {
                let done = rec.iter + 1;
                if let Some(p) = plan.filter(|p| done % p.every == 0) {
                    let ckpt = build_checkpoint(
                        &spec.path,
                        cfg,
                        session.factors(),
                        session.resume_state(),
                        session.slice_norm_sq(),
                        Some(spec),
                    );
                    spartan::service::checkpoint::save_checkpoint(&p.path, &ckpt)
                        .map_err(|e| anyhow!("checkpoint {}: {e}", p.path.display()))?;
                    maybe_crash_after(crash_after, done);
                }
            }
            StepOutcome::Done | StepOutcome::Cancelled => break,
        }
    }
    session.finish().map_err(|e| anyhow!("{e}"))
}

/// `spartan resume CKPT` — continue a checkpointed fit after a crash.
fn cmd_resume(args: &Args) -> Result<()> {
    args.reject_unknown(&[
        "input", "save-model", "checkpoint", "checkpoint-every", "workers", "shards",
        "shard-retries", "shard-backoff-ms", "kernel",
    ])
    .map_err(|e| anyhow!(e))?;
    apply_kernel_flag(args)?;
    let ck = args
        .positional
        .first()
        .context("usage: spartan resume <checkpoint> [options] (see `spartan help`)")?;
    resume_fit(args, Path::new(ck))
}

/// Shared by `spartan resume` and `decompose --resume-from`: load the
/// checkpoint, re-pack the dataset, verify the per-slice `‖X_k‖²` bits
/// (reattach contract — divergent data is rejected, never silently
/// refit), restore the loop state, and continue to completion.
fn resume_fit(args: &Args, ck_path: &Path) -> Result<()> {
    use spartan::service::checkpoint::load_checkpoint;
    let ckpt = load_checkpoint(ck_path)
        .map_err(|e| anyhow!("checkpoint {}: {e}", ck_path.display()))?;
    let ours = kernels::active_backend().name();
    if ckpt.kernel_backend != ours {
        bail!(
            "checkpoint was written under kernel backend `{}` but this process runs `{ours}` — \
             rerun with --kernel {} (trajectories are only bitwise within one backend)",
            ckpt.kernel_backend,
            ckpt.kernel_backend
        );
    }
    let mut cfg = ckpt.cfg.clone();
    if let Some(w) = args.get_usize("workers").map_err(|e| anyhow!(e))? {
        cfg.workers = w; // the worker count never affects the trajectory
    }
    let input = args.get("input").unwrap_or(&ckpt.input).to_string();
    let every = args.get_usize("checkpoint-every").map_err(|e| anyhow!(e))?.unwrap_or(1).max(1);
    // Keep checkpointing where the run left off unless redirected, so a
    // second crash is covered too.
    let path = args.get("checkpoint").map(PathBuf::from).unwrap_or_else(|| ck_path.to_path_buf());
    let plan = CheckpointPlan { path, every };
    println!(
        "resuming {} from iteration {} (input {input}, kernel {ours})",
        ck_path.display(),
        ckpt.state.iter
    );
    let data = load_data(Path::new(&input))?;

    // Shard topology: --shards overrides, else the checkpoint's layout
    // (the subject deal and the trajectory are shard-count invariant).
    let mut spec = match args.get("shards") {
        Some(list) => Some(
            spartan::service::shard::ShardSpec::from_list(list, input.clone())
                .map_err(|e| anyhow!("--shards: {e}"))?,
        ),
        None => ckpt.shards.as_ref().map(|l| l.to_spec(input.clone())),
    };
    if let Some(s) = spec.as_mut() {
        if let Some(n) = args.get_u64("shard-retries").map_err(|e| anyhow!(e))? {
            s.max_retries = u32::try_from(n).context("--shard-retries out of range")?;
        }
        if let Some(ms) = args.get_u64("shard-backoff-ms").map_err(|e| anyhow!(e))? {
            s.backoff_ms = ms;
        }
    }

    let from = spartan::service::shard::ShardedResume {
        h: ckpt.h,
        v: ckpt.v,
        w: ckpt.w,
        state: ckpt.state,
        x_norm_bits: ckpt.x_norm_bits,
    };
    let model = match spec {
        Some(spec) => {
            println!("sharding over {} worker(s): {}", spec.addrs.len(), spec.addrs.join(", "));
            run_sharded_fit(data, &cfg, &spec, Some(from), Some(&plan))?
        }
        None => {
            use spartan::parafac2::{DataHandle, FitSession, SessionOptions, WarmStart};
            let warm = WarmStart { h: from.h, v: from.v, w: from.w };
            let mut session = FitSession::with_options(
                DataHandle::Borrowed(&data),
                &cfg,
                SessionOptions { warm: Some(warm), ..Default::default() },
            )
            .map_err(|e| anyhow!("{e}"))?;
            let got = session.slice_norm_sq();
            let want = &from.x_norm_bits;
            if got.len() != want.len()
                || got.iter().zip(want).any(|(a, b)| a.to_bits() != b.to_bits())
            {
                bail!(
                    "resume re-packed a different arena (per-slice ‖X_k‖² bits diverge) — has \
                     `{input}` changed since the checkpoint? Refusing to continue: a silent \
                     refit would not be the checkpointed trajectory"
                );
            }
            session.restore(from.state);
            run_local_fit_loop(session, &input, &cfg, Some(&plan))?
        }
    };
    print_fit_summary(&model);
    if let Some(dir) = args.get("save-model") {
        save_model(&model, Path::new(dir))?;
        println!("model saved to {dir}/");
    }
    Ok(())
}

fn cmd_serve_stop(args: &Args) -> Result<()> {
    args.reject_unknown(&["addr"]).map_err(|e| anyhow!(e))?;
    let addr = args.get_or("addr", spartan::service::protocol::DEFAULT_ADDR);
    spartan::service::server::shutdown(addr).map_err(|e| anyhow!("{e}"))?;
    println!("server at {addr} stopping");
    Ok(())
}

fn cmd_submit(args: &Args) -> Result<()> {
    use spartan::service::server::{self, SubmitRequest};
    args.reject_unknown(&[
        "input", "rank", "addr", "engine", "max-iters", "tol", "nonneg", "unconstrained",
        "seed", "cohort", "wait", "shards", "shard-retries", "shard-backoff-ms",
    ])
    .map_err(|e| anyhow!(e))?;
    let addr = args.get_or("addr", spartan::service::protocol::DEFAULT_ADDR);
    let req = SubmitRequest {
        input: args.require("input").map_err(|e| anyhow!(e))?.to_string(),
        rank: args
            .get_usize("rank")
            .map_err(|e| anyhow!(e))?
            .context("--rank required")?,
        max_iters: args.get_usize("max-iters").map_err(|e| anyhow!(e))?,
        tol: args.get_f64("tol").map_err(|e| anyhow!(e))?,
        nonneg: if args.has_flag("nonneg") {
            Some(true)
        } else if args.has_flag("unconstrained") {
            Some(false)
        } else {
            None
        },
        seed: args.get_u64("seed").map_err(|e| anyhow!(e))?,
        engine: args.get("engine").map(str::to_string),
        cohort: args.get("cohort").map(str::to_string),
        shards: args
            .get("shards")
            .map(|s| {
                s.split(',')
                    .map(|a| a.trim().to_string())
                    .filter(|a| !a.is_empty())
                    .collect()
            })
            .unwrap_or_default(),
        shard_retries: args
            .get_u64("shard-retries")
            .map_err(|e| anyhow!(e))?
            .map(|n| u32::try_from(n).context("--shard-retries out of range"))
            .transpose()?,
        shard_backoff_ms: args.get_u64("shard-backoff-ms").map_err(|e| anyhow!(e))?,
    };
    let id = server::submit(addr, &req).map_err(|e| anyhow!("{e}"))?;
    println!("submitted job {id}");
    if args.has_flag("wait") {
        loop {
            let st = server::status(addr, id).map_err(|e| anyhow!("{e}"))?;
            let state = st.get("state").and_then(Json::as_str).unwrap_or("?");
            if matches!(state, "done" | "cancelled" | "failed") {
                print_wire_status(&st);
                if state == "failed" {
                    bail!(
                        "job {id} failed: {}",
                        st.get("reason").and_then(Json::as_str).unwrap_or("unknown")
                    );
                }
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
    }
    Ok(())
}

fn cmd_status(args: &Args) -> Result<()> {
    args.reject_unknown(&["id", "addr"]).map_err(|e| anyhow!(e))?;
    let addr = args.get_or("addr", spartan::service::protocol::DEFAULT_ADDR);
    let id = args.require_u64("id").map_err(|e| anyhow!(e))?;
    let st = spartan::service::server::status(addr, id).map_err(|e| anyhow!("{e}"))?;
    print_wire_status(&st);
    Ok(())
}

fn cmd_cancel(args: &Args) -> Result<()> {
    args.reject_unknown(&["id", "addr"]).map_err(|e| anyhow!(e))?;
    let addr = args.get_or("addr", spartan::service::protocol::DEFAULT_ADDR);
    let id = args.require_u64("id").map_err(|e| anyhow!(e))?;
    let snap = spartan::service::server::cancel(addr, id).map_err(|e| anyhow!("{e}"))?;
    println!(
        "cancelled job {id}: state={} iterations_at_cancel={}",
        snap.get("state").and_then(Json::as_str).unwrap_or("?"),
        snap.get("iterations").and_then(Json::as_usize).unwrap_or(0),
    );
    Ok(())
}

fn cmd_result(args: &Args) -> Result<()> {
    args.reject_unknown(&["id", "addr", "save-model"]).map_err(|e| anyhow!(e))?;
    let addr = args.get_or("addr", spartan::service::protocol::DEFAULT_ADDR);
    let id = args.require_u64("id").map_err(|e| anyhow!(e))?;
    match spartan::service::server::result(addr, id).map_err(|e| anyhow!("{e}"))? {
        None => {
            let st = spartan::service::server::status(addr, id).map_err(|e| anyhow!("{e}"))?;
            bail!(
                "job {id} not finished (state {})",
                st.get("state").and_then(Json::as_str).unwrap_or("?")
            );
        }
        Some(model) => {
            print_fit_summary(&model);
            if let Some(dir) = args.get("save-model") {
                save_model(&model, Path::new(dir))?;
                println!("model saved to {dir}/");
            }
        }
    }
    Ok(())
}

/// One parseable line per job snapshot (the e2e tests grep these fields).
fn print_wire_status(st: &Json) {
    let id = st.get("id").and_then(Json::as_usize).unwrap_or(0);
    let state = st.get("state").and_then(Json::as_str).unwrap_or("?");
    let iters = st.get("iterations").and_then(Json::as_usize).unwrap_or(0);
    let warm = st.get("warm_started").and_then(Json::as_bool).unwrap_or(false);
    let fit = st
        .get("fit")
        .and_then(Json::as_f64)
        .map(|f| format!(" fit={f:.5}"))
        .unwrap_or_default();
    println!("job {id}: state={state} iterations={iters}{fit} warm_started={warm}");
}

// ---------------------------------------------------------------------------

fn cmd_bench_diff(args: &Args) -> Result<()> {
    use spartan::bench::trend;
    args.reject_unknown(&["old", "new", "max-regress", "min-iters"]).map_err(|e| anyhow!(e))?;
    let old_dir = PathBuf::from(args.get("old").context("--old DIR required")?);
    let new_dir = PathBuf::from(args.get("new").context("--new DIR required")?);
    let max_regress = args.get_f64("max-regress").map_err(|e| anyhow!(e))?.unwrap_or(0.10);
    let min_iters = args.get_usize("min-iters").map_err(|e| anyhow!(e))?.unwrap_or(5);
    let old = trend::load_cells(&old_dir).map_err(|e| anyhow!(e))?;
    let new = trend::load_cells(&new_dir).map_err(|e| anyhow!(e))?;
    if old.is_empty() {
        println!(
            "bench-diff: no baseline cells under {} — nothing to gate (first run bootstraps the trend)",
            old_dir.display()
        );
    }
    let report = trend::diff(&old, &new, max_regress, min_iters);
    print!("{}", trend::render(&report, max_regress, min_iters));
    if !report.regressions.is_empty() {
        bail!(
            "{} bench cell(s) regressed more than {:.0}% (median iter_secs, ≥{} iters)",
            report.regressions.len(),
            max_regress * 100.0,
            min_iters
        );
    }
    Ok(())
}

fn load_data(path: &Path) -> Result<IrregularTensor> {
    if path.extension().map_or(false, |e| e == "txt") {
        tio::load_triplets_text(path)
    } else {
        tio::load_binary(path)
    }
}

fn vocab_path(data_path: &Path) -> PathBuf {
    let mut p = data_path.as_os_str().to_owned();
    p.push(".vocab.csv");
    PathBuf::from(p)
}

fn write_vocab_csv(vocab: &[Feature], path: &Path) -> Result<()> {
    use spartan::datagen::vocab::FeatureKind;
    let mut out = String::from("id,kind,name\n");
    for (i, f) in vocab.iter().enumerate() {
        let kind = match f.kind {
            FeatureKind::Diagnosis => "diagnosis",
            FeatureKind::Medication => "medication",
        };
        out.push_str(&format!("{i},{kind},\"{}\"\n", f.name.replace('"', "'")));
    }
    std::fs::write(path, out)?;
    Ok(())
}

fn read_vocab_csv(path: &Path) -> Result<Vec<Feature>> {
    use spartan::datagen::vocab::FeatureKind;
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if i == 0 || line.trim().is_empty() {
            continue; // header
        }
        let mut parts = line.splitn(3, ',');
        let _id = parts.next().context("bad vocab line")?;
        let kind = match parts.next().context("bad vocab line")? {
            "diagnosis" => FeatureKind::Diagnosis,
            "medication" => FeatureKind::Medication,
            other => bail!("unknown feature kind `{other}`"),
        };
        let name = parts.next().unwrap_or("").trim().trim_matches('"').to_string();
        out.push(Feature { name, kind });
    }
    Ok(out)
}

fn print_fit_summary(model: &Parafac2Model) {
    let s = &model.stats;
    let backend = if s.kernel_backend.is_empty() {
        String::new()
    } else {
        format!(" [kernel {}]", s.kernel_backend)
    };
    println!(
        "fit: {:.4} (SSE {:.4e}) after {} iterations — {:.2}s total ({:.2}s/iter; procrustes {:.2}s, cp {:.2}s){backend}",
        s.final_fit, s.final_sse, s.iterations, s.total_secs, s.secs_per_iter, s.procrustes_secs, s.cp_secs
    );
}

fn save_model(model: &Parafac2Model, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let write_mat = |name: &str, m: &spartan::linalg::Mat| -> Result<()> {
        let mut out = String::new();
        for i in 0..m.rows() {
            let row: Vec<String> = m.row(i).iter().map(|x| format!("{x:.9e}")).collect();
            out.push_str(&row.join(","));
            out.push('\n');
        }
        std::fs::write(dir.join(name), out)?;
        Ok(())
    };
    write_mat("H.csv", &model.h)?;
    write_mat("V.csv", &model.v)?;
    write_mat("W.csv", &model.w)?;
    for (k, q) in model.q.iter().enumerate().take(16) {
        write_mat(&format!("U{k}.csv"), &spartan::linalg::matmul(q, &model.h))?;
    }
    Ok(())
}
