//! Bucketing and packing of sparse slices into the fixed-shape dense
//! batches the AOT artifacts consume.
//!
//! PJRT executables are shape-specialized, so the coordinator:
//! 1. computes each subject's column support `c_k` once,
//! 2. assigns each subject to the smallest (I, C) bucket that fits
//!    (subjects larger than every bucket fall back to the native path —
//!    the hybrid strategy in DESIGN.md §Hardware-Adaptation),
//! 3. groups bucket members into batches of the manifest batch size B and
//!    zero-pads the tail batch (zero slices are exact no-ops for every
//!    kernel; validated by python/tests + pjrt_roundtrip.rs).

use crate::linalg::Mat;
use crate::runtime::{ArtifactRegistry, HostTensor};
use crate::sparse::IrregularTensor;

/// Per-subject packing metadata computed once per fit.
#[derive(Clone, Debug)]
pub struct SubjectPlan {
    pub subject: usize,
    /// Sorted nonzero columns of `X_k`.
    pub support: Vec<u32>,
    /// Assigned buckets (None ⇒ native fallback).
    pub i_bucket: Option<usize>,
    pub c_bucket: Option<usize>,
}

impl SubjectPlan {
    pub fn is_pjrt(&self) -> bool {
        self.i_bucket.is_some() && self.c_bucket.is_some()
    }
}

/// A batch of subjects sharing one (I, C) bucket.
#[derive(Clone, Debug)]
pub struct Batch {
    pub i_bucket: usize,
    pub c_bucket: usize,
    /// Subject ids; length ≤ manifest batch size (padded at pack time).
    pub subjects: Vec<usize>,
}

/// The full execution plan for a dataset against a registry.
#[derive(Debug)]
pub struct PackPlan {
    pub plans: Vec<SubjectPlan>,
    pub batches: Vec<Batch>,
    /// Subjects handled by the native path.
    pub fallback: Vec<usize>,
    pub batch_size: usize,
}

/// Build the plan: bucket every subject, group into batches.
pub fn plan(data: &IrregularTensor, reg: &ArtifactRegistry) -> PackPlan {
    let mut plans = Vec::with_capacity(data.k());
    for k in 0..data.k() {
        let xk = data.slice(k);
        let support = xk.col_support();
        let i_bucket = reg.i_bucket_for(xk.rows());
        let c_bucket = reg.c_bucket_for(support.len());
        plans.push(SubjectPlan { subject: k, support, i_bucket, c_bucket });
    }
    // group by bucket pair, preserving subject order within groups
    let mut groups: std::collections::BTreeMap<(usize, usize), Vec<usize>> =
        std::collections::BTreeMap::new();
    let mut fallback = Vec::new();
    for p in &plans {
        match (p.i_bucket, p.c_bucket) {
            (Some(i), Some(c)) => groups.entry((i, c)).or_default().push(p.subject),
            _ => fallback.push(p.subject),
        }
    }
    let mut batches = Vec::new();
    for ((i, c), subjects) in groups {
        for chunk in subjects.chunks(reg.batch) {
            batches.push(Batch { i_bucket: i, c_bucket: c, subjects: chunk.to_vec() });
        }
    }
    PackPlan { plans, batches, fallback, batch_size: reg.batch }
}

/// Pack the `X_k` blocks of a batch: f32[B, I, C], support columns only.
pub fn pack_xc(data: &IrregularTensor, batch: &Batch, plans: &[SubjectPlan], b_size: usize) -> HostTensor {
    let (ib, cb) = (batch.i_bucket, batch.c_bucket);
    let mut out = HostTensor::zeros(vec![b_size, ib, cb]);
    for (slot, &k) in batch.subjects.iter().enumerate() {
        let xk = data.slice(k);
        let support = &plans[k].support;
        // column id → local index
        let mut local = std::collections::HashMap::with_capacity(support.len());
        for (c, &j) in support.iter().enumerate() {
            local.insert(j, c);
        }
        let base = slot * ib * cb;
        for i in 0..xk.rows() {
            let row_base = base + i * cb;
            for (j, v) in xk.row_iter(i) {
                let c = local[&j];
                out.data[row_base + c] = v as f32;
            }
        }
    }
    out
}

/// Gather V rows for a batch: f32[B, C, R_pad] (R padded to the manifest
/// rank with zero columns).
pub fn pack_vc(v: &Mat, batch: &Batch, plans: &[SubjectPlan], b_size: usize, r_pad: usize) -> HostTensor {
    let cb = batch.c_bucket;
    let r = v.cols();
    assert!(r <= r_pad);
    let mut out = HostTensor::zeros(vec![b_size, cb, r_pad]);
    for (slot, &k) in batch.subjects.iter().enumerate() {
        let base = slot * cb * r_pad;
        for (c, &j) in plans[k].support.iter().enumerate() {
            let src = v.row(j as usize);
            let dst = base + c * r_pad;
            for t in 0..r {
                out.data[dst + t] = src[t] as f32;
            }
        }
    }
    out
}

/// Pack W rows for a batch: f32[B, R_pad].
pub fn pack_w(w: &Mat, batch: &Batch, b_size: usize, r_pad: usize) -> HostTensor {
    let r = w.cols();
    let mut out = HostTensor::zeros(vec![b_size, r_pad]);
    for (slot, &k) in batch.subjects.iter().enumerate() {
        let src = w.row(k);
        for t in 0..r {
            out.data[slot * r_pad + t] = src[t] as f32;
        }
    }
    out
}

/// Pad H to f32[R_pad, R_pad].
pub fn pack_h(h: &Mat, r_pad: usize) -> HostTensor {
    let r = h.rows();
    assert!(r <= r_pad);
    let mut out = HostTensor::zeros(vec![r_pad, r_pad]);
    for i in 0..r {
        for j in 0..r {
            out.data[i * r_pad + j] = h[(i, j)] as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;

    fn fake_registry(dir: &std::path::Path) -> ArtifactRegistry {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
            "version": 1, "dtype": "f32", "batch": 2, "rank": 4,
            "i_buckets": [4, 8], "c_buckets": [2, 4],
            "entries": [
                {"name": "x", "kind": "mttkrp_mode1", "path": "x.hlo.txt",
                 "b": 2, "i": null, "c": 2, "r": 4, "inputs": [], "outputs": []}
            ]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        ArtifactRegistry::load(dir).unwrap()
    }

    fn tiny_data() -> IrregularTensor {
        // subject 0: 3 rows, support {1, 5}; subject 1: 2 rows, support {0};
        // subject 2: 6 rows (exceeds no bucket), support {0,1,2,3,4} (c=5 > 4 ⇒ fallback)
        let x0 = Csr::from_triplets(3, 6, vec![(0, 1, 1.0), (1, 5, 2.0), (2, 1, 3.0)]);
        let x1 = Csr::from_triplets(2, 6, vec![(0, 0, 4.0), (1, 0, 5.0)]);
        let x2 = Csr::from_triplets(
            6,
            6,
            vec![(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0), (3, 3, 1.0), (4, 4, 1.0), (5, 0, 1.0)],
        );
        IrregularTensor::new(vec![x0, x1, x2])
    }

    #[test]
    fn plan_buckets_and_fallback() {
        let dir = std::env::temp_dir().join("spartan_pack_test");
        let reg = fake_registry(&dir);
        let data = tiny_data();
        let p = plan(&data, &reg);
        assert_eq!(p.plans[0].i_bucket, Some(4));
        assert_eq!(p.plans[0].c_bucket, Some(2));
        assert_eq!(p.plans[1].i_bucket, Some(4));
        assert_eq!(p.plans[1].c_bucket, Some(2));
        // subject 2: c_k = 5 > max bucket 4 ⇒ fallback
        assert_eq!(p.fallback, vec![2]);
        // subjects 0,1 share bucket (4,2) and batch size 2 ⇒ one batch
        assert_eq!(p.batches.len(), 1);
        assert_eq!(p.batches[0].subjects, vec![0, 1]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pack_xc_places_values() {
        let dir = std::env::temp_dir().join("spartan_pack_test2");
        let reg = fake_registry(&dir);
        let data = tiny_data();
        let p = plan(&data, &reg);
        let xc = pack_xc(&data, &p.batches[0], &p.plans, 2);
        assert_eq!(xc.dims, vec![2, 4, 2]);
        // subject 0: support [1,5]; X(0,1)=1 → xc[0,0,0]; X(1,5)=2 → xc[0,1,1]
        assert_eq!(xc.data[0], 1.0);
        assert_eq!(xc.data[1 * 2 + 1], 2.0);
        assert_eq!(xc.data[2 * 2 + 0], 3.0);
        // subject 1 in slot 1: support [0]; X(0,0)=4 → xc[1,0,0]
        let base = 4 * 2;
        assert_eq!(xc.data[base], 4.0);
        assert_eq!(xc.data[base + 2], 5.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pack_vc_and_w_pad_rank() {
        let dir = std::env::temp_dir().join("spartan_pack_test3");
        let reg = fake_registry(&dir);
        let data = tiny_data();
        let p = plan(&data, &reg);
        let v = Mat::from_fn(6, 2, |i, j| (i * 10 + j) as f64);
        let vc = pack_vc(&v, &p.batches[0], &p.plans, 2, 4);
        assert_eq!(vc.dims, vec![2, 2, 4]);
        // subject 0 support [1,5] → rows 1 and 5 of V, padded to width 4
        assert_eq!(vc.data[0], 10.0);
        assert_eq!(vc.data[1], 11.0);
        assert_eq!(vc.data[2], 0.0); // rank padding
        assert_eq!(vc.data[4], 50.0);
        let w = Mat::from_fn(3, 2, |i, j| (i + j) as f64);
        let wt = pack_w(&w, &p.batches[0], 2, 4);
        assert_eq!(wt.dims, vec![2, 4]);
        assert_eq!(wt.data[0], 0.0);
        assert_eq!(wt.data[1], 1.0);
        assert_eq!(wt.data[4], 1.0); // subject 1, col 0
        let h = Mat::eye(2);
        let hp = pack_h(&h, 4);
        assert_eq!(hp.dims, vec![4, 4]);
        assert_eq!(hp.data[0], 1.0);
        assert_eq!(hp.data[5], 1.0);
        assert_eq!(hp.data[10], 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
