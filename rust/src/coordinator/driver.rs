//! The PJRT-backed PARAFAC2-ALS driver: the same outer loop as
//! [`crate::parafac2::als`], with step 1 (Procrustes+pack) and the three
//! MTTKRPs executing as AOT-compiled JAX/Pallas artifacts on the XLA CPU
//! client. Factor solves, normalization and convergence bookkeeping stay
//! native (tiny R×R problems).
//!
//! Hybrid execution: subjects whose slices exceed every shape bucket run
//! on the native f64 path and their partial results merge with the PJRT
//! partials. Mixed precision: artifacts compute in f32 (the MXU story),
//! the driver accumulates in f64; parity with the native backend is
//! asserted at ~1e-3 in the integration tests.

use super::packing::{self, PackPlan};
use crate::linalg::{blas, Mat};
use crate::parafac2::cp_als::{normalize_cols_safe, residual_stats, solve_mode, CpFactors};
use crate::parafac2::init::{initialize, InitMethod};
use crate::parafac2::intermediate::{PackedSlice, PackedY};
use crate::parafac2::model::{FitStats, Parafac2Model};
use crate::parafac2::procrustes;
use crate::parafac2::procrustes::SubjectScratch;
use crate::runtime::{ArtifactRegistry, HostTensor, Kind, PjrtContext};
use crate::sparse::{CompactSlice, IrregularTensor};
use crate::threadpool::Pool;
use crate::util::timer::Stopwatch;
use anyhow::{bail, Result};

/// Configuration for the PJRT driver (a subset of [`crate::parafac2::als::Parafac2Config`]
/// — the backend is implied and the baseline knobs don't apply).
#[derive(Clone, Debug)]
pub struct PjrtFitConfig {
    pub rank: usize,
    pub max_iters: usize,
    pub tol: f64,
    pub nonneg: bool,
    pub init: InitMethod,
    pub seed: u64,
    pub workers: usize,
}

impl Default for PjrtFitConfig {
    fn default() -> Self {
        PjrtFitConfig {
            rank: 8,
            max_iters: 50,
            tol: 1e-6,
            nonneg: true,
            init: InitMethod::Random,
            seed: 42,
            workers: 0,
        }
    }
}

/// Throughput/latency counters for the end-to-end example.
#[derive(Clone, Debug, Default)]
pub struct PjrtRunMetrics {
    pub kernel_invocations: usize,
    pub kernel_secs: f64,
    pub pack_secs: f64,
    pub native_fallback_subjects: usize,
    pub pjrt_subjects: usize,
    pub batches_per_iter: usize,
    /// Cold X passes on the PJRT side: `pack_xc` streams every batched
    /// subject's CSR slice once per Procrustes step, so each step adds
    /// one pass per batched subject (keeps `FitStats::x_traversals`
    /// honest for the hybrid driver — the `x/(K·iters) ≈ 1` schema
    /// invariant must hold whichever engine did the streaming).
    pub pjrt_x_passes: u64,
}

/// The driver: owns the client, registry, and per-fit plan.
pub struct PjrtDriver<'a> {
    ctx: &'a PjrtContext,
    reg: &'a ArtifactRegistry,
    pub metrics: PjrtRunMetrics,
}

/// The per-iteration intermediate state: yt batches (PJRT side) and packed
/// fallback slices (native side).
struct YState {
    /// One HostTensor [B, C, R_pad] per batch, parallel to plan.batches.
    yt_batches: Vec<HostTensor>,
    /// Native packed slices for fallback subjects.
    fallback: Vec<(usize, PackedSlice)>,
    /// Σ‖Y_k‖² over every subject.
    norm_sq: f64,
    /// Q_k per subject, only materialized on the final pass.
    q: Option<Vec<Option<Mat>>>,
}

impl<'a> PjrtDriver<'a> {
    pub fn new(ctx: &'a PjrtContext, reg: &'a ArtifactRegistry) -> PjrtDriver<'a> {
        PjrtDriver { ctx, reg, metrics: PjrtRunMetrics::default() }
    }

    /// Fit a PARAFAC2 model through the artifact path.
    pub fn fit(&mut self, data: &IrregularTensor, cfg: &PjrtFitConfig) -> Result<Parafac2Model> {
        if cfg.rank == 0 || cfg.rank > self.reg.rank {
            bail!(
                "rank {} outside artifact support (manifest rank {}; regenerate with `python -m compile.aot --rank N`)",
                cfg.rank,
                self.reg.rank
            );
        }
        let pool = Pool::new(cfg.workers);
        let plan = packing::plan(data, self.reg);
        self.metrics.pjrt_subjects = data.k() - plan.fallback.len();
        self.metrics.native_fallback_subjects = plan.fallback.len();
        self.metrics.batches_per_iter = plan.batches.len();
        crate::info!(
            "pjrt plan: {} batches across {} subjects ({} native fallback)",
            plan.batches.len(),
            data.k(),
            plan.fallback.len()
        );

        let total_sw = Stopwatch::start();
        let x_norm_sq = data.fro_norm_sq();
        let x_norm = x_norm_sq.sqrt();
        let init = initialize(data, cfg.rank, cfg.init, cfg.seed, &pool);
        let mut factors = CpFactors { h: init.h, v: init.v, w: init.w };

        // Resident compact-X arena for the native-fallback subjects (the
        // PJRT batches pack their own operands): packed once, streamed
        // once per subject per iteration, with one reused scratch for the
        // per-subject temporaries — same single-traversal structure as the
        // native driver.
        let fallback_cx: Vec<(usize, CompactSlice)> = plan
            .fallback
            .iter()
            .map(|&k| (k, CompactSlice::pack(data.slice(k))))
            .collect();
        let mut fallback_scratch = SubjectScratch::new();

        // Default stats: PJRT fits run in-process and never shard, so the
        // `shard_reconnects`/`shard_retries` recovery counters stay 0
        // (the sharded coordinator in `service::shard` owns that path).
        let mut stats = FitStats::default();
        let mut prev_sse = f64::INFINITY;
        let mut iters_done = 0;

        for iter in 0..cfg.max_iters {
            let sw = Stopwatch::start();
            let y = self.procrustes_step(
                data,
                &plan,
                &factors,
                &pool,
                false,
                &fallback_cx,
                &mut fallback_scratch,
            )?;
            stats.procrustes_secs += sw.elapsed_secs();

            let sw = Stopwatch::start();
            let cp_res = self.cp_step(data, &plan, &y, &mut factors, cfg, &pool)?;
            stats.cp_secs += sw.elapsed_secs();

            let sse = (x_norm_sq - y.norm_sq + cp_res).max(0.0);
            let fit = 1.0 - sse.sqrt() / x_norm;
            stats.fit_history.push(fit);
            iters_done = iter + 1;
            crate::debug!("pjrt iter {iter}: sse={sse:.6e} fit={fit:.6}");

            let converged = prev_sse.is_finite()
                && (prev_sse - sse).abs() / prev_sse.max(f64::MIN_POSITIVE) < cfg.tol;
            prev_sse = sse;
            if converged {
                break;
            }
        }

        // Final pass with Q materialization.
        let y = self.procrustes_step(
            data,
            &plan,
            &factors,
            &pool,
            true,
            &fallback_cx,
            &mut fallback_scratch,
        )?;
        let qs: Vec<Mat> = y
            .q
            .expect("q requested")
            .into_iter()
            .map(|q| q.expect("every subject materialized"))
            .collect();
        // exact final SSE on the refreshed Q (same convention as native)
        let m3 = self.mttkrp3(data, &plan, &y.yt_batches, &y.fallback, &factors, &pool)?;
        let res = residual_stats(&m3, &factors, y.norm_sq);
        let final_sse = (x_norm_sq - y.norm_sq + res.y_residual_sq).max(0.0);

        stats.iterations = iters_done;
        stats.final_sse = final_sse;
        stats.final_fit = 1.0 - final_sse.sqrt() / x_norm;
        // Cold X passes across BOTH engines: the fallback arena's tally
        // plus one pass per batched subject per Procrustes step (pack_xc)
        // — so the bench-schema invariant x_traversals/(K·fit_iters) ≈ 1
        // holds for the hybrid driver too. heap_bytes covers the native
        // resident state only (PJRT operand buffers are per-step
        // transients, not arenas).
        stats.x_traversals = self.metrics.pjrt_x_passes
            + fallback_cx.iter().map(|(_, c)| c.x_traversals()).sum::<u64>();
        stats.heap_bytes = fallback_cx.iter().map(|(_, c)| c.heap_bytes()).sum::<u64>()
            + fallback_scratch.heap_bytes();
        stats.total_secs = total_sw.elapsed_secs();
        stats.secs_per_iter = if iters_done > 0 {
            (stats.procrustes_secs + stats.cp_secs) / iters_done as f64
        } else {
            0.0
        };
        Ok(Parafac2Model {
            rank: cfg.rank,
            h: factors.h,
            v: factors.v,
            w: factors.w,
            q: qs,
            stats,
        })
    }

    // --- step 1 -----------------------------------------------------------

    #[allow(clippy::too_many_arguments)]
    fn procrustes_step(
        &mut self,
        data: &IrregularTensor,
        plan: &PackPlan,
        f: &CpFactors,
        pool: &Pool,
        keep_q: bool,
        fallback_cx: &[(usize, CompactSlice)],
        fallback_scratch: &mut SubjectScratch,
    ) -> Result<YState> {
        let r_pad = self.reg.rank;
        let b_size = plan.batch_size;
        let h_t = packing::pack_h(&f.h, r_pad);
        let mut yt_batches = Vec::with_capacity(plan.batches.len());
        let mut q_store: Vec<Option<Mat>> = if keep_q { vec![None; data.k()] } else { Vec::new() };
        let mut norm_sq = 0.0;
        for batch in &plan.batches {
            let sw = Stopwatch::start();
            let xc = packing::pack_xc(data, batch, &plan.plans, b_size);
            let vc = packing::pack_vc(&f.v, batch, &plan.plans, b_size, r_pad);
            let w = packing::pack_w(&f.w, batch, b_size, r_pad);
            self.metrics.pack_secs += sw.elapsed_secs();
            // pack_xc streamed each batched subject's CSR slice once.
            self.metrics.pjrt_x_passes += batch.subjects.len() as u64;

            let kernel = self.reg.kernel(
                self.ctx,
                Kind::ProcrustesPack,
                Some(batch.i_bucket),
                batch.c_bucket,
            )?;
            let sw = Stopwatch::start();
            let out = kernel.run(&[xc, vc, h_t.clone(), w])?;
            self.metrics.kernel_secs += sw.elapsed_secs();
            self.metrics.kernel_invocations += 1;
            let [yt, q]: [HostTensor; 2] = out
                .try_into()
                .map_err(|_| anyhow::anyhow!("procrustes_pack must return (yt, q)"))?;
            norm_sq += yt.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
            if keep_q {
                // slice q [B, I, R_pad] into per-subject I_k × R blocks
                let ib = batch.i_bucket;
                for (slot, &k) in batch.subjects.iter().enumerate() {
                    let i_k = data.i_k(k);
                    let mut qm = Mat::zeros(i_k, f.h.rows());
                    for i in 0..i_k {
                        for t in 0..f.h.rows() {
                            qm[(i, t)] = q.data[slot * ib * r_pad + i * r_pad + t] as f64;
                        }
                    }
                    q_store[k] = Some(qm);
                }
            }
            yt_batches.push(yt);
        }
        // native fallback subjects, off the resident compact arena (one
        // cold X pass per subject; the repack rides it)
        let mut fallback = Vec::with_capacity(fallback_cx.len());
        for (k, cxk) in fallback_cx {
            let (packed, q) = procrustes::procrustes_and_pack_compact(
                cxk,
                &f.v,
                &f.h,
                f.w.row(*k),
                keep_q,
                fallback_scratch,
            );
            norm_sq += packed.norm_sq();
            if keep_q {
                q_store[*k] = q;
            }
            fallback.push((*k, packed));
        }
        let _ = pool;
        Ok(YState {
            yt_batches,
            fallback,
            norm_sq,
            q: if keep_q { Some(q_store) } else { None },
        })
    }

    // --- step 2 -----------------------------------------------------------

    fn cp_step(
        &mut self,
        data: &IrregularTensor,
        plan: &PackPlan,
        y: &YState,
        f: &mut CpFactors,
        cfg: &PjrtFitConfig,
        pool: &Pool,
    ) -> Result<f64> {
        // mode 1: H
        let m1 = self.mttkrp1(data, plan, &y.yt_batches, &y.fallback, f, pool)?;
        let g1 = blas::hadamard(&blas::gram(&f.w), &blas::gram(&f.v));
        f.h = solve_mode(&m1, &g1, false);
        normalize_cols_safe(&mut f.h);
        // mode 2: V
        let m2 = self.mttkrp2(data, plan, &y.yt_batches, &y.fallback, f)?;
        let g2 = blas::hadamard(&blas::gram(&f.w), &blas::gram(&f.h));
        f.v = solve_mode(&m2, &g2, cfg.nonneg);
        normalize_cols_safe(&mut f.v);
        // mode 3: W
        let m3 = self.mttkrp3(data, plan, &y.yt_batches, &y.fallback, f, pool)?;
        let g3 = blas::hadamard(&blas::gram(&f.v), &blas::gram(&f.h));
        f.w = solve_mode(&m3, &g3, cfg.nonneg);
        Ok(residual_stats(&m3, f, y.norm_sq).y_residual_sq)
    }

    fn native_y(&self, fallback: &[(usize, PackedSlice)], j_dim: usize) -> PackedY {
        PackedY { slices: fallback.iter().map(|(_, p)| p.clone()).collect(), j_dim }
    }

    fn mttkrp1(
        &mut self,
        data: &IrregularTensor,
        plan: &PackPlan,
        yt_batches: &[HostTensor],
        fallback: &[(usize, PackedSlice)],
        f: &CpFactors,
        pool: &Pool,
    ) -> Result<Mat> {
        let r = f.h.rows();
        let r_pad = self.reg.rank;
        let b_size = plan.batch_size;
        let mut m1 = Mat::zeros(r, r);
        for (batch, yt) in plan.batches.iter().zip(yt_batches) {
            let vc = packing::pack_vc(&f.v, batch, &plan.plans, b_size, r_pad);
            let w = packing::pack_w(&f.w, batch, b_size, r_pad);
            let kernel = self.reg.kernel(self.ctx, Kind::Mttkrp1, None, batch.c_bucket)?;
            let sw = Stopwatch::start();
            let out = kernel.run(&[yt.clone(), vc, w])?;
            self.metrics.kernel_secs += sw.elapsed_secs();
            self.metrics.kernel_invocations += 1;
            let part = &out[0]; // [R_pad, R_pad]
            for i in 0..r {
                for j in 0..r {
                    m1[(i, j)] += part.data[i * r_pad + j] as f64;
                }
            }
        }
        if !fallback.is_empty() {
            let ynative = self.native_y(fallback, data.j());
            let fw = fallback_w(&f.w, fallback);
            let plan = crate::threadpool::ChunkPlan::fixed(ynative.k());
            let part = crate::parafac2::mttkrp::mttkrp_mode1(&ynative, &f.v, &fw, pool, &plan);
            m1.axpy(1.0, &part);
        }
        Ok(m1)
    }

    fn mttkrp2(
        &mut self,
        data: &IrregularTensor,
        plan: &PackPlan,
        yt_batches: &[HostTensor],
        fallback: &[(usize, PackedSlice)],
        f: &CpFactors,
    ) -> Result<Mat> {
        let r = f.h.rows();
        let r_pad = self.reg.rank;
        let b_size = plan.batch_size;
        let h_t = packing::pack_h(&f.h, r_pad);
        let mut m2 = Mat::zeros(data.j(), r);
        for (batch, yt) in plan.batches.iter().zip(yt_batches) {
            let w = packing::pack_w(&f.w, batch, b_size, r_pad);
            let kernel = self.reg.kernel(self.ctx, Kind::Mttkrp2, None, batch.c_bucket)?;
            let sw = Stopwatch::start();
            let out = kernel.run(&[yt.clone(), h_t.clone(), w])?;
            self.metrics.kernel_secs += sw.elapsed_secs();
            self.metrics.kernel_invocations += 1;
            let rows = &out[0]; // [B, C, R_pad]
            let cb = batch.c_bucket;
            for (slot, &k) in batch.subjects.iter().enumerate() {
                for (c, &j) in plan.plans[k].support.iter().enumerate() {
                    let src = slot * cb * r_pad + c * r_pad;
                    let dst = m2.row_mut(j as usize);
                    for t in 0..r {
                        dst[t] += rows.data[src + t] as f64;
                    }
                }
            }
        }
        if !fallback.is_empty() {
            let ynative = self.native_y(fallback, data.j());
            let fw = fallback_w(&f.w, fallback);
            let part = crate::parafac2::mttkrp::mttkrp_mode2(
                &ynative,
                &f.h,
                &fw,
                &Pool::serial(),
                &crate::threadpool::ChunkPlan::fixed(ynative.k()),
            );
            m2.axpy(1.0, &part);
        }
        Ok(m2)
    }

    fn mttkrp3(
        &mut self,
        data: &IrregularTensor,
        plan: &PackPlan,
        yt_batches: &[HostTensor],
        fallback: &[(usize, PackedSlice)],
        f: &CpFactors,
        pool: &Pool,
    ) -> Result<Mat> {
        let r = f.h.rows();
        let r_pad = self.reg.rank;
        let b_size = plan.batch_size;
        let h_t = packing::pack_h(&f.h, r_pad);
        let mut m3 = Mat::zeros(data.k(), r);
        for (batch, yt) in plan.batches.iter().zip(yt_batches) {
            let vc = packing::pack_vc(&f.v, batch, &plan.plans, b_size, r_pad);
            let kernel = self.reg.kernel(self.ctx, Kind::Mttkrp3, None, batch.c_bucket)?;
            let sw = Stopwatch::start();
            let out = kernel.run(&[yt.clone(), vc, h_t.clone()])?;
            self.metrics.kernel_secs += sw.elapsed_secs();
            self.metrics.kernel_invocations += 1;
            let rows = &out[0]; // [B, R_pad]
            for (slot, &k) in batch.subjects.iter().enumerate() {
                let dst = m3.row_mut(k);
                for t in 0..r {
                    dst[t] = rows.data[slot * r_pad + t] as f64;
                }
            }
        }
        if !fallback.is_empty() {
            let ynative = self.native_y(fallback, data.j());
            let plan = crate::threadpool::ChunkPlan::fixed(ynative.k());
            let part = crate::parafac2::mttkrp::mttkrp_mode3(&ynative, &f.h, &f.v, pool, &plan);
            for (local, &(k, _)) in fallback.iter().enumerate() {
                m3.row_mut(k).copy_from_slice(part.row(local));
            }
        }
        Ok(m3)
    }
}

/// Extract the W rows of the fallback subjects (native kernels expect a
/// compact K'×R matrix aligned with the fallback slice order).
fn fallback_w(w: &Mat, fallback: &[(usize, PackedSlice)]) -> Mat {
    let idx: Vec<usize> = fallback.iter().map(|&(k, _)| k).collect();
    w.gather_rows(&idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_sane() {
        let c = PjrtFitConfig::default();
        assert!(c.rank > 0 && c.max_iters > 0 && c.tol > 0.0);
    }

    // End-to-end driver tests (requiring artifacts + the PJRT client) live
    // in rust/tests/pjrt_roundtrip.rs.
}
