//! The coordinator: shape-bucket batching of irregular sparse slices and
//! the PJRT-backed ALS driver that executes the AOT artifacts (with native
//! fallback for out-of-bucket subjects).

pub mod driver;
pub mod packing;

pub use driver::{PjrtDriver, PjrtFitConfig, PjrtRunMetrics};
