//! Memory-budget accounting.
//!
//! The paper's Table 1 reports the baseline running **Out of Memory** on a
//! 1 TB machine for the two largest problem instances at R=40: the explicit
//! sparse intermediate tensor `Y` (and the Khatri-Rao blocks the standard
//! kernel materializes) outgrow RAM. This box has 35 GB, and the sweeps are
//! scaled down ~50×, so the honest way to reproduce the *wall* is to track
//! the bytes the algorithm would allocate for its intermediates against a
//! proportionally scaled budget, and declare OoM when it is exceeded —
//! while also genuinely allocating, so the numbers are not fictional.
//!
//! The tracker is shared (Arc) and thread-safe; `charge` returns an error
//! once the budget is exhausted, which the baseline propagates as
//! [`crate::parafac2::OomError`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Thread-safe byte-accounting against an optional hard budget.
#[derive(Debug)]
pub struct MemBudget {
    used: AtomicU64,
    peak: AtomicU64,
    limit: Option<u64>,
}

/// Error returned when a charge would exceed the budget.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BudgetExceeded {
    pub requested: u64,
    pub used: u64,
    pub limit: u64,
}

impl std::fmt::Display for BudgetExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "memory budget exceeded: requested {} with {} already used (limit {})",
            super::humansize::bytes(self.requested),
            super::humansize::bytes(self.used),
            super::humansize::bytes(self.limit),
        )
    }
}

impl std::error::Error for BudgetExceeded {}

impl MemBudget {
    /// Budget with a hard limit in bytes.
    pub fn limited(limit_bytes: u64) -> Arc<Self> {
        Arc::new(MemBudget {
            used: AtomicU64::new(0),
            peak: AtomicU64::new(0),
            limit: Some(limit_bytes),
        })
    }

    /// Accounting only, never fails.
    pub fn unlimited() -> Arc<Self> {
        Arc::new(MemBudget {
            used: AtomicU64::new(0),
            peak: AtomicU64::new(0),
            limit: None,
        })
    }

    /// Record an allocation of `bytes`. Fails if it would exceed the limit.
    pub fn charge(&self, bytes: u64) -> Result<(), BudgetExceeded> {
        let prev = self.used.fetch_add(bytes, Ordering::Relaxed);
        let now = prev + bytes;
        if let Some(limit) = self.limit {
            if now > limit {
                // roll back so later smaller allocations may still proceed;
                // a rejected allocation never happened, so it does not count
                // toward the peak either
                self.used.fetch_sub(bytes, Ordering::Relaxed);
                return Err(BudgetExceeded { requested: bytes, used: prev, limit });
            }
        }
        self.peak.fetch_max(now, Ordering::Relaxed);
        Ok(())
    }

    /// Record a release of `bytes`.
    pub fn release(&self, bytes: u64) {
        self.used.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub fn used(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    /// High-water mark over the lifetime of the tracker.
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn limit(&self) -> Option<u64> {
        self.limit
    }
}

/// RAII guard that releases its charge on drop.
pub struct Charge<'a> {
    budget: &'a MemBudget,
    bytes: u64,
}

impl<'a> Charge<'a> {
    pub fn new(budget: &'a MemBudget, bytes: u64) -> Result<Self, BudgetExceeded> {
        budget.charge(bytes)?;
        Ok(Charge { budget, bytes })
    }
}

impl Drop for Charge<'_> {
    fn drop(&mut self) {
        self.budget.release(self.bytes);
    }
}

/// Owned RAII charge against a **shared** (`Arc`) budget — the admission
/// primitive for long-lived holders that cannot borrow the tracker for a
/// lifetime (a [`crate::parafac2::FitSession`] keeps its arena charge for
/// the whole fit; the service keeps one per resident job). Semantically
/// identical to [`Charge`]: the bytes are charged on construction
/// (admission *enforced*, not advisory — construction fails when the
/// budget would be exceeded) and released exactly once on drop.
#[derive(Debug)]
pub struct SharedCharge {
    budget: Arc<MemBudget>,
    bytes: u64,
}

impl SharedCharge {
    pub fn new(budget: &Arc<MemBudget>, bytes: u64) -> Result<Self, BudgetExceeded> {
        budget.charge(bytes)?;
        Ok(SharedCharge { budget: Arc::clone(budget), bytes })
    }

    /// Bytes held by this charge.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Shrink the held charge to `bytes` (release the difference). Used
    /// when an admission *estimate* is replaced by the actual packed size,
    /// or when a session drops a sub-resource (the CSR slices after the
    /// arena pack) without giving up the rest of its reservation. Growing
    /// is not supported — admission happens once, up front.
    pub fn shrink_to(&mut self, bytes: u64) {
        assert!(bytes <= self.bytes, "SharedCharge can only shrink");
        self.budget.release(self.bytes - bytes);
        self.bytes = bytes;
    }
}

impl Drop for SharedCharge {
    fn drop(&mut self) {
        self.budget.release(self.bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_never_fails() {
        let b = MemBudget::unlimited();
        b.charge(u64::MAX / 2).unwrap();
        assert_eq!(b.used(), u64::MAX / 2);
    }

    #[test]
    fn limit_enforced_and_rolled_back() {
        let b = MemBudget::limited(100);
        b.charge(60).unwrap();
        let err = b.charge(50).unwrap_err();
        assert_eq!(err.used, 60);
        assert_eq!(b.used(), 60); // rolled back
        b.charge(40).unwrap(); // exactly at limit is fine
        assert_eq!(b.used(), 100);
    }

    #[test]
    fn peak_tracks_high_water() {
        let b = MemBudget::unlimited();
        b.charge(100).unwrap();
        b.release(80);
        b.charge(30).unwrap();
        assert_eq!(b.used(), 50);
        assert_eq!(b.peak(), 100);
    }

    #[test]
    fn raii_guard_releases() {
        let b = MemBudget::limited(100);
        {
            let _c = Charge::new(&b, 90).unwrap();
            assert_eq!(b.used(), 90);
            assert!(Charge::new(&b, 20).is_err());
        }
        assert_eq!(b.used(), 0);
        assert_eq!(b.peak(), 90);
    }

    #[test]
    fn shared_charge_admission_and_release() {
        let b = MemBudget::limited(100);
        let c = SharedCharge::new(&b, 70).unwrap();
        assert_eq!(c.bytes(), 70);
        assert_eq!(b.used(), 70);
        // a second holder is admission-checked against the same tracker
        let err = SharedCharge::new(&b, 40).unwrap_err();
        assert_eq!(err.used, 70);
        let c2 = SharedCharge::new(&b, 30).unwrap();
        drop(c);
        assert_eq!(b.used(), 30);
        drop(c2);
        assert_eq!(b.used(), 0);
        assert_eq!(b.peak(), 100);
    }

    #[test]
    fn shared_charge_shrinks_but_never_grows() {
        let b = MemBudget::limited(100);
        let mut c = SharedCharge::new(&b, 90).unwrap();
        c.shrink_to(40); // e.g. estimate → actual, or CSR dropped post-pack
        assert_eq!(b.used(), 40);
        assert_eq!(c.bytes(), 40);
        // freed headroom is immediately admissible to others
        let c2 = SharedCharge::new(&b, 50).unwrap();
        drop(c2);
        drop(c);
        assert_eq!(b.used(), 0);
    }

    #[test]
    #[should_panic(expected = "only shrink")]
    fn shared_charge_grow_panics() {
        let b = MemBudget::unlimited();
        let mut c = SharedCharge::new(&b, 10).unwrap();
        c.shrink_to(20);
    }

    #[test]
    fn concurrent_charges_consistent() {
        let b = MemBudget::unlimited();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let b = &b;
                s.spawn(move || {
                    for _ in 0..1000 {
                        b.charge(3).unwrap();
                        b.release(3);
                    }
                });
            }
        });
        assert_eq!(b.used(), 0);
    }
}
