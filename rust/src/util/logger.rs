//! Tiny leveled logger (the offline crate set has no `tracing`, and the
//! coordinator wants structured, timestamped progress lines).
//!
//! Global level is process-wide and cheap to read (atomic). Use the
//! [`crate::info!`] / [`crate::debug!`] / [`crate::warn!`] macros.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    Off = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            "off" | "none" => Some(Level::Off),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
            Level::Off => "OFF  ",
        }
    }
}

/// Set the global level (also honors `SPARTAN_LOG` env at first use).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        3 => Level::Error,
        _ => Level::Off,
    }
}

/// Initialize from the `SPARTAN_LOG` environment variable if present.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("SPARTAN_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

fn start_instant() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Core emit function used by the macros. `module` is `module_path!()`.
pub fn emit(lvl: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if lvl < level() {
        return;
    }
    let t = start_instant().elapsed().as_secs_f64();
    let short = module.rsplit("::").next().unwrap_or(module);
    eprintln!("[{t:9.3}s {} {short}] {msg}", lvl.tag());
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::logger::emit($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("INFO"), Some(Level::Info));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Warn < Level::Error);
        assert!(Level::Error < Level::Off);
    }
}
