//! Human-readable byte and count formatting for logs and bench tables.

/// Format a byte count: `1.5GiB`, `320.0MiB`, `47B`, ...
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n}B");
    }
    let mut x = n as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    format!("{x:.1}{}", UNITS[u])
}

/// Format a count with SI-ish suffixes: `12.3M`, `500K`, `42`.
pub fn count(n: u64) -> String {
    if n >= 1_000_000_000 {
        format!("{:.2}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 10_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

/// Parse a human size like "512MiB", "1.5GB", "300M", "1024" into bytes.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-'))
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let x: f64 = num.parse().ok()?;
    if x < 0.0 {
        return None;
    }
    let mult: u64 = match unit.trim().to_ascii_lowercase().as_str() {
        "b" | "" => 1,
        "k" | "kb" | "kib" => 1 << 10,
        "m" | "mb" | "mib" => 1 << 20,
        "g" | "gb" | "gib" => 1 << 30,
        "t" | "tb" | "tib" => 1u64 << 40,
        _ => return None,
    };
    Some((x * mult as f64) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(bytes(512), "512B");
        assert_eq!(bytes(2048), "2.0KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0MiB");
        assert_eq!(bytes(5 * 1024 * 1024 * 1024), "5.0GiB");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(count(42), "42");
        assert_eq!(count(63_000_000), "63.00M");
        assert_eq!(count(12_300), "12.3K");
    }

    #[test]
    fn parse_roundtrips() {
        assert_eq!(parse_bytes("1024"), Some(1024));
        assert_eq!(parse_bytes("512MiB"), Some(512 << 20));
        assert_eq!(parse_bytes("1.5GB"), Some((1.5 * (1u64 << 30) as f64) as u64));
        assert_eq!(parse_bytes("2K"), Some(2048));
        assert_eq!(parse_bytes("nonsense"), None);
        assert_eq!(parse_bytes("-5MB"), None);
    }
}
