//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we ship a small, well-tested
//! PCG-family generator (PCG XSL RR 128/64, a.k.a. `pcg64`). Everything in
//! the repo that needs randomness (data generators, initializers, property
//! tests) goes through [`Pcg64`], so runs are reproducible from a single
//! `u64` seed.

/// PCG XSL RR 128/64 generator (O'Neill 2014).
///
/// 128-bit LCG state, 64-bit output via xorshift-low + random rotation.
/// Passes BigCrush; more than adequate for synthetic data generation.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams
    /// with the same seed are independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, n)`. Uses Lemire's unbiased multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform in `[lo, hi)` as f64.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (no caching of the pair's twin; we
    /// favor statelessness over saving one transcendental).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // in (0, 1]
        -u.ln() / lambda
    }

    /// Poisson-distributed count (Knuth for small means, normal
    /// approximation clamped at 0 for large means).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        debug_assert!(mean >= 0.0);
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = mean + mean.sqrt() * self.normal();
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm for
    /// small k, shuffle prefix otherwise). Returned sorted ascending.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut out: Vec<usize>;
        if k * 4 < n {
            // Floyd's: O(k) expected, set-based.
            let mut chosen = std::collections::HashSet::with_capacity(k * 2);
            for j in (n - k)..n {
                let t = self.below((j + 1) as u64) as usize;
                if !chosen.insert(t) {
                    chosen.insert(j);
                }
            }
            out = chosen.into_iter().collect();
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            out = all;
        }
        out.sort_unstable();
        out
    }

    /// Draw from a discrete distribution given cumulative weights
    /// (`cum` strictly increasing, last element = total mass).
    pub fn discrete_cum(&mut self, cum: &[f64]) -> usize {
        debug_assert!(!cum.is_empty());
        let total = *cum.last().unwrap();
        let x = self.f64() * total;
        match cum.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cum.len() - 1),
            Err(i) => i.min(cum.len() - 1),
        }
    }

    /// Split off an independent child generator (for per-worker streams).
    pub fn split(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seed(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg64::seed(3);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.below(10) as usize] += 1;
        }
        for c in counts {
            // expected 10_000, allow 5 sigma (~±474)
            assert!((c as i64 - 10_000).abs() < 600, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn poisson_mean_matches() {
        let mut rng = Pcg64::seed(13);
        for mean in [0.5, 3.0, 12.0, 80.0] {
            let n = 50_000;
            let s: u64 = (0..n).map(|_| rng.poisson(mean)).sum();
            let got = s as f64 / n as f64;
            assert!(
                (got - mean).abs() < 0.05 * mean + 0.05,
                "mean {mean} got {got}"
            );
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Pcg64::seed(17);
        for (n, k) in [(100, 5), (100, 90), (10, 10), (1, 1), (1000, 0)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(19);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn discrete_cum_respects_weights() {
        let mut rng = Pcg64::seed(23);
        let cum = [1.0, 1.0 + 3.0, 1.0 + 3.0 + 6.0]; // weights 1, 3, 6
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.discrete_cum(&cum)] += 1;
        }
        assert!((counts[0] as f64 / n as f64 - 0.1).abs() < 0.01);
        assert!((counts[1] as f64 / n as f64 - 0.3).abs() < 0.01);
        assert!((counts[2] as f64 / n as f64 - 0.6).abs() < 0.01);
    }
}
