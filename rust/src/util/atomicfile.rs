//! Crash-safe file commits: write-to-temp → fsync → rename.
//!
//! The durability layer (checkpoints, journal results) must never leave a
//! torn file behind — a reader either sees the previous complete version
//! or the new complete version, even if the process dies mid-write. POSIX
//! gives exactly that from `rename(2)` within one filesystem, provided
//! the temp file's contents are flushed to disk *before* the rename and
//! the containing directory entry is flushed *after* it.

use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::Path;

/// Atomically replace `path` with `bytes`.
///
/// The temp file lives next to `path` (same directory ⇒ same filesystem ⇒
/// `rename` is atomic) and carries the pid so concurrent writers of
/// *different* targets never collide; concurrent writers of the *same*
/// target last-write-win with each version complete. On any error the
/// temp file is removed and `path` is untouched.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty());
    let file_name = path
        .file_name()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "path has no file name"))?;
    let tmp = path.with_file_name(format!(
        ".{}.tmp.{}",
        file_name.to_string_lossy(),
        std::process::id()
    ));
    let commit = (|| -> io::Result<()> {
        let mut f = OpenOptions::new().write(true).create(true).truncate(true).open(&tmp)?;
        f.write_all(bytes)?;
        // Data must be durable before the rename makes it reachable —
        // otherwise a crash could publish a name pointing at torn bytes.
        f.sync_all()?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    })();
    if let Err(e) = commit {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    // Make the rename itself durable: fsync the directory entry. Best
    // effort on platforms where directories cannot be opened for sync.
    if let Some(dir) = dir {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("spartan_atomic_{name}_{}", std::process::id()))
    }

    #[test]
    fn writes_and_replaces() {
        let p = tmp("basic");
        write_atomic(&p, b"one").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"one");
        write_atomic(&p, b"two-longer").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"two-longer");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn leaves_no_temp_on_success() {
        let p = tmp("clean");
        write_atomic(&p, b"x").unwrap();
        let dir = p.parent().unwrap();
        let stem = format!(".{}.tmp", p.file_name().unwrap().to_string_lossy());
        for entry in std::fs::read_dir(dir).unwrap() {
            let name = entry.unwrap().file_name().to_string_lossy().into_owned();
            assert!(!name.starts_with(&stem), "temp file leaked: {name}");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn failed_write_preserves_previous_version() {
        let p = tmp("preserve");
        write_atomic(&p, b"stable").unwrap();
        // Writing *through* the file as if it were a directory must fail
        // without touching the committed version.
        let bad = p.join("child");
        assert!(write_atomic(&bad, b"x").is_err());
        assert_eq!(std::fs::read(&p).unwrap(), b"stable");
        std::fs::remove_file(&p).ok();
    }
}
