//! Minimal JSON value model, writer, and parser.
//!
//! The offline crate set has no `serde`, but the repo needs JSON twice:
//! writing machine-readable bench/experiment results and reading the AOT
//! `artifacts/manifest.json` emitted by `python/compile/aot.py`. This is a
//! complete (objects/arrays/strings/numbers/bools/null, escape handling)
//! but deliberately small implementation.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are sorted (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- writing ---------------------------------------------------------

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parsing ---------------------------------------------------------------

/// Parse a JSON document. Returns a descriptive error with byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| "invalid utf8 in string")?;
                    out.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::str("spartan")),
            ("sizes", Json::arr([Json::num(1.0), Json::num(2.0)])),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::Obj(Default::default())),
        ]);
        assert_eq!(parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
    }

    #[test]
    fn errors_have_positions() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }
}
