//! Cross-cutting utilities: RNG, timing, logging, JSON, sizes, memory
//! accounting. Everything here is dependency-free substrate the rest of
//! the crate builds on.

pub mod atomicfile;
pub mod humansize;
pub mod json;
pub mod logger;
pub mod membudget;
pub mod rng;
pub mod timer;

pub use membudget::MemBudget;
pub use rng::Pcg64;
pub use timer::Stopwatch;
