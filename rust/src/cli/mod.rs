//! Minimal CLI argument parser (no `clap` offline) for the `spartan`
//! launcher: subcommand + `--key value` / `--key=value` / boolean
//! `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    return Err("stray `--`".into());
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        self.get(key)
            .map(|v| v.parse::<usize>().map_err(|_| format!("--{key}: expected integer, got `{v}`")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        self.get(key)
            .map(|v| v.parse::<f64>().map_err(|_| format!("--{key}: expected number, got `{v}`")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>, String> {
        self.get(key)
            .map(|v| v.parse::<u64>().map_err(|_| format!("--{key}: expected integer, got `{v}`")))
            .transpose()
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Value of a mandatory option, with the standard error message.
    pub fn require(&self, key: &str) -> Result<&str, String> {
        self.get(key).ok_or_else(|| format!("--{key} required"))
    }

    /// Mandatory integer option (parse error or missing both reported).
    pub fn require_u64(&self, key: &str) -> Result<u64, String> {
        self.get_u64(key)?.ok_or_else(|| format!("--{key} required"))
    }

    /// Keys the user supplied (for unknown-option detection).
    pub fn option_keys(&self) -> Vec<&str> {
        self.options
            .keys()
            .map(String::as_str)
            .chain(self.flags.iter().map(String::as_str))
            .collect()
    }

    /// Error if any supplied option is not in the allowed list.
    pub fn reject_unknown(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.option_keys() {
            if !allowed.contains(&k) {
                return Err(format!("unknown option --{k} (allowed: {})", allowed.join(", ")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("decompose --rank 10 --nonneg --input=data.spt pos1");
        assert_eq!(a.subcommand.as_deref(), Some("decompose"));
        assert_eq!(a.get("rank"), Some("10"));
        assert_eq!(a.get("input"), Some("data.spt"));
        assert!(a.has_flag("nonneg"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn typed_getters() {
        let a = parse("x --n 5 --t 0.5");
        assert_eq!(a.get_usize("n").unwrap(), Some(5));
        assert_eq!(a.get_f64("t").unwrap(), Some(0.5));
        assert_eq!(a.get_usize("missing").unwrap(), None);
        let bad = parse("x --n five");
        assert!(bad.get_usize("n").is_err());
    }

    #[test]
    fn flag_vs_option_disambiguation() {
        // --a followed by another option ⇒ flag; --a value ⇒ option
        let a = parse("cmd --verbose --rank 3");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get("rank"), Some("3"));
    }

    #[test]
    fn require_reports_missing_and_bad_values() {
        let a = parse("cmd --id 7");
        assert_eq!(a.require("id").unwrap(), "7");
        assert_eq!(a.require_u64("id").unwrap(), 7);
        assert!(a.require("addr").unwrap_err().contains("--addr required"));
        assert!(a.require_u64("addr").unwrap_err().contains("--addr required"));
        let bad = parse("cmd --id seven");
        assert!(bad.require_u64("id").is_err());
    }

    #[test]
    fn reject_unknown_lists_allowed() {
        let a = parse("cmd --oops 1");
        let err = a.reject_unknown(&["rank"]).unwrap_err();
        assert!(err.contains("--oops"));
        assert!(err.contains("rank"));
    }
}
