//! Householder QR factorization (thin Q).
//!
//! Used for orthonormal random initialization of the `Q_k` factors and as a
//! building block in tests (checking `Q_kᵀQ_k = I` invariants against a
//! trusted construction).

use super::blas;
use super::dense::Mat;

/// Thin QR of an m×n matrix with m ≥ n: returns (Q m×n with orthonormal
/// columns, R n×n upper triangular) such that A = Q·R.
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_thin expects a tall matrix, got {m}x{n}");
    let mut r = a.clone();
    // Householder vectors stored column by column; betas on the side.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut betas = Vec::with_capacity(n);
    for k in 0..n {
        // Build the Householder reflector for column k below the diagonal.
        let mut v: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        let alpha = {
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if v[0] >= 0.0 {
                -norm
            } else {
                norm
            }
        };
        let beta;
        if alpha == 0.0 {
            beta = 0.0; // column already zero below: identity reflector
        } else {
            v[0] -= alpha;
            let vnorm2 = v.iter().map(|x| x * x).sum::<f64>();
            beta = if vnorm2 == 0.0 { 0.0 } else { 2.0 / vnorm2 };
        }
        // Apply reflector to the trailing submatrix of R.
        if beta != 0.0 {
            for j in k..n {
                let mut dotv = 0.0;
                for (idx, &vi) in v.iter().enumerate() {
                    dotv += vi * r[(k + idx, j)];
                }
                let s = beta * dotv;
                for (idx, &vi) in v.iter().enumerate() {
                    r[(k + idx, j)] -= s * vi;
                }
            }
            r[(k, k)] = alpha;
            for i in (k + 1)..m {
                r[(i, k)] = 0.0;
            }
        }
        vs.push(v);
        betas.push(beta);
    }
    // Accumulate thin Q by applying reflectors (in reverse) to I(m×n).
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let beta = betas[k];
        if beta == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dotv = 0.0;
            for (idx, &vi) in v.iter().enumerate() {
                dotv += vi * q[(k + idx, j)];
            }
            let s = beta * dotv;
            for (idx, &vi) in v.iter().enumerate() {
                q[(k + idx, j)] -= s * vi;
            }
        }
    }
    // Trim R to n×n.
    let r_thin = r.block(0, n, 0, n);
    (q, r_thin)
}

/// Random matrix with orthonormal columns (QR of a Gaussian matrix).
pub fn random_orthonormal(m: usize, n: usize, rng: &mut crate::util::rng::Pcg64) -> Mat {
    assert!(m >= n);
    let g = Mat::rand_normal(m, n, rng);
    let (q, _) = qr_thin(&g);
    q
}

/// Replace (near-)zero columns of `q` with unit vectors orthogonal to all
/// other columns, so `QᵀQ = I` holds exactly even when the source matrix
/// was rank-deficient. Deterministic: candidate directions are the
/// standard basis vectors, orthogonalized by two rounds of modified
/// Gram-Schmidt. Requires `rows ≥ cols`. Returns the number of columns
/// completed.
///
/// This mirrors what an SVD-based Orthogonal Procrustes solution does for
/// zero singular values (the reference Matlab implementation returns an
/// arbitrary orthonormal completion), preserving the PARAFAC2 invariant
/// `U_kᵀU_k = Φ` for every subject.
pub fn orthonormal_complete(q: &mut Mat) -> usize {
    let (m, n) = q.shape();
    assert!(m >= n, "cannot complete a short-fat matrix to orthonormal columns");
    // Full-rank fast path without touching the heap: the steady-state ALS
    // loop calls this once per subject per iteration (via the polar
    // factor), and on non-degenerate slices nothing is deficient — the
    // Procrustes phase's allocation-free contract forbids materializing
    // the norms vector just to discover that. Per-column sums accumulate
    // in the same ascending-row order as `col_norms`, so the deficiency
    // decision is identical to the slow path's.
    let any_deficient = (0..n).any(|j| {
        let mut s = 0.0;
        for i in 0..m {
            s += q[(i, j)] * q[(i, j)];
        }
        s.sqrt() < 1e-7
    });
    if !any_deficient {
        return 0;
    }
    let norms = q.col_norms();
    let deficient: Vec<usize> =
        (0..n).filter(|&j| norms[j] < 1e-7).collect();
    // zero them exactly first
    for &j in &deficient {
        for i in 0..m {
            q[(i, j)] = 0.0;
        }
    }
    let mut completed = 0;
    let mut next_basis = 0usize;
    for &j in &deficient {
        'candidates: while next_basis < m + n {
            // candidate: standard basis vector e_t
            let t = next_basis % m;
            next_basis += 1;
            let mut v = vec![0.0f64; m];
            v[t] = 1.0;
            // two rounds of MGS against every other column
            for _ in 0..2 {
                for col in 0..n {
                    if col == j {
                        continue;
                    }
                    let mut dot = 0.0;
                    for i in 0..m {
                        dot += v[i] * q[(i, col)];
                    }
                    for i in 0..m {
                        v[i] -= dot * q[(i, col)];
                    }
                }
            }
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-6 {
                for i in 0..m {
                    q[(i, j)] = v[i] / norm;
                }
                completed += 1;
                break 'candidates;
            }
        }
    }
    completed
}

/// || QᵀQ - I ||_max — orthonormality defect, used in tests/invariants.
pub fn orthonormality_defect(q: &Mat) -> f64 {
    let g = blas::gram(q);
    let n = q.cols();
    let mut worst: f64 = 0.0;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g[(i, j)] - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::seed(21);
        for (m, n) in [(5, 5), (10, 4), (100, 40), (3, 1)] {
            let a = Mat::rand_normal(m, n, &mut rng);
            let (q, r) = qr_thin(&a);
            let qr = blas::matmul(&q, &r);
            assert!(qr.max_abs_diff(&a) < 1e-10, "({m},{n})");
            assert!(orthonormality_defect(&q) < 1e-10, "({m},{n})");
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert!(r[(i, j)].abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn qr_rank_deficient_stays_finite() {
        // two identical columns
        let a = Mat::from_rows(&[&[1.0, 1.0], &[2.0, 2.0], &[3.0, 3.0]]);
        let (q, r) = qr_thin(&a);
        let qr = blas::matmul(&q, &r);
        assert!(qr.max_abs_diff(&a) < 1e-10);
        assert!(q.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn random_orthonormal_is_orthonormal() {
        let mut rng = Pcg64::seed(22);
        let q = random_orthonormal(50, 10, &mut rng);
        assert!(orthonormality_defect(&q) < 1e-10);
    }

    #[test]
    fn complete_restores_orthonormality() {
        let mut rng = Pcg64::seed(23);
        // orthonormal basis with two columns zeroed
        let mut q = random_orthonormal(12, 5, &mut rng);
        for i in 0..12 {
            q[(i, 1)] = 0.0;
            q[(i, 4)] = 0.0;
        }
        let n = orthonormal_complete(&mut q);
        assert_eq!(n, 2);
        assert!(orthonormality_defect(&q) < 1e-9);
    }

    #[test]
    fn complete_noop_on_full_rank() {
        let mut rng = Pcg64::seed(24);
        let mut q = random_orthonormal(8, 3, &mut rng);
        let before = q.clone();
        assert_eq!(orthonormal_complete(&mut q), 0);
        assert_eq!(q.data(), before.data());
    }

    #[test]
    fn complete_all_zero() {
        let mut q = Mat::zeros(6, 3);
        assert_eq!(orthonormal_complete(&mut q), 3);
        assert!(orthonormality_defect(&q) < 1e-10);
    }

    #[test]
    fn qr_zero_matrix() {
        let a = Mat::zeros(4, 2);
        let (q, r) = qr_thin(&a);
        assert!(blas::matmul(&q, &r).max_abs_diff(&a) < 1e-12);
    }
}
