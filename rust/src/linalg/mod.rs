//! Dense linear algebra substrate (no external BLAS/LAPACK in the offline
//! crate set, so everything the PARAFAC2 fitting algorithm needs is built
//! here): row-major matrices, GEMM kernels, Householder QR, Jacobi
//! SVD/eigendecomposition, the Procrustes polar factor, SPD solvers, and
//! Bro & de Jong's fast NNLS.
//!
//! The ALS hot loops run on the register-blocked micro-kernels in
//! [`kernels`] — one dispatch point with a scalar reference implementation
//! per shape and a documented bitwise/ULP determinism contract (pinned by
//! `rust/tests/kernel_conformance.rs`).

pub mod blas;
pub mod dense;
pub mod kernels;
pub mod nnls;
pub mod norms;
pub mod qr;
pub mod solve;
pub mod svd;

pub use blas::{dot, gram, hadamard, khatri_rao, matmul, matmul_a_bt, matmul_at_b};
pub use dense::Mat;
pub use nnls::{fnnls, nnls_gram_system};
pub use norms::{column_congruence, fms_greedy, fms_joint};
pub use qr::{qr_thin, random_orthonormal};
pub use solve::{solve_gram_system, solve_spd};
pub use svd::{pinv, pinv_psd, polar_orthonormal, svd_thin, sym_eig};
