//! Factor-comparison metrics: congruence and the Factor Match Score (FMS)
//! used to validate model recovery against planted ground truth.

use super::blas;
use super::dense::Mat;

/// Cosine similarity matrix between columns of `a` and columns of `b`
/// (both m×r; result r_a × r_b).
pub fn column_congruence(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows());
    let an = a.col_norms();
    let bn = b.col_norms();
    let mut c = blas::matmul_at_b(a, b);
    for i in 0..c.rows() {
        for j in 0..c.cols() {
            let d = an[i] * bn[j];
            c[(i, j)] = if d > 0.0 { c[(i, j)] / d } else { 0.0 };
        }
    }
    c
}

/// Greedy Factor Match Score between two factor sets with the same rank:
/// match columns greedily by absolute congruence and average the matched
/// scores. 1.0 = perfect recovery up to permutation/sign/scale.
pub fn fms_greedy(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.cols(), b.cols());
    let r = a.cols();
    if r == 0 {
        return 1.0;
    }
    let c = column_congruence(a, b);
    let mut used_a = vec![false; r];
    let mut used_b = vec![false; r];
    let mut total = 0.0;
    for _ in 0..r {
        let mut best = (0usize, 0usize, -1.0f64);
        for i in 0..r {
            if used_a[i] {
                continue;
            }
            for j in 0..r {
                if used_b[j] {
                    continue;
                }
                let v = c[(i, j)].abs();
                if v > best.2 {
                    best = (i, j, v);
                }
            }
        }
        used_a[best.0] = true;
        used_b[best.1] = true;
        total += best.2;
    }
    total / r as f64
}

/// Joint FMS over multiple aligned factor matrices (e.g. V and W): the
/// column matching is chosen on the *product* of congruences so all factors
/// must agree on the permutation.
pub fn fms_joint(pairs: &[(&Mat, &Mat)]) -> f64 {
    assert!(!pairs.is_empty());
    let r = pairs[0].0.cols();
    for (a, b) in pairs {
        assert_eq!(a.cols(), r);
        assert_eq!(b.cols(), r);
    }
    if r == 0 {
        return 1.0;
    }
    // score(i,j) = Π_f |congr_f(i,j)|
    let mut score = Mat::from_fn(r, r, |_, _| 1.0);
    for (a, b) in pairs {
        let c = column_congruence(a, b);
        for i in 0..r {
            for j in 0..r {
                score[(i, j)] *= c[(i, j)].abs();
            }
        }
    }
    let mut used_a = vec![false; r];
    let mut used_b = vec![false; r];
    let mut total = 0.0;
    for _ in 0..r {
        let mut best = (0usize, 0usize, -1.0f64);
        for i in 0..r {
            if used_a[i] {
                continue;
            }
            for j in 0..r {
                if !used_b[j] && score[(i, j)] > best.2 {
                    best = (i, j, score[(i, j)]);
                }
            }
        }
        used_a[best.0] = true;
        used_b[best.1] = true;
        total += best.2;
    }
    total / r as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn congruence_identity() {
        let mut rng = Pcg64::seed(61);
        let a = Mat::rand_normal(10, 3, &mut rng);
        let c = column_congruence(&a, &a);
        for i in 0..3 {
            assert!((c[(i, i)] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn fms_perfect_under_permutation_and_scale() {
        let mut rng = Pcg64::seed(62);
        let a = Mat::rand_normal(20, 4, &mut rng);
        // permute columns [2,0,3,1], scale, flip a sign
        let perm = [2usize, 0, 3, 1];
        let scales = [3.0, -0.5, 1.7, 2.2];
        let b = Mat::from_fn(20, 4, |i, j| a[(i, perm[j])] * scales[j]);
        assert!(fms_greedy(&a, &b) > 1.0 - 1e-10);
    }

    #[test]
    fn fms_low_for_unrelated() {
        let mut rng = Pcg64::seed(63);
        let a = Mat::rand_normal(500, 4, &mut rng);
        let b = Mat::rand_normal(500, 4, &mut rng);
        assert!(fms_greedy(&a, &b) < 0.3);
    }

    #[test]
    fn joint_fms_requires_consistent_permutation() {
        let mut rng = Pcg64::seed(64);
        let v = Mat::rand_normal(30, 3, &mut rng);
        let w = Mat::rand_normal(25, 3, &mut rng);
        // consistent permutation on both -> near 1
        let perm = [1usize, 2, 0];
        let vp = Mat::from_fn(30, 3, |i, j| v[(i, perm[j])]);
        let wp = Mat::from_fn(25, 3, |i, j| w[(i, perm[j])]);
        assert!(fms_joint(&[(&v, &vp), (&w, &wp)]) > 1.0 - 1e-9);
        // inconsistent permutations -> strictly lower
        let wq = Mat::from_fn(25, 3, |i, j| w[(i, [2usize, 0, 1][j])]);
        assert!(fms_joint(&[(&v, &vp), (&w, &wq)]) < 0.9);
    }
}
