//! Linear solvers: Cholesky for SPD systems, with pseudo-inverse fallback.
//!
//! CP-ALS factor updates solve `M · G⁺` where `G` is a Hadamard product of
//! Gram matrices — symmetric PSD, usually well-conditioned but exactly
//! singular when a factor column collapses. We try Cholesky first (fast
//! path) and fall back to the eigen-based pseudo-inverse.

use super::dense::Mat;
use super::svd;

/// Cholesky factorization A = L·Lᵀ of an SPD matrix.
/// Returns `None` if the matrix is not (numerically) positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve L·y = b (forward substitution), L lower triangular.
pub fn forward_sub(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[(i, k)] * y[k];
        }
        y[i] = s / l[(i, i)];
    }
    y
}

/// Solve Lᵀ·x = y (backward substitution).
pub fn backward_sub_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve the SPD system A·x = b via Cholesky; `None` if not SPD.
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(backward_sub_t(&l, &forward_sub(&l, b)))
}

/// Solve X·A = M for X, where A is symmetric PSD (the CP-ALS update
/// `factor ← MTTKRP · G⁺`). Row-wise Cholesky solves with pinv fallback.
pub fn solve_gram_system(m: &Mat, g: &Mat) -> Mat {
    let n = g.rows();
    assert_eq!(m.cols(), n);
    if let Some(l) = cholesky(g) {
        // X(i,:) solves G·xᵀ = M(i,:)ᵀ (G symmetric so left/right agree).
        let mut out = Mat::zeros(m.rows(), n);
        for i in 0..m.rows() {
            let x = backward_sub_t(&l, &forward_sub(&l, m.row(i)));
            out.row_mut(i).copy_from_slice(&x);
        }
        out
    } else {
        let gp = svd::pinv_psd(g);
        super::blas::matmul(m, &gp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Pcg64;

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg64::seed(41);
        let g0 = Mat::rand_normal(10, 6, &mut rng);
        let a = blas::gram(&g0);
        let l = cholesky(&a).expect("SPD");
        let rec = blas::matmul_a_bt(&l, &l);
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigvals 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_roundtrip() {
        let mut rng = Pcg64::seed(42);
        let g0 = Mat::rand_normal(9, 5, &mut rng);
        let a = blas::gram(&g0);
        let x_true: Vec<f64> = (0..5).map(|i| i as f64 - 2.0).collect();
        let b = blas::mat_vec(&a, &x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-8);
        }
    }

    #[test]
    fn solve_gram_system_matches_pinv() {
        let mut rng = Pcg64::seed(43);
        let g0 = Mat::rand_normal(12, 4, &mut rng);
        let g = blas::gram(&g0);
        let m = Mat::rand_normal(7, 4, &mut rng);
        let x = solve_gram_system(&m, &g);
        let want = blas::matmul(&m, &svd::pinv_psd(&g));
        assert!(x.max_abs_diff(&want) < 1e-7);
    }

    #[test]
    fn solve_gram_system_singular_falls_back() {
        // G singular: one zero row/col.
        let mut g = Mat::zeros(3, 3);
        g[(0, 0)] = 2.0;
        g[(1, 1)] = 3.0;
        let m = Mat::from_rows(&[&[2.0, 3.0, 0.0]]);
        let x = solve_gram_system(&m, &g);
        assert!((x[(0, 0)] - 1.0).abs() < 1e-10);
        assert!((x[(0, 1)] - 1.0).abs() < 1e-10);
        assert_eq!(x[(0, 2)], 0.0);
    }
}
