//! Dense row-major `f64` matrix.
//!
//! The native compute path stores everything as row-major `f64` (the paper's
//! Matlab reference is double precision). Row-major is the natural layout
//! for SPARTan's kernels, which stream *rows*: `Y_k(j,:) · V` partial
//! products, row-wise Hadamards with `W(k,:)`, and row-gathered `V_c`
//! packing.

use crate::util::rng::Pcg64;

/// Row-major dense matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "..." } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// From rows of slices (convenience for tests).
    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// i.i.d. uniform [0,1) entries.
    pub fn rand_uniform(rows: usize, cols: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.f64())
    }

    /// i.i.d. standard normal entries.
    pub fn rand_normal(rows: usize, cols: usize, rng: &mut Pcg64) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal())
    }

    /// Diagonal matrix from a vector.
    pub fn diag(v: &[f64]) -> Mat {
        let n = v.len();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = v[i];
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Two distinct mutable rows at once (for rotations).
    pub fn two_rows_mut(&mut self, i: usize, j: usize) -> (&mut [f64], &mut [f64]) {
        assert!(i != j && i < self.rows && j < self.rows);
        let c = self.cols;
        if i < j {
            let (a, b) = self.data.split_at_mut(j * c);
            (&mut a[i * c..(i + 1) * c], &mut b[..c])
        } else {
            let (a, b) = self.data.split_at_mut(i * c);
            let bj = &mut a[j * c..(j + 1) * c];
            (&mut b[..c], bj)
        }
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Raw row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the raw buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            for (j, &x) in row.iter().enumerate() {
                t[(j, i)] = x;
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Frobenius norm of (self - other).
    pub fn fro_dist(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// self += alpha * other.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Set everything to zero (reuse allocation).
    pub fn fill_zero(&mut self) {
        self.data.fill(0.0);
    }

    /// Reshape in place to an all-zeros `rows × cols` matrix, reusing the
    /// existing buffer capacity. Allocates only while the buffer is still
    /// growing toward its high-water mark — the primitive behind the
    /// per-worker scratch arenas that make steady-state ALS iterations
    /// allocation-free (`parafac2::procrustes::SubjectScratch`,
    /// `linalg::svd::PolarScratch`).
    pub fn reset_to_zeros(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Reshape in place to the `n × n` identity, reusing the buffer
    /// (see [`Mat::reset_to_zeros`]). Same values as [`Mat::eye`].
    pub fn reset_to_eye(&mut self, n: usize) {
        self.reset_to_zeros(n, n);
        for i in 0..n {
            self[(i, i)] = 1.0;
        }
    }

    /// Reshape in place WITHOUT zero-filling: for callers that overwrite
    /// **every** element immediately (gathers, transposes, dense fills).
    /// The retained contents are the previous buffer's values — never
    /// uninitialized memory — but they are unspecified, so a caller that
    /// reads or accumulates before writing each element must use
    /// [`Mat::reset_to_zeros`] instead. Skipping the fill removes a full
    /// write pass per buffer per subject from the steady-state hot loops.
    pub fn reset_for_overwrite(&mut self, rows: usize, cols: usize) {
        let n = rows * cols;
        self.rows = rows;
        self.cols = cols;
        if self.data.len() > n {
            self.data.truncate(n);
        } else {
            // only the newly exposed tail gets the (dummy) fill value
            self.data.resize(n, 0.0);
        }
    }

    /// Transposed copy into a reused output buffer — same values, same
    /// write order as [`Mat::transpose`], zero steady-state allocations
    /// (every element is written, so no zero-fill pass is needed).
    pub fn transpose_into(&self, out: &mut Mat) {
        out.reset_for_overwrite(self.cols, self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            for (j, &x) in row.iter().enumerate() {
                out[(j, i)] = x;
            }
        }
    }

    /// Heap bytes held by the backing buffer (scratch-arena accounting).
    pub fn heap_bytes(&self) -> u64 {
        (self.data.capacity() * std::mem::size_of::<f64>()) as u64
    }

    /// Euclidean norm of each column.
    pub fn col_norms(&self) -> Vec<f64> {
        let mut norms = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, &x) in self.row(i).iter().enumerate() {
                norms[j] += x * x;
            }
        }
        for n in &mut norms {
            *n = n.sqrt();
        }
        norms
    }

    /// Divide each column by the given per-column factor (skip zeros).
    pub fn scale_cols_inv(&mut self, factors: &[f64]) {
        assert_eq!(factors.len(), self.cols);
        for i in 0..self.rows {
            let row = self.row_mut(i);
            for (j, x) in row.iter_mut().enumerate() {
                if factors[j] != 0.0 {
                    *x /= factors[j];
                }
            }
        }
    }

    /// Normalize columns to unit 2-norm, returning the previous norms.
    pub fn normalize_cols(&mut self) -> Vec<f64> {
        let norms = self.col_norms();
        self.scale_cols_inv(&norms);
        norms
    }

    /// Clamp all entries to be >= 0 (projection onto the nonnegative orthant).
    pub fn clamp_nonneg(&mut self) {
        for x in &mut self.data {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
    }

    /// Extract a sub-block (row range, col range) as a new matrix.
    pub fn block(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        Mat::from_fn(r1 - r0, c1 - c0, |i, j| self[(r0 + i, c0 + j)])
    }

    /// Gather selected rows into a new matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (dst, &src) in idx.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(self.row(src));
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.shape(), (2, 2));
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col(0), vec![1.0, 3.0]);
    }

    #[test]
    fn eye_and_diag() {
        let i = Mat::eye(3);
        assert_eq!(i[(1, 1)], 1.0);
        assert_eq!(i[(0, 1)], 0.0);
        let d = Mat::diag(&[2.0, 5.0]);
        assert_eq!(d[(1, 1)], 5.0);
        assert_eq!(d[(1, 0)], 0.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed(1);
        let m = Mat::rand_normal(4, 7, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(3, 2)], m[(2, 3)]);
    }

    #[test]
    fn norms() {
        let m = Mat::from_rows(&[&[3.0, 0.0], &[0.0, 4.0]]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        let z = Mat::zeros(2, 2);
        assert!((m.fro_dist(&z) - 5.0).abs() < 1e-12);
        assert_eq!(m.max_abs_diff(&z), 4.0);
    }

    #[test]
    fn normalize_cols_unit() {
        let mut m = Mat::from_rows(&[&[3.0, 0.0], &[4.0, 0.0]]);
        let norms = m.normalize_cols();
        assert!((norms[0] - 5.0).abs() < 1e-12);
        assert_eq!(norms[1], 0.0); // zero column untouched
        assert!((m[(0, 0)] - 0.6).abs() < 1e-12);
        assert!((m[(1, 0)] - 0.8).abs() < 1e-12);
        assert_eq!(m[(0, 1)], 0.0);
    }

    #[test]
    fn two_rows_mut_disjoint() {
        let mut m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        {
            let (a, b) = m.two_rows_mut(2, 0);
            a[0] = 50.0;
            b[1] = 20.0;
        }
        assert_eq!(m[(2, 0)], 50.0);
        assert_eq!(m[(0, 1)], 20.0);
        // reversed order too
        let (a, b) = m.two_rows_mut(0, 2);
        assert_eq!(a[1], 20.0);
        assert_eq!(b[0], 50.0);
    }

    #[test]
    fn gather_rows_selects() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.row(0), &[5.0, 6.0]);
        assert_eq!(g.row(1), &[1.0, 2.0]);
    }

    #[test]
    fn block_extracts() {
        let m = Mat::from_fn(4, 5, |i, j| (i * 5 + j) as f64);
        let b = m.block(1, 3, 2, 5);
        assert_eq!(b.shape(), (2, 3));
        assert_eq!(b[(0, 0)], 7.0);
        assert_eq!(b[(1, 2)], 14.0);
    }

    #[test]
    fn reset_reuses_buffer_and_matches_fresh() {
        let mut m = Mat::rand_normal(6, 7, &mut Pcg64::seed(2));
        let ptr = m.data().as_ptr();
        m.reset_to_zeros(4, 5); // shrink: must not reallocate
        assert_eq!(m.shape(), (4, 5));
        assert!(m.data().iter().all(|&x| x == 0.0));
        assert_eq!(m.data().as_ptr(), ptr);
        m.reset_to_eye(3);
        assert_eq!(m.data(), Mat::eye(3).data());
        assert_eq!(m.data().as_ptr(), ptr);
    }

    #[test]
    fn transpose_into_matches_transpose() {
        let mut rng = Pcg64::seed(3);
        let m = Mat::rand_normal(5, 8, &mut rng);
        // stale, larger buffer: reset_for_overwrite must not leak old
        // contents through the full-overwrite fill
        let mut out = Mat::rand_normal(9, 9, &mut rng);
        m.transpose_into(&mut out);
        assert_eq!(out.data(), m.transpose().data());
        assert_eq!(out.shape(), (8, 5));
        // and growing from a smaller stale buffer also matches
        let big = Mat::rand_normal(12, 11, &mut rng);
        big.transpose_into(&mut out);
        assert_eq!(out.data(), big.transpose().data());
    }

    #[test]
    fn reset_for_overwrite_reuses_buffer() {
        let mut m = Mat::rand_normal(6, 7, &mut Pcg64::seed(4));
        let ptr = m.data().as_ptr();
        m.reset_for_overwrite(3, 4); // shrink: no realloc, no fill pass
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m.data().as_ptr(), ptr);
        assert_eq!(m.data().len(), 12);
    }

    #[test]
    fn clamp_nonneg_projects() {
        let mut m = Mat::from_rows(&[&[-1.0, 2.0], &[3.0, -4.0]]);
        m.clamp_nonneg();
        assert_eq!(m.data(), &[0.0, 2.0, 3.0, 0.0]);
    }
}
