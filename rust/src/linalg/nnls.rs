//! Fast Non-Negative Least Squares (FNNLS), Bro & de Jong 1997.
//!
//! The paper imposes non-negativity on the `{S_k}` and `V` factors of
//! PARAFAC2 by swapping the unconstrained least-squares solves inside the
//! CP-ALS iteration for NNLS solves (paper §3.2, citing [8] = Bro & de
//! Jong). FNNLS is the "fast" variant of Lawson–Hanson that works directly
//! from the normal-equation quantities `AᵀA` and `Aᵀb` — exactly what
//! CP-ALS already has in hand (the Hadamard-of-Grams matrix and the MTTKRP
//! rows), so no extra passes over the data are needed.

use super::dense::Mat;

/// Solve `min ‖A x − b‖₂ s.t. x ≥ 0` given `ata = AᵀA` (n×n, symmetric
/// PSD) and `atb = Aᵀb` (n). Active-set method; terminates in finitely
/// many iterations (guarded by `max_iter`).
pub fn fnnls(ata: &Mat, atb: &[f64]) -> Vec<f64> {
    let n = atb.len();
    assert_eq!(ata.shape(), (n, n));
    let tol = 10.0 * f64::EPSILON * inf_norm(ata) * n as f64;
    let mut passive = vec![false; n]; // P set
    let mut x = vec![0.0; n];
    // w = Aᵀb − AᵀA x  (gradient of ½‖Ax−b‖² negated)
    let mut w: Vec<f64> = atb.to_vec();
    let max_iter = 30 * n.max(1);
    let mut iter = 0;
    loop {
        // Find the most violated KKT multiplier among the active set.
        let mut t_best: Option<usize> = None;
        let mut w_best = tol;
        for j in 0..n {
            if !passive[j] && w[j] > w_best {
                w_best = w[j];
                t_best = Some(j);
            }
        }
        let Some(t) = t_best else { break };
        passive[t] = true;

        loop {
            iter += 1;
            if iter > max_iter {
                break;
            }
            // Solve the unconstrained LS on the passive set.
            let p_idx: Vec<usize> = (0..n).filter(|&j| passive[j]).collect();
            let s_p = solve_passive(ata, atb, &p_idx);
            // If the passive solution is feasible, accept it.
            if s_p.iter().all(|&v| v > tol) {
                for (xi, &j) in s_p.iter().zip(&p_idx) {
                    x[j] = *xi;
                }
                for j in 0..n {
                    if !passive[j] {
                        x[j] = 0.0;
                    }
                }
                break;
            }
            // Otherwise step toward it until the first variable hits zero.
            let mut alpha = f64::INFINITY;
            for (si, &j) in s_p.iter().zip(&p_idx) {
                if *si <= tol {
                    let d = x[j] - si;
                    if d > 0.0 {
                        alpha = alpha.min(x[j] / d);
                    }
                }
            }
            if !alpha.is_finite() {
                alpha = 0.0;
            }
            for (si, &j) in s_p.iter().zip(&p_idx) {
                x[j] += alpha * (si - x[j]);
            }
            // Move variables that reached zero back to the active set.
            for &j in &p_idx {
                if x[j] <= tol {
                    x[j] = 0.0;
                    passive[j] = false;
                }
            }
        }
        if iter > max_iter {
            break;
        }
        // Refresh the gradient.
        for j in 0..n {
            let mut s = atb[j];
            for k in 0..n {
                if x[k] != 0.0 {
                    s -= ata[(j, k)] * x[k];
                }
            }
            w[j] = s;
        }
    }
    x
}

/// Solve the LS subproblem restricted to the passive index set via
/// Cholesky on the principal submatrix (pinv fallback for singularity).
fn solve_passive(ata: &Mat, atb: &[f64], p_idx: &[usize]) -> Vec<f64> {
    let np = p_idx.len();
    let sub = Mat::from_fn(np, np, |i, j| ata[(p_idx[i], p_idx[j])]);
    let rhs: Vec<f64> = p_idx.iter().map(|&j| atb[j]).collect();
    match super::solve::solve_spd(&sub, &rhs) {
        Some(x) => x,
        None => {
            let sp = super::svd::pinv_psd(&sub);
            super::blas::mat_vec(&sp, &rhs)
        }
    }
}

fn inf_norm(a: &Mat) -> f64 {
    a.data().iter().map(|x| x.abs()).fold(0.0, f64::max)
}

/// Row-wise NNLS: for each row i of `m`, solve `min_x ‖A x − b_i‖, x ≥ 0`
/// with `AᵀA = g` and `Aᵀb_i = m(i,:)`. The non-negative counterpart of
/// [`super::solve::solve_gram_system`], used for the V and W updates.
///
/// Fast path (§Perf): factor `g` once and solve every row unconstrained;
/// only rows whose unconstrained optimum leaves the non-negative orthant
/// enter the FNNLS active-set machinery. On non-negative data most rows
/// take the fast path, amortizing one Cholesky across K (or J) rows
/// instead of re-factoring per row per active-set step.
pub fn nnls_gram_system(m: &Mat, g: &Mat) -> Mat {
    let mut out = Mat::zeros(m.rows(), g.rows());
    let chol = super::solve::cholesky(g);
    for i in 0..m.rows() {
        if let Some(l) = &chol {
            let x = super::solve::backward_sub_t(l, &super::solve::forward_sub(l, m.row(i)));
            if x.iter().all(|&v| v >= 0.0) {
                out.row_mut(i).copy_from_slice(&x);
                continue;
            }
        }
        let x = fnnls(g, m.row(i));
        out.row_mut(i).copy_from_slice(&x);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Pcg64;

    /// Brute-force reference for tiny n: enumerate all active sets.
    fn brute_force_nnls(ata: &Mat, atb: &[f64]) -> Vec<f64> {
        let n = atb.len();
        let mut best: Option<(f64, Vec<f64>)> = None;
        for mask in 0..(1u32 << n) {
            let p_idx: Vec<usize> = (0..n).filter(|&j| mask >> j & 1 == 1).collect();
            let mut x = vec![0.0; n];
            if !p_idx.is_empty() {
                let s = solve_passive(ata, atb, &p_idx);
                if s.iter().any(|&v| v < -1e-12) {
                    continue;
                }
                for (si, &j) in s.iter().zip(&p_idx) {
                    x[j] = *si;
                }
            }
            // objective ½ xᵀG x − xᵀb (up to constant)
            let gx = blas::mat_vec(ata, &x);
            let obj = 0.5 * blas::dot(&x, &gx) - blas::dot(&x, atb);
            if best.as_ref().map_or(true, |(b, _)| obj < b - 1e-14) {
                best = Some((obj, x));
            }
        }
        best.unwrap().1
    }

    #[test]
    fn unconstrained_optimum_nonneg_is_returned() {
        // If the LS solution is already nonnegative, FNNLS must find it.
        let mut rng = Pcg64::seed(51);
        let a = Mat::rand_uniform(20, 4, &mut rng); // positive A
        let x_true = [1.0, 0.5, 2.0, 0.25];
        let b = blas::mat_vec(&a, &x_true);
        let ata = blas::gram(&a);
        let atb = blas::vec_mat(&b, &a);
        let x = fnnls(&ata, &atb);
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-8, "{got} vs {want}");
        }
    }

    #[test]
    fn negative_ls_gets_clamped_correctly() {
        let mut rng = Pcg64::seed(52);
        for trial in 0..50 {
            let a = Mat::rand_normal(12, 4, &mut rng);
            let b: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
            let ata = blas::gram(&a);
            let atb = blas::vec_mat(&b, &a);
            let x = fnnls(&ata, &atb);
            assert!(x.iter().all(|&v| v >= 0.0), "trial {trial}");
            let want = brute_force_nnls(&ata, &atb);
            // compare objectives rather than x (ties possible)
            let obj = |x: &[f64]| {
                let gx = blas::mat_vec(&ata, x);
                0.5 * blas::dot(x, &gx) - blas::dot(x, &atb)
            };
            assert!(
                obj(&x) <= obj(&want) + 1e-8,
                "trial {trial}: {} vs {}",
                obj(&x),
                obj(&want)
            );
        }
    }

    #[test]
    fn zero_rhs_gives_zero() {
        let ata = Mat::eye(3);
        let x = fnnls(&ata, &[0.0, 0.0, 0.0]);
        assert_eq!(x, vec![0.0; 3]);
    }

    #[test]
    fn all_negative_gradient_gives_zero() {
        let ata = Mat::eye(2);
        let x = fnnls(&ata, &[-1.0, -5.0]);
        assert_eq!(x, vec![0.0; 2]);
    }

    #[test]
    fn nnls_gram_system_rowwise() {
        let mut rng = Pcg64::seed(53);
        let a = Mat::rand_uniform(15, 3, &mut rng);
        let g = blas::gram(&a);
        let m = Mat::rand_normal(4, 3, &mut rng);
        let out = nnls_gram_system(&m, &g);
        assert_eq!(out.shape(), (4, 3));
        for i in 0..4 {
            let want = fnnls(&g, m.row(i));
            for (a, b) in out.row(i).iter().zip(&want) {
                assert_eq!(a, b);
            }
        }
    }
}
