//! SVD, symmetric eigendecomposition, polar factor, pseudo-inverse.
//!
//! Two workhorses live here:
//!
//! * [`sym_eig`] — cyclic Jacobi eigendecomposition of a symmetric R×R
//!   matrix. R is the PARAFAC2 target rank (≤ 64 in every experiment), so
//!   Jacobi's O(R³ · sweeps) with quadratic convergence is the right tool:
//!   simple, branch-light, and accurate to machine precision.
//! * [`polar_orthonormal`] — the Orthogonal Procrustes solution. The
//!   minimizer of ‖X_k − Q H S_k Vᵀ‖_F over QᵀQ = I is the orthonormal
//!   polar factor of B = X_k V S_k Hᵀ, computed as B·(BᵀB)^(−1/2) via
//!   [`sym_eig`] on the small Gram matrix — O(I_k R²) instead of a full
//!   O(I_k R² · sweeps) one-sided-Jacobi SVD of B. This is the per-subject
//!   step 1 of PARAFAC2-ALS (paper Algorithm 2, lines 3–6).
//!
//! A general thin [`svd_thin`] (one-sided Jacobi) is kept for tests,
//! initialization, and conditioning fallbacks.

use super::blas;
use super::dense::Mat;

/// Relative spectral cutoff used to declare eigen/singular values zero.
const RELATIVE_RANK_TOL: f64 = 1e-12;

/// Jacobi eigendecomposition of a symmetric matrix.
///
/// Returns `(eigvals, eigvecs)` with `A = V · diag(λ) · Vᵀ`, eigenvalues
/// sorted descending, eigenvectors as *columns* of `V`.
pub fn sym_eig(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows();
    assert_eq!(a.cols(), n, "sym_eig expects square");
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius mass; stop when negligible vs diagonal.
        let mut off = 0.0;
        let mut diag = 0.0;
        for i in 0..n {
            diag += m[(i, i)] * m[(i, i)];
            for j in (i + 1)..n {
                off += 2.0 * m[(i, j)] * m[(i, j)];
            }
        }
        if off <= (diag + off) * 1e-28 + f64::MIN_POSITIVE {
            break;
        }
        // Per-sweep skip threshold: pairs already numerically diagonal are
        // not rotated — later sweeps become nearly free (quadratic
        // convergence leaves only a few live pairs).
        let skip_tol = 1e-18;
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                if apq * apq <= skip_tol * app.abs().max(1e-300) * aqq.abs().max(1e-300)
                    && apq * apq <= skip_tol * (diag / n as f64)
                {
                    continue;
                }
                // Classic stable rotation computation.
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // A ← JᵀAJ. A stays symmetric, so the column updates are
                // the row updates transposed: rotate rows p and q
                // (contiguous, vectorizable), then mirror them into the
                // columns, then fix the 2×2 pivot block analytically.
                {
                    let (rp, rq) = m.two_rows_mut(p, q);
                    for (a, b) in rp.iter_mut().zip(rq.iter_mut()) {
                        let x = *a;
                        let y = *b;
                        *a = c * x - s * y;
                        *b = s * x + c * y;
                    }
                }
                // mirror rows into columns (strided writes, values ready)
                for k in 0..n {
                    if k != p && k != q {
                        m[(k, p)] = m[(p, k)];
                        m[(k, q)] = m[(q, k)];
                    }
                }
                // pivot block: standard Jacobi update
                let new_app = app - t * apq;
                let new_aqq = aqq + t * apq;
                m[(p, p)] = new_app;
                m[(q, q)] = new_aqq;
                m[(p, q)] = 0.0;
                m[(q, p)] = 0.0;
                // Accumulate eigenvectors: rotate V's columns p and q —
                // done row-wise (contiguous pairs within each row).
                for k in 0..n {
                    let row = v.row_mut(k);
                    let vkp = row[p];
                    let vkq = row[q];
                    row[p] = c * vkp - s * vkq;
                    row[q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    let lam: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&i, &j| lam[j].partial_cmp(&lam[i]).unwrap());
    let eigvals: Vec<f64> = order.iter().map(|&i| lam[i]).collect();
    let eigvecs = Mat::from_fn(n, n, |i, j| v[(i, order[j])]);
    (eigvals, eigvecs)
}

/// Thin SVD `A = U·diag(s)·Vᵀ` with inner dimension `min(m, n)`.
///
/// One-sided Jacobi on the tall orientation: rotations orthogonalize the
/// columns; singular values are the resulting column norms. Zero (or
/// numerically tiny) singular directions get zero columns in `U`.
pub fn svd_thin(a: &Mat) -> (Mat, Vec<f64>, Mat) {
    let (m, n) = a.shape();
    if m < n {
        let (u, s, v) = svd_thin(&a.transpose());
        return (v, s, u);
    }
    let mut w = a.clone(); // m×n, columns get orthogonalized in place
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    let eps = 1e-30;
    for _ in 0..max_sweeps {
        let mut rotated = false;
        for p in 0..n {
            for q in (p + 1)..n {
                // Column inner products.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= eps + 1e-15 * (app * aqq).sqrt() {
                    continue;
                }
                rotated = true;
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                for i in 0..m {
                    let wp = w[(i, p)];
                    let wq = w[(i, q)];
                    w[(i, p)] = c * wp - s * wq;
                    w[(i, q)] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if !rotated {
            break;
        }
    }
    // Column norms → singular values; normalize U columns.
    let mut svals: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| w[(i, j)] * w[(i, j)]).sum::<f64>().sqrt())
        .collect();
    let smax = svals.iter().cloned().fold(0.0, f64::max);
    let cutoff = smax * RELATIVE_RANK_TOL;
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| svals[j].partial_cmp(&svals[i]).unwrap());
    let mut u = Mat::zeros(m, n);
    let mut vv = Mat::zeros(n, n);
    let mut s_sorted = vec![0.0; n];
    for (dst, &src) in order.iter().enumerate() {
        let s = svals[src];
        s_sorted[dst] = if s > cutoff { s } else { 0.0 };
        if s > cutoff {
            for i in 0..m {
                u[(i, dst)] = w[(i, src)] / s;
            }
        }
        for i in 0..n {
            vv[(i, dst)] = v[(i, src)];
        }
    }
    svals = s_sorted;
    (u, svals, vv)
}

/// Orthonormal polar factor `Q = B (BᵀB)^(−1/2)` — the Orthogonal
/// Procrustes solution (see module docs). For rank-deficient `B`, the
/// deficient directions contribute zero columns, which leaves the ALS
/// objective unchanged (their singular values are zero).
pub fn polar_orthonormal(b: &Mat) -> Mat {
    let g = blas::gram(b); // R×R
    let (lam, p) = sym_eig(&g);
    let lmax = lam.first().cloned().unwrap_or(0.0).max(0.0);
    // The Gram route squares the condition number: eigenvalues below
    // ~λmax·1e-9 (singular values below ~3e-5·σmax) are noise-dominated
    // and would yield badly non-orthonormal columns. Treat them as zero —
    // callers complete those directions orthonormally if they need exact
    // QᵀQ = I (see `linalg::qr::orthonormal_complete`).
    let cutoff = lmax * 1e-9;
    let r = g.rows();
    // M = P diag(λ^{-1/2}) Pᵀ on the numerically nonzero spectrum.
    let mut m = Mat::zeros(r, r);
    for t in 0..r {
        let l = lam[t];
        if l > cutoff && l > 0.0 {
            let inv_sqrt = 1.0 / l.sqrt();
            for i in 0..r {
                let pi = p[(i, t)] * inv_sqrt;
                if pi == 0.0 {
                    continue;
                }
                for j in 0..r {
                    m[(i, j)] += pi * p[(j, t)];
                }
            }
        }
    }
    blas::matmul(b, &m)
}

/// Orthogonal-Procrustes solution with **exact** orthonormal columns even
/// for rank-deficient targets (requires `rows ≥ cols`).
///
/// Where [`polar_orthonormal`] leaves the null-space directions at zero
/// (so `QᵀQ` is a projector, not `I`), this computes the thin left factors
/// `U_kept = B P diag(λ^{-1/2})` on the numerically nonzero spectrum,
/// completes them to a full orthonormal set with deterministic
/// Gram–Schmidt over standard basis vectors, and returns
/// `Q = [U_kept | U_comp] · Pᵀ` — exactly what the SVD formulation
/// `Q = Z Pᵀ` produces (up to the arbitrary completion), preserving the
/// PARAFAC2 invariant `QᵀQ = I` for degenerate slices.
pub fn polar_orthonormal_completed(b: &Mat) -> Mat {
    let (m, n) = b.shape();
    assert!(m >= n, "polar_orthonormal_completed requires rows ≥ cols");
    let g = blas::gram(b);
    let (lam, p) = sym_eig(&g);
    let lmax = lam.first().cloned().unwrap_or(0.0).max(0.0);
    let cutoff = lmax * 1e-9;
    let kept: Vec<usize> = (0..n).filter(|&t| lam[t] > cutoff && lam[t] > 0.0).collect();
    // U columns: kept directions from B, the rest completed.
    let mut u = Mat::zeros(m, n);
    for (uc, &t) in kept.iter().enumerate() {
        let inv_sqrt = 1.0 / lam[t].sqrt();
        for i in 0..m {
            let mut s = 0.0;
            let brow = b.row(i);
            for jj in 0..n {
                s += brow[jj] * p[(jj, t)];
            }
            u[(i, uc)] = s * inv_sqrt;
        }
    }
    if kept.len() < n {
        // mark the tail columns as deficient and complete them
        super::qr::orthonormal_complete(&mut u);
    }
    // Q = U · P_orderedᵀ where P_ordered = [P_kept | P_rest]
    let rest: Vec<usize> = (0..n).filter(|t| !kept.contains(t)).collect();
    let order: Vec<usize> = kept.iter().chain(rest.iter()).copied().collect();
    let mut q = Mat::zeros(m, n);
    for i in 0..m {
        for jj in 0..n {
            let mut s = 0.0;
            for (uc, &t) in order.iter().enumerate() {
                s += u[(i, uc)] * p[(jj, t)];
            }
            q[(i, jj)] = s;
        }
    }
    q
}

/// Reusable buffers for [`procrustes_polar_jacobi_into`]: the per-subject
/// polar factor is the deepest call of the ALS hot loop, so its
/// temporaries live in a per-worker scratch that grows to the cohort's
/// high-water shapes during the first iteration and never allocates again
/// (the steady-state-allocation-free contract of the Procrustes phase,
/// asserted by the `arena_memory` integration test).
#[derive(Debug)]
pub struct PolarScratch {
    /// `W = Bᵀ` (n × m), rotated in place.
    w: Mat,
    /// `Vᵀ` accumulator (n × n).
    vt: Mat,
    /// Normalized left factors (m × n; tall branch only).
    u: Mat,
    /// Cached squared column norms (length n).
    norm_sq: Vec<f64>,
    /// Final singular-value estimates (length n).
    norms: Vec<f64>,
}

impl Default for PolarScratch {
    fn default() -> Self {
        PolarScratch::new()
    }
}

impl PolarScratch {
    pub fn new() -> PolarScratch {
        PolarScratch {
            w: Mat::zeros(0, 0),
            vt: Mat::zeros(0, 0),
            u: Mat::zeros(0, 0),
            norm_sq: Vec::new(),
            norms: Vec::new(),
        }
    }

    /// Heap bytes currently held (scratch-arena accounting).
    pub fn heap_bytes(&self) -> u64 {
        self.w.heap_bytes()
            + self.vt.heap_bytes()
            + self.u.heap_bytes()
            + (self.norm_sq.capacity() * 8 + self.norms.capacity() * 8) as u64
    }
}

/// Orthogonal-Procrustes solution via **one-sided Jacobi on transposed
/// storage** — the fast path used by the per-subject step-1 kernel.
/// Allocating convenience wrapper over [`procrustes_polar_jacobi_into`]
/// (bitwise identical; the ALS hot loop holds a [`PolarScratch`] instead).
pub fn procrustes_polar_jacobi(b: &Mat) -> Mat {
    let mut scratch = PolarScratch::new();
    let mut q = Mat::zeros(0, 0);
    procrustes_polar_jacobi_into(b, &mut scratch, &mut q);
    q
}

/// Computes `Q = U·Vᵀ` from the thin SVD `B = U Σ Vᵀ` directly, without
/// forming the Gram matrix or an eigendecomposition: Jacobi rotations
/// orthogonalize the *columns* of `B`, held transposed (`W = Bᵀ`) so every
/// rotation touches two contiguous rows — the strided column access that
/// dominates the eig route (61% of iteration time in the §Perf profile)
/// disappears. `Vᵀ` accumulates in the same transposed layout.
///
/// Rank-deficient targets: zero singular values leave exactly-zero rows of
/// `W`, which (for tall B) are completed to an orthonormal set before the
/// final product, so `QᵀQ = I` holds exactly — same semantics as
/// [`polar_orthonormal_completed`]. Short matrices (rows < cols) keep the
/// zero directions and return orthonormal *rows*.
///
/// `q` receives the `rows(b) × cols(b)` result; every temporary lives in
/// `scratch`. The floating-point sequence is identical to the historical
/// allocating form for every input — scratch reuse is invisible to the
/// bits (buffers are fully overwritten before use).
pub fn procrustes_polar_jacobi_into(b: &Mat, scratch: &mut PolarScratch, q: &mut Mat) {
    let (m, n) = b.shape();
    b.transpose_into(&mut scratch.w); // n rows of length m — B's columns
    let w = &mut scratch.w;
    scratch.vt.reset_to_eye(n); // Vᵀ, rotated in the same row layout
    let vt = &mut scratch.vt;
    let max_sweeps = 64;
    // convergence/skip threshold: |⟨b_p, b_q⟩| ≤ tol·‖b_p‖‖b_q‖.
    // 1e-8 leaves an orthonormality defect ≤ ~1e-8 — far below anything
    // the ALS objective can see — and saves 1–2 full sweeps vs 1e-14
    // (§Perf step 4; quadratic convergence makes the last sweeps pure
    // verification).
    let tol = 1e-8;
    // Cached squared column norms, updated analytically after each
    // rotation (app' = app − t·apq, aqq' = aqq + t·apq) — only the cross
    // product ⟨w_p, w_q⟩ needs a fresh dot per pair (§Perf step 3).
    scratch.norm_sq.clear();
    scratch.norm_sq.extend((0..n).map(|j| w.row(j).iter().map(|x| x * x).sum::<f64>()));
    let norm_sq = &mut scratch.norm_sq;
    for _ in 0..max_sweeps {
        let mut rotated = false;
        for p in 0..n {
            for q in (p + 1)..n {
                let app = norm_sq[p];
                let aqq = norm_sq[q];
                let apq = blas::dot(w.row(p), w.row(q));
                if apq.abs() <= tol * (app * aqq).sqrt() + f64::MIN_POSITIVE {
                    continue;
                }
                rotated = true;
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                norm_sq[p] = app - t * apq;
                norm_sq[q] = aqq + t * apq;
                {
                    let (wp, wq) = w.two_rows_mut(p, q);
                    for (x, y) in wp.iter_mut().zip(wq.iter_mut()) {
                        let a = *x;
                        let b = *y;
                        *x = c * a - s * b;
                        *y = s * a + c * b;
                    }
                }
                {
                    let (vp, vq) = vt.two_rows_mut(p, q);
                    for (x, y) in vp.iter_mut().zip(vq.iter_mut()) {
                        let a = *x;
                        let b = *y;
                        *x = c * a - s * b;
                        *y = s * a + c * b;
                    }
                }
            }
        }
        if !rotated {
            break;
        }
    }
    // Normalize the components: row j of W is σ_j·u_jᵀ. (Norms recomputed
    // exactly — the cached values drift by rounding over many rotations.)
    scratch.norms.clear();
    scratch.norms.resize(n, 0.0);
    let norms = &mut scratch.norms;
    for j in 0..n {
        norms[j] = w.row(j).iter().map(|x| x * x).sum::<f64>().sqrt();
    }
    let smax = norms.iter().cloned().fold(0.0, f64::max);
    let cutoff = smax * 3e-5; // matches the eig route's λmax·1e-9
    for j in 0..n {
        if norms[j] > cutoff {
            let inv = 1.0 / norms[j];
            for x in w.row_mut(j) {
                *x *= inv;
            }
        } else {
            w.row_mut(j).fill(0.0);
        }
    }
    if m >= n {
        // complete zero components (deficiency is axis-aligned here)
        w.transpose_into(&mut scratch.u); // m×n, orthonormal-or-zero columns
        super::qr::orthonormal_complete(&mut scratch.u);
        // Q = U·Vᵀ (matmul = zero-init + gemm, reproduced on the reused q)
        q.reset_to_zeros(m, n);
        blas::gemm_acc(q, &scratch.u, vt, 1.0);
    } else {
        // short case: Q = Uᵀ-transposed product, orthonormal rows
        q.reset_to_zeros(m, n);
        super::kernels::atb_into(w, vt, q);
    }
}

/// Moore-Penrose pseudo-inverse of a symmetric PSD matrix (the Gram
/// products appearing in CP-ALS normal equations).
pub fn pinv_psd(a: &Mat) -> Mat {
    let (lam, p) = sym_eig(a);
    let lmax = lam.first().cloned().unwrap_or(0.0).max(0.0);
    let cutoff = lmax * 1e-13;
    let n = a.rows();
    let mut out = Mat::zeros(n, n);
    for t in 0..n {
        let l = lam[t];
        if l > cutoff && l > 0.0 {
            let inv = 1.0 / l;
            for i in 0..n {
                let pi = p[(i, t)] * inv;
                if pi == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[(i, j)] += pi * p[(j, t)];
                }
            }
        }
    }
    out
}

/// General pseudo-inverse via thin SVD (any shape).
pub fn pinv(a: &Mat) -> Mat {
    let (u, s, v) = svd_thin(a);
    let smax = s.iter().cloned().fold(0.0, f64::max);
    let cutoff = smax * 1e-13;
    // A⁺ = V diag(1/s) Uᵀ
    let r = s.len();
    let mut vs = Mat::zeros(v.rows(), r);
    for j in 0..r {
        if s[j] > cutoff {
            let inv = 1.0 / s[j];
            for i in 0..v.rows() {
                vs[(i, j)] = v[(i, j)] * inv;
            }
        }
    }
    blas::matmul_a_bt(&vs, &u) // V diag(1/s) · Uᵀ
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthonormality_defect;
    use crate::util::rng::Pcg64;

    fn reconstruct_svd(u: &Mat, s: &[f64], v: &Mat) -> Mat {
        let mut us = u.clone();
        for i in 0..us.rows() {
            for (j, x) in us.row_mut(i).iter_mut().enumerate() {
                *x *= s[j];
            }
        }
        blas::matmul_a_bt(&us, v)
    }

    #[test]
    fn sym_eig_reconstructs() {
        let mut rng = Pcg64::seed(31);
        for n in [1, 2, 5, 17, 40] {
            let g0 = Mat::rand_normal(n + 3, n, &mut rng);
            let a = blas::gram(&g0);
            let (lam, v) = sym_eig(&a);
            // V diag(lam) Vᵀ == A
            let mut vl = v.clone();
            for i in 0..n {
                for (j, x) in vl.row_mut(i).iter_mut().enumerate() {
                    *x *= lam[j];
                }
            }
            let rec = blas::matmul_a_bt(&vl, &v);
            assert!(rec.max_abs_diff(&a) < 1e-8 * (1.0 + a.fro_norm()), "n={n}");
            assert!(orthonormality_defect(&v) < 1e-10);
            // sorted descending
            for w in lam.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }

    #[test]
    fn sym_eig_known_values() {
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let (lam, _) = sym_eig(&a);
        assert!((lam[0] - 3.0).abs() < 1e-12);
        assert!((lam[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn svd_reconstructs_tall_wide_square() {
        let mut rng = Pcg64::seed(32);
        for (m, n) in [(8, 3), (3, 8), (5, 5), (40, 10), (1, 4)] {
            let a = Mat::rand_normal(m, n, &mut rng);
            let (u, s, v) = svd_thin(&a);
            let rec = reconstruct_svd(&u, &s, &v);
            assert!(rec.max_abs_diff(&a) < 1e-9, "({m},{n})");
            for w in s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
            assert!(s.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn svd_rank_deficient() {
        // rank-1 matrix 4x3
        let mut rng = Pcg64::seed(33);
        let x = Mat::rand_normal(4, 1, &mut rng);
        let y = Mat::rand_normal(3, 1, &mut rng);
        let a = blas::matmul_a_bt(&x, &y);
        let (u, s, v) = svd_thin(&a);
        assert!(s[0] > 1e-8);
        assert!(s[1].abs() < 1e-10 && s[2].abs() < 1e-10);
        let rec = reconstruct_svd(&u, &s, &v);
        assert!(rec.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn polar_is_procrustes_optimum() {
        // For B with full column rank, Q = polar(B) maximizes trace(QᵀB)
        // over orthonormal Q; check Q beats random orthonormal candidates.
        let mut rng = Pcg64::seed(34);
        let b = Mat::rand_normal(30, 6, &mut rng);
        let q = polar_orthonormal(&b);
        assert!(orthonormality_defect(&q) < 1e-9);
        let trace = |q: &Mat| -> f64 {
            let qtb = blas::matmul_at_b(q, &b);
            (0..6).map(|i| qtb[(i, i)]).sum()
        };
        let t_opt = trace(&q);
        for _ in 0..20 {
            let cand = crate::linalg::qr::random_orthonormal(30, 6, &mut rng);
            assert!(trace(&cand) <= t_opt + 1e-9);
        }
    }

    #[test]
    fn polar_matches_svd_route() {
        let mut rng = Pcg64::seed(35);
        let b = Mat::rand_normal(25, 5, &mut rng);
        let q1 = polar_orthonormal(&b);
        let (u, _s, v) = svd_thin(&b);
        let q2 = blas::matmul_a_bt(&u, &v); // U Vᵀ
        assert!(q1.max_abs_diff(&q2) < 1e-8);
    }

    #[test]
    fn polar_short_fat_has_orthonormal_rows() {
        // I_k < R case: B is 3×5; Q should satisfy Q Qᵀ = I (rows).
        let mut rng = Pcg64::seed(36);
        let b = Mat::rand_normal(3, 5, &mut rng);
        let q = polar_orthonormal(&b);
        let qqt = blas::matmul_a_bt(&q, &q);
        assert!(qqt.max_abs_diff(&Mat::eye(3)) < 1e-8);
    }

    #[test]
    fn polar_completed_matches_polar_on_full_rank() {
        let mut rng = Pcg64::seed(39);
        let b = Mat::rand_normal(20, 5, &mut rng);
        let q1 = polar_orthonormal(&b);
        let q2 = polar_orthonormal_completed(&b);
        assert!(q1.max_abs_diff(&q2) < 1e-7);
    }

    #[test]
    fn polar_completed_orthonormal_on_rank_deficient() {
        let mut rng = Pcg64::seed(40);
        // rank-2 target in R^5 columns
        let x = Mat::rand_normal(15, 2, &mut rng);
        let y = Mat::rand_normal(5, 2, &mut rng);
        let b = blas::matmul_a_bt(&x, &y);
        let q = polar_orthonormal_completed(&b);
        assert!(
            crate::linalg::qr::orthonormality_defect(&q) < 1e-8,
            "defect {}",
            crate::linalg::qr::orthonormality_defect(&q)
        );
        // still optimal on the live directions: trace(QᵀB) equals the
        // nuclear norm of B (sum of singular values)
        let qtb = blas::matmul_at_b(&q, &b);
        let trace: f64 = (0..5).map(|i| qtb[(i, i)]).sum();
        let (_u, s, _v) = svd_thin(&b);
        let nuclear: f64 = s.iter().sum();
        assert!((trace - nuclear).abs() < 1e-6 * (1.0 + nuclear));
    }

    #[test]
    fn jacobi_polar_matches_eig_route() {
        let mut rng = Pcg64::seed(44);
        for (m, n) in [(20usize, 5usize), (7, 7), (64, 16), (3, 6)] {
            let b = Mat::rand_normal(m, n, &mut rng);
            let q1 = procrustes_polar_jacobi(&b);
            let q2 = if m >= n { polar_orthonormal_completed(&b) } else { polar_orthonormal(&b) };
            assert!(q1.max_abs_diff(&q2) < 1e-7, "({m},{n}): {}", q1.max_abs_diff(&q2));
        }
    }

    #[test]
    fn jacobi_polar_scratch_reuse_is_bitwise() {
        // The ALS hot loop reuses one PolarScratch across subjects whose
        // shapes vary (grow, shrink, short-fat, rank-deficient): every
        // call must be bit-identical to a fresh allocating call — scratch
        // residue can never leak into the result.
        let mut rng = Pcg64::seed(47);
        let mut scratch = PolarScratch::new();
        let mut q = Mat::zeros(0, 0);
        let rank2 = {
            let x = Mat::rand_normal(12, 2, &mut rng);
            let y = Mat::rand_normal(5, 2, &mut rng);
            blas::matmul_a_bt(&x, &y)
        };
        let shapes: Vec<Mat> = vec![
            Mat::rand_normal(20, 5, &mut rng),
            Mat::rand_normal(6, 3, &mut rng), // shrink
            Mat::rand_normal(64, 16, &mut rng), // grow
            Mat::rand_normal(3, 8, &mut rng), // short-fat branch
            rank2,                            // deficiency → completion path
            Mat::rand_normal(7, 7, &mut rng),
        ];
        for (i, b) in shapes.iter().enumerate() {
            procrustes_polar_jacobi_into(b, &mut scratch, &mut q);
            let fresh = procrustes_polar_jacobi(b);
            assert_eq!(q.shape(), fresh.shape(), "case {i}");
            for (a, bq) in q.data().iter().zip(fresh.data()) {
                assert_eq!(a.to_bits(), bq.to_bits(), "case {i}");
            }
        }
    }

    #[test]
    fn jacobi_polar_rank_deficient_orthonormal() {
        let mut rng = Pcg64::seed(45);
        let x = Mat::rand_normal(15, 2, &mut rng);
        let y = Mat::rand_normal(6, 2, &mut rng);
        let b = blas::matmul_a_bt(&x, &y); // rank 2, 15×6
        let q = procrustes_polar_jacobi(&b);
        assert!(crate::linalg::qr::orthonormality_defect(&q) < 1e-8);
        // optimality: trace(QᵀB) = nuclear norm
        let qtb = blas::matmul_at_b(&q, &b);
        let trace: f64 = (0..6).map(|i| qtb[(i, i)]).sum();
        let (_u, s, _v) = svd_thin(&b);
        let nuclear: f64 = s.iter().sum();
        assert!((trace - nuclear).abs() < 1e-6 * (1.0 + nuclear));
    }

    #[test]
    fn jacobi_polar_short_fat_orthonormal_rows() {
        let mut rng = Pcg64::seed(46);
        let b = Mat::rand_normal(3, 8, &mut rng);
        let q = procrustes_polar_jacobi(&b);
        let qqt = blas::matmul_a_bt(&q, &q);
        assert!(qqt.max_abs_diff(&Mat::eye(3)) < 1e-8);
    }

    #[test]
    fn polar_completed_zero_matrix_still_orthonormal() {
        let b = Mat::zeros(6, 3);
        let q = polar_orthonormal_completed(&b);
        assert!(crate::linalg::qr::orthonormality_defect(&q) < 1e-10);
    }

    #[test]
    fn pinv_psd_properties() {
        let mut rng = Pcg64::seed(37);
        let g0 = Mat::rand_normal(12, 6, &mut rng);
        let a = blas::gram(&g0); // SPD w.h.p.
        let ap = pinv_psd(&a);
        let aa = blas::matmul(&a, &ap);
        assert!(aa.max_abs_diff(&Mat::eye(6)) < 1e-7);
    }

    #[test]
    fn pinv_general_minimum_norm() {
        let mut rng = Pcg64::seed(38);
        let a = Mat::rand_normal(4, 7, &mut rng); // wide
        let ap = pinv(&a);
        // A A⁺ A == A
        let rec = blas::matmul(&blas::matmul(&a, &ap), &a);
        assert!(rec.max_abs_diff(&a) < 1e-8);
    }

    #[test]
    fn svd_zero_matrix() {
        let a = Mat::zeros(4, 3);
        let (u, s, _v) = svd_thin(&a);
        assert!(s.iter().all(|&x| x == 0.0));
        assert!(u.fro_norm() == 0.0);
    }
}
