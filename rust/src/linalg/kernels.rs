//! Register-blocked micro-kernels for the ALS hot loops, behind one
//! dispatch point with runtime-selected ISA backends.
//!
//! Profiling after the PR 1–2 fusions leaves the iteration time inside two
//! rank-1-update loop shapes, and this module owns both:
//!
//! * **Shape A — sparse-support rows × dense panel.** `Y_k·V` restricted
//!   to the column support ([`spmm_yt_v`], powering
//!   `PackedSlice::yk_times_v{,_fused}` and therefore the pack-fused
//!   Procrustes→mode-1 sweep), and the CSR-row AXPY `X_k·V`
//!   ([`sparse_row_axpy`], powering `Csr::matmul_dense` inside the
//!   Procrustes target).
//! * **Shape B — dense-transpose × dense panel.** The per-row
//!   `Z_k(c,:) = Y_k(:,j_c)ᵀ H` kernel ([`zt_row`], the mode-2/mode-3
//!   sweeps), and the panel forms `AᵀB` ([`atb_into`], `blas::matmul_at_b`)
//!   and `AᵀA` ([`gram_into`], `blas::gram`) that the normal equations and
//!   Procrustes hit every iteration.
//!
//! ## Backends and selection
//!
//! Every public kernel dispatches through [`active_backend`], a
//! process-wide choice resolved once and cached in an atomic:
//!
//! 1. an explicit [`set_backend`] call wins (the CLI's `--kernel` flag on
//!    `decompose`/`serve`/`shard-worker`/bench binaries routes here);
//! 2. else the `SPARTAN_KERNEL` env var (`scalar`, `blocked`, `avx2`,
//!    `avx512`, `neon`) is honored — an unknown name or a backend the
//!    host cannot run aborts loudly rather than silently falling back;
//! 3. else auto-detection picks the widest **bitwise** backend the host
//!    supports: `avx2` on x86-64 with AVX2, `neon` on AArch64, `blocked`
//!    otherwise. Reordered backends are *never* auto-selected.
//!
//! | backend   | family    | how                                              | auto? |
//! |-----------|-----------|--------------------------------------------------|-------|
//! | `scalar`  | bitwise   | the [`reference`] loops (the contract itself)    | no    |
//! | `blocked` | bitwise   | portable 4-wide register blocking ([`blocked`])  | fallback |
//! | `avx2`    | bitwise   | 256-bit lanes, **unfused** mul then add          | yes   |
//! | `neon`    | bitwise   | 128-bit lanes, **unfused** mul then add          | yes   |
//! | `avx512`  | reordered | 512-bit lanes with 8-wide **FMA**                | never |
//!
//! ## Blocking schedule
//!
//! Every backend blocks the **accumulation axis** by [`ACC_BLOCK`] = 4:
//! four coefficient/row pairs are held in registers and applied to the
//! destination row in one pass, quartering the destination's load/store
//! round-trips. The `blocked` per-slice kernels additionally monomorphize
//! the panel width for `R ≤` [`R_UNROLL_MAX`]; the SIMD backends instead
//! vectorize the **panel-width axis j** — output elements are independent,
//! so each lane owns one output element and replays the scalar chain for
//! it. The schedule is **fixed and data-only**: which variant runs depends
//! only on the selected backend and operand shapes, never on values,
//! worker counts, or timing.
//!
//! ## Determinism contract
//!
//! Two lane families, asserted by `rust/tests/kernel_conformance.rs`:
//!
//! * **Order-preserving (bitwise): `scalar`, `blocked`, `avx2`, `neon`.**
//!   All five kernels produce results **bitwise identical** to the scalar
//!   references in [`reference`] for *every* input (zeros, denormals, NaN
//!   propagation included). The trick is that vector lanes sit on the
//!   panel-width axis, where elements are independent: lane `j` computes
//!   `o_j + y₀·v₀[j] + y₁·v₁[j] + y₂·v₂[j] + y₃·v₃[j]` with separate
//!   multiply and add instructions (Rust/LLVM never contracts FP by
//!   default), which is the *identical* rounding sequence the scalar
//!   reference applies to that element; exact-zero skips keep the same
//!   branch structure (all-nonzero fast path vs per-coefficient skip), so
//!   a zero coefficient never turns a skipped `0·NaN` into a NaN. Forcing
//!   any backend in this family can never move a trajectory by one ulp —
//!   the golden-trajectory fixture passes un-re-blessed under all of them.
//! * **Reordered (ULP-bounded): `avx512`, [`dot`].** The `avx512` backend
//!   uses 8-wide `fmadd` (one rounding per multiply-add instead of two),
//!   and [`dot`] keeps 4 independent accumulators; both are *not* bitwise
//!   against the references. Conformance pins them to a forward-error
//!   envelope (`≲ n·ε·Σ|yᵢ·vᵢ[j]|` plus a subnormal absolute slack) and to
//!   identical NaN placement / zero-skip semantics. `avx512` is opt-in
//!   only (`--kernel avx512` / `SPARTAN_KERNEL=avx512`): it is never
//!   auto-selected, and shard topologies mixing it with another backend
//!   are rejected at the `hello` handshake (`service::shard`).
//!
//! The selected backend is recorded in `FitStats::kernel_backend`, the
//! bench JSON `backend` field, and the shard `hello` handshake, so a
//! trajectory can always be traced back to the lane family that made it.
//!
//! ## Adding a kernel shape
//!
//! 1. Write the scalar loop in [`reference`] first — its floating-point
//!    order *is* the contract.
//! 2. Add the blocked form with the same per-element term order (or
//!    document it in the reordered family), extend each backend module
//!    (they share the kernel skeletons; only `accum4`/`accum1` differ),
//!    and dispatch through a single `pub fn` + `*_with` pair.
//! 3. Extend `kernel_conformance.rs` with the new shape's differential
//!    sweep (R sweep, ragged/empty operands, zero / denormal / NaN
//!    regimes) across `KernelBackend::detected()`, `prop_invariants.rs`
//!    if the kernel feeds a pooled reduction, and per-backend A/B cells
//!    in `benches/micro_linalg.rs`.
//!
//! ## Adding a backend
//!
//! 1. Add the [`KernelBackend`] variant, its `name`/`parse` strings, and
//!    its `is_supported` detection arm (`is_x86_feature_detected!` /
//!    `is_aarch64_feature_detected!` — never compile-time only).
//! 2. Implement the five kernels in a new `cfg(target_arch)` module: keep
//!    the *exact* skeletons (block-of-4 loop, all-nonzero fast path,
//!    per-coefficient skip path, ragged tails) and supply `accum4`/
//!    `accum1`. Unfused mul+add on the j axis ⇒ bitwise family; anything
//!    that fuses or re-associates ⇒ reordered family, opt-in only.
//! 3. Wire the `*_with` dispatch arms, declare the family in
//!    `is_bitwise`, and extend the conformance sweep + `micro_linalg`
//!    cells. Auto-selection (`KernelBackend::auto`) may only ever pick
//!    bitwise backends.
//!
//! Callers (`parafac2::intermediate`, `parafac2::mttkrp`, `sparse::csr`,
//! `linalg::blas`) go through the dispatch functions and never select
//! variants themselves.

use super::dense::Mat;
use std::sync::atomic::{AtomicU8, Ordering};

/// Register block over the accumulation axis: 4 coefficient/row pairs in
/// flight per destination-row pass.
pub const ACC_BLOCK: usize = 4;

/// Panel widths `1..=R_UNROLL_MAX` get a monomorphized (fully unrolled)
/// inner loop in the `blocked` per-slice kernels; wider panels take the
/// same blocked body with a runtime width.
pub const R_UNROLL_MAX: usize = 16;

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// A kernel backend: one implementation of the five hot-shape kernels.
///
/// `Scalar`/`Blocked`/`Avx2`/`Neon` form the **bitwise** lane family
/// (interchangeable without moving any trajectory by a single bit);
/// `Avx512` is the **reordered** family (ULP-bounded, opt-in only). Named
/// `KernelBackend` because `parafac2::Backend` already names the engine
/// choice (SPARTan vs baseline).
#[repr(u8)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelBackend {
    /// The [`reference`] loops themselves — the contract, and the slow
    /// baseline for the A/B bench cells.
    Scalar = 0,
    /// Portable 4-wide register blocking with width monomorphization.
    Blocked = 1,
    /// x86-64 AVX2: 4 × f64 lanes on the panel axis, unfused mul+add.
    Avx2 = 2,
    /// x86-64 AVX-512F: 8 × f64 lanes with fused multiply-add. Reordered
    /// family — opt-in only, never auto-selected.
    Avx512 = 3,
    /// AArch64 NEON: 2 × f64 lanes on the panel axis, unfused mul+add.
    Neon = 4,
}

/// Sentinel for "not yet resolved" in [`ACTIVE_BACKEND`].
const BACKEND_UNSET: u8 = u8::MAX;

/// The process-wide backend choice. Relaxed ordering suffices: the value
/// is write-once-then-read (plus benign same-value races during lazy
/// init), and every backend in play computes from the same inputs.
static ACTIVE_BACKEND: AtomicU8 = AtomicU8::new(BACKEND_UNSET);

impl KernelBackend {
    /// Every backend, in discriminant order.
    pub const ALL: [KernelBackend; 5] = [
        KernelBackend::Scalar,
        KernelBackend::Blocked,
        KernelBackend::Avx2,
        KernelBackend::Avx512,
        KernelBackend::Neon,
    ];

    /// Stable lowercase name — the `SPARTAN_KERNEL`/`--kernel` spelling,
    /// and the string recorded in `FitStats`, bench JSON, and the shard
    /// `hello` handshake.
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Blocked => "blocked",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Avx512 => "avx512",
            KernelBackend::Neon => "neon",
        }
    }

    /// Inverse of [`name`](Self::name).
    pub fn parse(s: &str) -> Result<KernelBackend, String> {
        match s {
            "scalar" => Ok(KernelBackend::Scalar),
            "blocked" => Ok(KernelBackend::Blocked),
            "avx2" => Ok(KernelBackend::Avx2),
            "avx512" => Ok(KernelBackend::Avx512),
            "neon" => Ok(KernelBackend::Neon),
            other => Err(format!(
                "unknown kernel backend '{other}' (expected one of scalar, blocked, avx2, avx512, neon)"
            )),
        }
    }

    /// Whether this backend is in the order-preserving (bitwise) lane
    /// family. Only bitwise backends may ever be auto-selected.
    pub fn is_bitwise(self) -> bool {
        !matches!(self, KernelBackend::Avx512)
    }

    /// Whether the running host can execute this backend (compile-target
    /// architecture *and* runtime CPUID/feature detection).
    pub fn is_supported(self) -> bool {
        match self {
            KernelBackend::Scalar | KernelBackend::Blocked => true,
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx512 => is_x86_feature_detected!("avx512f"),
            #[cfg(target_arch = "aarch64")]
            KernelBackend::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[cfg(not(target_arch = "x86_64"))]
            KernelBackend::Avx2 | KernelBackend::Avx512 => false,
            #[cfg(not(target_arch = "aarch64"))]
            KernelBackend::Neon => false,
        }
    }

    /// Every backend the running host supports, in [`Self::ALL`] order —
    /// the sweep set for conformance tests and per-ISA bench cells.
    pub fn detected() -> Vec<KernelBackend> {
        Self::ALL.iter().copied().filter(|b| b.is_supported()).collect()
    }

    /// The auto-selection policy: the widest supported **bitwise**
    /// backend. Reordered backends are never returned here.
    pub fn auto() -> KernelBackend {
        if KernelBackend::Avx2.is_supported() {
            return KernelBackend::Avx2;
        }
        if KernelBackend::Neon.is_supported() {
            return KernelBackend::Neon;
        }
        KernelBackend::Blocked
    }

    fn from_u8(v: u8) -> KernelBackend {
        Self::ALL[v as usize]
    }
}

/// The backend the dispatch functions route to, resolving it on first use
/// (see the module docs for the precedence: `set_backend` > env > auto).
///
/// # Panics
///
/// On first use, if `SPARTAN_KERNEL` names an unknown backend or one the
/// host cannot run — a misconfigured override must fail loudly, not
/// silently fall back to a different lane family.
pub fn active_backend() -> KernelBackend {
    match ACTIVE_BACKEND.load(Ordering::Relaxed) {
        BACKEND_UNSET => init_backend(),
        b => KernelBackend::from_u8(b),
    }
}

#[cold]
fn init_backend() -> KernelBackend {
    let b = match std::env::var("SPARTAN_KERNEL") {
        Ok(s) if !s.is_empty() => {
            let b = KernelBackend::parse(&s).unwrap_or_else(|e| panic!("SPARTAN_KERNEL: {e}"));
            assert!(
                b.is_supported(),
                "SPARTAN_KERNEL={s}: backend not supported on this host (detected: {})",
                detected_names()
            );
            b
        }
        _ => KernelBackend::auto(),
    };
    ACTIVE_BACKEND.store(b as u8, Ordering::Relaxed);
    b
}

/// Force the process-wide backend (the `--kernel` CLI flag). Errors if
/// the host cannot run it; callers surface the message instead of
/// panicking. Overrides `SPARTAN_KERNEL` when called before first kernel
/// use (the CLI parses flags before any fit work starts).
pub fn set_backend(b: KernelBackend) -> Result<(), String> {
    if !b.is_supported() {
        return Err(format!(
            "kernel backend '{}' is not supported on this host (detected: {})",
            b.name(),
            detected_names()
        ));
    }
    ACTIVE_BACKEND.store(b as u8, Ordering::Relaxed);
    Ok(())
}

fn detected_names() -> String {
    KernelBackend::detected()
        .iter()
        .map(|b| b.name())
        .collect::<Vec<_>>()
        .join(", ")
}

/// Scalar reference kernels. Their loop order defines the floating-point
/// sequence the order-preserving backends must reproduce bit for bit;
/// they also serve as the slow-but-obvious implementations the
/// conformance harness and the `micro_linalg` A/B cells diff against.
/// Selecting `KernelBackend::Scalar` runs these directly.
pub mod reference {
    use super::Mat;

    /// Shape A reference: `out += Σ_c yt(c,:)ᵀ ⊗ v(support[c],:)` — the
    /// pre-blocking `yk_times_v` loop (exact-zero coefficients skipped).
    pub fn spmm_yt_v(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
        for (c, &j) in support.iter().enumerate() {
            let yrow = yt.row(c);
            let vrow = v.row(j as usize);
            for (i, &yv) in yrow.iter().enumerate() {
                if yv == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for (o, &vv) in orow.iter_mut().zip(vrow) {
                    *o += yv * vv;
                }
            }
        }
    }

    /// Shape A reference: `dst += Σ_p vals[p] · dense(cols[p],:)` — one
    /// CSR row times a dense panel (no zero skip: stored zeros are rare
    /// and the historical loop applied them).
    pub fn sparse_row_axpy(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64]) {
        for (&x, &c) in vals.iter().zip(cols) {
            let drow = dense.row(c as usize);
            for (o, &d) in dst.iter_mut().zip(drow) {
                *o += x * d;
            }
        }
    }

    /// Shape B reference: `out = yrowᵀ · H` (overwrites `out`; exact-zero
    /// coefficients skipped) — the pre-blocking `yt_row_times_h`.
    pub fn zt_row(yrow: &[f64], h: &Mat, out: &mut [f64]) {
        out.fill(0.0);
        for (i, &yv) in yrow.iter().enumerate() {
            if yv == 0.0 {
                continue;
            }
            let hrow = h.row(i);
            for (o, &hv) in out.iter_mut().zip(hrow) {
                *o += yv * hv;
            }
        }
    }

    /// Shape B reference: `c += AᵀB` by outer products over rows of `A`
    /// (exact-zero coefficients skipped) — the pre-blocking
    /// `matmul_at_b`.
    pub fn atb(a: &Mat, b: &Mat, c: &mut Mat) {
        let ka = a.rows();
        for k in 0..ka {
            let arow = a.row(k);
            let brow = b.row(k);
            for (i, &aki) in arow.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let crow = c.row_mut(i);
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aki * bv;
                }
            }
        }
    }

    /// Shape B reference: `g += AᵀA` upper triangle, then mirror (exact
    /// zeros skipped) — the pre-blocking `gram`.
    pub fn gram(a: &Mat, g: &mut Mat) {
        let (k, n) = a.shape();
        for r in 0..k {
            let row = a.row(r);
            for i in 0..n {
                let ai = row[i];
                if ai == 0.0 {
                    continue;
                }
                let grow = g.row_mut(i);
                for j in i..n {
                    grow[j] += ai * row[j];
                }
            }
        }
        super::mirror_upper(g);
    }

    /// Strictly sequential dot product — the order baseline for the
    /// reordered [`super::dot`].
    pub fn dot_seq(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let mut s = 0.0;
        for (&a, &b) in x.iter().zip(y) {
            s += a * b;
        }
        s
    }
}

/// Copy the upper triangle of a square matrix onto the lower one.
fn mirror_upper(g: &mut Mat) {
    let n = g.rows();
    for i in 0..n {
        for j in 0..i {
            g[(i, j)] = g[(j, i)];
        }
    }
}

/// Shape-only width dispatch: monomorphize the inner loop for
/// `1..=R_UNROLL_MAX`, fall through to the runtime-width body otherwise.
/// (Selection depends on shapes alone; every arm computes bitwise the
/// same result, so dispatch can never perturb determinism.)
macro_rules! dispatch_width {
    ($w:expr, $mono:ident, $body:ident, ($($a:expr),+)) => {
        match $w {
            1 => $mono::<1>($($a),+),
            2 => $mono::<2>($($a),+),
            3 => $mono::<3>($($a),+),
            4 => $mono::<4>($($a),+),
            5 => $mono::<5>($($a),+),
            6 => $mono::<6>($($a),+),
            7 => $mono::<7>($($a),+),
            8 => $mono::<8>($($a),+),
            9 => $mono::<9>($($a),+),
            10 => $mono::<10>($($a),+),
            11 => $mono::<11>($($a),+),
            12 => $mono::<12>($($a),+),
            13 => $mono::<13>($($a),+),
            14 => $mono::<14>($($a),+),
            15 => $mono::<15>($($a),+),
            16 => $mono::<16>($($a),+),
            w => $body($($a),+, w),
        }
    };
}

/// Portable register-blocked kernels (the pre-SIMD fast path, and the
/// fallback backend on hosts without AVX2/NEON). Bitwise identical to
/// [`reference`] for every input: the 4-wide block applies its terms
/// left-to-right in scalar accumulation order, and exact-zero skips are
/// preserved term-by-term.
pub mod blocked {
    use super::{Mat, ACC_BLOCK};

    /// Shape A: `out += Y_k · V_c` with `Y_k` held as its packed
    /// transpose `yt` (`c_k × R`). Bitwise vs [`super::reference::spmm_yt_v`].
    pub fn spmm_yt_v(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
        dispatch_width!(v.cols(), spmm_mono, spmm_body, (yt, support, v, out));
    }

    #[inline(always)]
    fn spmm_mono<const W: usize>(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
        spmm_body(yt, support, v, out, W);
    }

    #[inline(always)]
    fn spmm_body(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat, w: usize) {
        let r = yt.cols();
        let n = support.len();
        let mut c = 0usize;
        while c + ACC_BLOCK <= n {
            let v0 = &v.row(support[c] as usize)[..w];
            let v1 = &v.row(support[c + 1] as usize)[..w];
            let v2 = &v.row(support[c + 2] as usize)[..w];
            let v3 = &v.row(support[c + 3] as usize)[..w];
            for i in 0..r {
                let y0 = yt[(c, i)];
                let y1 = yt[(c + 1, i)];
                let y2 = yt[(c + 2, i)];
                let y3 = yt[(c + 3, i)];
                let orow = &mut out.row_mut(i)[..w];
                if y0 != 0.0 && y1 != 0.0 && y2 != 0.0 && y3 != 0.0 {
                    // Left-to-right: the identical per-element term order
                    // the scalar reference produces with four sequential
                    // `+=`.
                    for (j, o) in orow.iter_mut().enumerate() {
                        *o = *o + y0 * v0[j] + y1 * v1[j] + y2 * v2[j] + y3 * v3[j];
                    }
                } else {
                    // Preserve the reference's exact-zero skip term-by-term.
                    for (y, vr) in [(y0, v0), (y1, v1), (y2, v2), (y3, v3)] {
                        if y == 0.0 {
                            continue;
                        }
                        for (o, &vv) in orow.iter_mut().zip(vr) {
                            *o += y * vv;
                        }
                    }
                }
            }
            c += ACC_BLOCK;
        }
        // Ragged tail in reference order.
        for cc in c..n {
            let vrow = &v.row(support[cc] as usize)[..w];
            let yrow = yt.row(cc);
            for (i, &yv) in yrow.iter().enumerate() {
                if yv == 0.0 {
                    continue;
                }
                let orow = &mut out.row_mut(i)[..w];
                for (o, &vv) in orow.iter_mut().zip(vrow) {
                    *o += yv * vv;
                }
            }
        }
    }

    /// Shape A: `dst += Σ_p vals[p] · dense(cols[p],:)`. Bitwise vs
    /// [`super::reference::sparse_row_axpy`].
    pub fn sparse_row_axpy(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64]) {
        dispatch_width!(dense.cols(), sparse_row_mono, sparse_row_body, (vals, cols, dense, dst));
    }

    #[inline(always)]
    fn sparse_row_mono<const W: usize>(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64]) {
        sparse_row_body(vals, cols, dense, dst, W);
    }

    #[inline(always)]
    fn sparse_row_body(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64], w: usize) {
        let dst = &mut dst[..w];
        let n = vals.len();
        let mut p = 0usize;
        while p + ACC_BLOCK <= n {
            let (x0, x1, x2, x3) = (vals[p], vals[p + 1], vals[p + 2], vals[p + 3]);
            let d0 = &dense.row(cols[p] as usize)[..w];
            let d1 = &dense.row(cols[p + 1] as usize)[..w];
            let d2 = &dense.row(cols[p + 2] as usize)[..w];
            let d3 = &dense.row(cols[p + 3] as usize)[..w];
            // No zero skip here — the reference applies every stored
            // entry — so the fast path is unconditional.
            for (j, o) in dst.iter_mut().enumerate() {
                *o = *o + x0 * d0[j] + x1 * d1[j] + x2 * d2[j] + x3 * d3[j];
            }
            p += ACC_BLOCK;
        }
        for pp in p..n {
            let x = vals[pp];
            let drow = &dense.row(cols[pp] as usize)[..w];
            for (o, &d) in dst.iter_mut().zip(drow) {
                *o += x * d;
            }
        }
    }

    /// Shape B: `out = yrowᵀ · H` (overwrites `out`). Bitwise vs
    /// [`super::reference::zt_row`].
    pub fn zt_row(yrow: &[f64], h: &Mat, out: &mut [f64]) {
        dispatch_width!(h.cols(), zt_row_mono, zt_row_body, (yrow, h, out));
    }

    #[inline(always)]
    fn zt_row_mono<const W: usize>(yrow: &[f64], h: &Mat, out: &mut [f64]) {
        zt_row_body(yrow, h, out, W);
    }

    #[inline(always)]
    fn zt_row_body(yrow: &[f64], h: &Mat, out: &mut [f64], w: usize) {
        let out = &mut out[..w];
        out.fill(0.0);
        let n = yrow.len();
        let mut i = 0usize;
        while i + ACC_BLOCK <= n {
            let (y0, y1, y2, y3) = (yrow[i], yrow[i + 1], yrow[i + 2], yrow[i + 3]);
            let h0 = &h.row(i)[..w];
            let h1 = &h.row(i + 1)[..w];
            let h2 = &h.row(i + 2)[..w];
            let h3 = &h.row(i + 3)[..w];
            if y0 != 0.0 && y1 != 0.0 && y2 != 0.0 && y3 != 0.0 {
                for (j, o) in out.iter_mut().enumerate() {
                    *o = *o + y0 * h0[j] + y1 * h1[j] + y2 * h2[j] + y3 * h3[j];
                }
            } else {
                for (y, hr) in [(y0, h0), (y1, h1), (y2, h2), (y3, h3)] {
                    if y == 0.0 {
                        continue;
                    }
                    for (o, &hv) in out.iter_mut().zip(hr) {
                        *o += y * hv;
                    }
                }
            }
            i += ACC_BLOCK;
        }
        for ii in i..n {
            let yv = yrow[ii];
            if yv == 0.0 {
                continue;
            }
            let hrow = &h.row(ii)[..w];
            for (o, &hv) in out.iter_mut().zip(hrow) {
                *o += yv * hv;
            }
        }
    }

    /// Shape B: `c += AᵀB` without materializing `Aᵀ` (outer products
    /// over rows of `A`, 4 rows in flight). Bitwise vs
    /// [`super::reference::atb`].
    pub fn atb_into(a: &Mat, b: &Mat, c: &mut Mat) {
        let (ka, m) = a.shape();
        let mut k = 0usize;
        while k + ACC_BLOCK <= ka {
            let a0 = a.row(k);
            let a1 = a.row(k + 1);
            let a2 = a.row(k + 2);
            let a3 = a.row(k + 3);
            let b0 = b.row(k);
            let b1 = b.row(k + 1);
            let b2 = b.row(k + 2);
            let b3 = b.row(k + 3);
            for i in 0..m {
                let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
                let crow = c.row_mut(i);
                if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                    for (j, cv) in crow.iter_mut().enumerate() {
                        *cv = *cv + x0 * b0[j] + x1 * b1[j] + x2 * b2[j] + x3 * b3[j];
                    }
                } else {
                    for (x, br) in [(x0, b0), (x1, b1), (x2, b2), (x3, b3)] {
                        if x == 0.0 {
                            continue;
                        }
                        for (cv, &bv) in crow.iter_mut().zip(br) {
                            *cv += x * bv;
                        }
                    }
                }
            }
            k += ACC_BLOCK;
        }
        for kk in k..ka {
            let arow = a.row(kk);
            let brow = b.row(kk);
            for (i, &aki) in arow.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let crow = c.row_mut(i);
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aki * bv;
                }
            }
        }
    }

    /// Shape B: `g += AᵀA` upper triangle with 4 rows of `A` in flight,
    /// then mirror. Bitwise vs [`super::reference::gram`].
    pub fn gram_into(a: &Mat, g: &mut Mat) {
        let (k, n) = a.shape();
        let mut r = 0usize;
        while r + ACC_BLOCK <= k {
            let r0 = a.row(r);
            let r1 = a.row(r + 1);
            let r2 = a.row(r + 2);
            let r3 = a.row(r + 3);
            for i in 0..n {
                let (x0, x1, x2, x3) = (r0[i], r1[i], r2[i], r3[i]);
                let grow = &mut g.row_mut(i)[i..];
                let (t0, t1, t2, t3) = (&r0[i..], &r1[i..], &r2[i..], &r3[i..]);
                if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                    for (j, gv) in grow.iter_mut().enumerate() {
                        *gv = *gv + x0 * t0[j] + x1 * t1[j] + x2 * t2[j] + x3 * t3[j];
                    }
                } else {
                    for (x, tr) in [(x0, t0), (x1, t1), (x2, t2), (x3, t3)] {
                        if x == 0.0 {
                            continue;
                        }
                        for (gv, &tv) in grow.iter_mut().zip(tr) {
                            *gv += x * tv;
                        }
                    }
                }
            }
            r += ACC_BLOCK;
        }
        for rr in r..k {
            let row = a.row(rr);
            for i in 0..n {
                let ai = row[i];
                if ai == 0.0 {
                    continue;
                }
                let grow = g.row_mut(i);
                for j in i..n {
                    grow[j] += ai * row[j];
                }
            }
        }
        super::mirror_upper(g);
    }
}

/// Generates the five kernel skeletons for a SIMD backend module. The
/// skeletons are *identical* across backends — block-of-4 accumulation
/// loop, all-nonzero fast path, per-coefficient exact-zero skip path,
/// ragged tails in reference order — and only the two leaf primitives
/// differ per module:
///
/// * `accum4(dst, [y;4], [row;4])` — `dst[j] (+)= y0·r0[j] + … + y3·r3[j]`
///   with the module's lane width and rounding discipline;
/// * `accum1(dst, y, row)` — `dst[j] += y·row[j]`.
///
/// A module whose `accum*` use separate mul+add per term (lane = one
/// output element, scalar chain order) lands in the bitwise family; one
/// that fuses (FMA) lands in the reordered family. Keeping the skeleton
/// shared is what guarantees zero-skip/NaN semantics can never drift
/// between backends.
macro_rules! simd_panel_kernels {
    ($feat:literal, $detect:expr) => {
        /// Shape A: `out += Y_k · V_c` (packed transpose × support
        /// gather). Same skeleton as `blocked::spmm_yt_v`.
        pub fn spmm_yt_v(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
            assert!($detect, "kernel backend requires {}", $feat);
            // SAFETY: the assert above proves the ISA is present.
            unsafe { spmm_yt_v_tf(yt, support, v, out) }
        }

        #[target_feature(enable = $feat)]
        unsafe fn spmm_yt_v_tf(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
            let w = v.cols();
            let r = yt.cols();
            let n = support.len();
            let mut c = 0usize;
            while c + ACC_BLOCK <= n {
                let v0 = &v.row(support[c] as usize)[..w];
                let v1 = &v.row(support[c + 1] as usize)[..w];
                let v2 = &v.row(support[c + 2] as usize)[..w];
                let v3 = &v.row(support[c + 3] as usize)[..w];
                for i in 0..r {
                    let y = [yt[(c, i)], yt[(c + 1, i)], yt[(c + 2, i)], yt[(c + 3, i)]];
                    let orow = &mut out.row_mut(i)[..w];
                    if y[0] != 0.0 && y[1] != 0.0 && y[2] != 0.0 && y[3] != 0.0 {
                        accum4(orow, y, [v0, v1, v2, v3]);
                    } else {
                        // Preserve the reference's exact-zero skip
                        // term-by-term (a skipped 0·NaN must stay skipped).
                        for (k, &yv) in y.iter().enumerate() {
                            if yv == 0.0 {
                                continue;
                            }
                            accum1(orow, yv, [v0, v1, v2, v3][k]);
                        }
                    }
                }
                c += ACC_BLOCK;
            }
            // Ragged tail in reference order.
            for cc in c..n {
                let vrow = &v.row(support[cc] as usize)[..w];
                let yrow = yt.row(cc);
                for (i, &yv) in yrow.iter().enumerate() {
                    if yv == 0.0 {
                        continue;
                    }
                    accum1(&mut out.row_mut(i)[..w], yv, vrow);
                }
            }
        }

        /// Shape A: `dst += Σ_p vals[p] · dense(cols[p],:)`. Same
        /// skeleton as `blocked::sparse_row_axpy` (no zero skip: the
        /// reference applies every stored entry).
        pub fn sparse_row_axpy(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64]) {
            assert!($detect, "kernel backend requires {}", $feat);
            // SAFETY: the assert above proves the ISA is present.
            unsafe { sparse_row_axpy_tf(vals, cols, dense, dst) }
        }

        #[target_feature(enable = $feat)]
        unsafe fn sparse_row_axpy_tf(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64]) {
            let w = dense.cols();
            let dst = &mut dst[..w];
            let n = vals.len();
            let mut p = 0usize;
            while p + ACC_BLOCK <= n {
                let x = [vals[p], vals[p + 1], vals[p + 2], vals[p + 3]];
                let d0 = &dense.row(cols[p] as usize)[..w];
                let d1 = &dense.row(cols[p + 1] as usize)[..w];
                let d2 = &dense.row(cols[p + 2] as usize)[..w];
                let d3 = &dense.row(cols[p + 3] as usize)[..w];
                accum4(dst, x, [d0, d1, d2, d3]);
                p += ACC_BLOCK;
            }
            for pp in p..n {
                accum1(dst, vals[pp], &dense.row(cols[pp] as usize)[..w]);
            }
        }

        /// Shape B: `out = yrowᵀ · H` (overwrites `out`). Same skeleton
        /// as `blocked::zt_row`.
        pub fn zt_row(yrow: &[f64], h: &Mat, out: &mut [f64]) {
            assert!($detect, "kernel backend requires {}", $feat);
            // SAFETY: the assert above proves the ISA is present.
            unsafe { zt_row_tf(yrow, h, out) }
        }

        #[target_feature(enable = $feat)]
        unsafe fn zt_row_tf(yrow: &[f64], h: &Mat, out: &mut [f64]) {
            let w = h.cols();
            let out = &mut out[..w];
            out.fill(0.0);
            let n = yrow.len();
            let mut i = 0usize;
            while i + ACC_BLOCK <= n {
                let y = [yrow[i], yrow[i + 1], yrow[i + 2], yrow[i + 3]];
                let h0 = &h.row(i)[..w];
                let h1 = &h.row(i + 1)[..w];
                let h2 = &h.row(i + 2)[..w];
                let h3 = &h.row(i + 3)[..w];
                if y[0] != 0.0 && y[1] != 0.0 && y[2] != 0.0 && y[3] != 0.0 {
                    accum4(out, y, [h0, h1, h2, h3]);
                } else {
                    for (k, &yv) in y.iter().enumerate() {
                        if yv == 0.0 {
                            continue;
                        }
                        accum1(out, yv, [h0, h1, h2, h3][k]);
                    }
                }
                i += ACC_BLOCK;
            }
            for ii in i..n {
                let yv = yrow[ii];
                if yv == 0.0 {
                    continue;
                }
                accum1(out, yv, &h.row(ii)[..w]);
            }
        }

        /// Shape B: `c += AᵀB` (outer products over rows of `A`, 4 rows
        /// in flight). Same skeleton as `blocked::atb_into`.
        pub fn atb_into(a: &Mat, b: &Mat, c: &mut Mat) {
            assert!($detect, "kernel backend requires {}", $feat);
            // SAFETY: the assert above proves the ISA is present.
            unsafe { atb_into_tf(a, b, c) }
        }

        #[target_feature(enable = $feat)]
        unsafe fn atb_into_tf(a: &Mat, b: &Mat, c: &mut Mat) {
            let (ka, m) = a.shape();
            let mut k = 0usize;
            while k + ACC_BLOCK <= ka {
                let a0 = a.row(k);
                let a1 = a.row(k + 1);
                let a2 = a.row(k + 2);
                let a3 = a.row(k + 3);
                let brows = [b.row(k), b.row(k + 1), b.row(k + 2), b.row(k + 3)];
                for i in 0..m {
                    let x = [a0[i], a1[i], a2[i], a3[i]];
                    let crow = c.row_mut(i);
                    if x[0] != 0.0 && x[1] != 0.0 && x[2] != 0.0 && x[3] != 0.0 {
                        accum4(crow, x, brows);
                    } else {
                        for (kk, &xv) in x.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            accum1(crow, xv, brows[kk]);
                        }
                    }
                }
                k += ACC_BLOCK;
            }
            for kk in k..ka {
                let arow = a.row(kk);
                let brow = b.row(kk);
                for (i, &aki) in arow.iter().enumerate() {
                    if aki == 0.0 {
                        continue;
                    }
                    accum1(c.row_mut(i), aki, brow);
                }
            }
        }

        /// Shape B: `g += AᵀA` upper triangle, then mirror. Same skeleton
        /// as `blocked::gram_into`.
        pub fn gram_into(a: &Mat, g: &mut Mat) {
            assert!($detect, "kernel backend requires {}", $feat);
            // SAFETY: the assert above proves the ISA is present.
            unsafe { gram_into_tf(a, g) }
        }

        #[target_feature(enable = $feat)]
        unsafe fn gram_into_tf(a: &Mat, g: &mut Mat) {
            let (k, n) = a.shape();
            let mut r = 0usize;
            while r + ACC_BLOCK <= k {
                let rows = [a.row(r), a.row(r + 1), a.row(r + 2), a.row(r + 3)];
                for i in 0..n {
                    let x = [rows[0][i], rows[1][i], rows[2][i], rows[3][i]];
                    let grow = &mut g.row_mut(i)[i..];
                    if x[0] != 0.0 && x[1] != 0.0 && x[2] != 0.0 && x[3] != 0.0 {
                        accum4(
                            grow,
                            x,
                            [&rows[0][i..], &rows[1][i..], &rows[2][i..], &rows[3][i..]],
                        );
                    } else {
                        for (kk, &xv) in x.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            accum1(grow, xv, &rows[kk][i..]);
                        }
                    }
                }
                r += ACC_BLOCK;
            }
            for rr in r..k {
                let row = a.row(rr);
                for i in 0..n {
                    let ai = row[i];
                    if ai == 0.0 {
                        continue;
                    }
                    accum1(&mut g.row_mut(i)[i..], ai, &row[i..]);
                }
            }
            super::mirror_upper(g);
        }
    };
}

/// x86-64 AVX2 backend: 4 × f64 lanes on the panel-width axis with
/// **separate** multiply and add per accumulation term. Each lane owns
/// one output element and replays the scalar chain in identical order,
/// so this backend is in the **bitwise** family (FMA is deliberately not
/// used — fusing would change the rounding and eject it from the family).
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use super::{Mat, ACC_BLOCK};
    use core::arch::x86_64::*;

    const LANES: usize = 4;

    simd_panel_kernels!("avx2", is_x86_feature_detected!("avx2"));

    /// `dst[j] = dst[j] + y0·r0[j] + y1·r1[j] + y2·r2[j] + y3·r3[j]`,
    /// left to right with unfused mul+add — the scalar chain per lane.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn accum4(dst: &mut [f64], y: [f64; 4], rows: [&[f64]; 4]) {
        let w = dst.len();
        debug_assert!(rows.iter().all(|r| r.len() >= w));
        let (y0, y1, y2, y3) = (
            _mm256_set1_pd(y[0]),
            _mm256_set1_pd(y[1]),
            _mm256_set1_pd(y[2]),
            _mm256_set1_pd(y[3]),
        );
        let (r0, r1, r2, r3) = (
            rows[0].as_ptr(),
            rows[1].as_ptr(),
            rows[2].as_ptr(),
            rows[3].as_ptr(),
        );
        let d = dst.as_mut_ptr();
        let mut j = 0usize;
        while j + LANES <= w {
            let mut acc = _mm256_loadu_pd(d.add(j));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(y0, _mm256_loadu_pd(r0.add(j))));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(y1, _mm256_loadu_pd(r1.add(j))));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(y2, _mm256_loadu_pd(r2.add(j))));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(y3, _mm256_loadu_pd(r3.add(j))));
            _mm256_storeu_pd(d.add(j), acc);
            j += LANES;
        }
        while j < w {
            dst[j] = dst[j] + y[0] * rows[0][j] + y[1] * rows[1][j] + y[2] * rows[2][j]
                + y[3] * rows[3][j];
            j += 1;
        }
    }

    /// `dst[j] += y·src[j]` — one unfused mul+add per element.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn accum1(dst: &mut [f64], y: f64, src: &[f64]) {
        let w = dst.len();
        debug_assert!(src.len() >= w);
        let yv = _mm256_set1_pd(y);
        let s = src.as_ptr();
        let d = dst.as_mut_ptr();
        let mut j = 0usize;
        while j + LANES <= w {
            let acc = _mm256_add_pd(
                _mm256_loadu_pd(d.add(j)),
                _mm256_mul_pd(yv, _mm256_loadu_pd(s.add(j))),
            );
            _mm256_storeu_pd(d.add(j), acc);
            j += LANES;
        }
        while j < w {
            dst[j] += y * src[j];
            j += 1;
        }
    }
}

/// x86-64 AVX-512F backend: 8 × f64 lanes with **fused** multiply-add
/// (one rounding per term instead of two). **Reordered family**: results
/// are ULP-bounded against the reference, not bitwise — opt-in only,
/// never auto-selected, and rejected in mixed-backend shard topologies.
/// The skeleton (zero-skip branches, term order, tails) is still shared,
/// so NaN placement and zero-skip semantics match the reference exactly.
#[cfg(target_arch = "x86_64")]
pub mod avx512 {
    use super::{Mat, ACC_BLOCK};
    use core::arch::x86_64::*;

    const LANES: usize = 8;

    simd_panel_kernels!("avx512f", is_x86_feature_detected!("avx512f"));

    /// `dst[j] = fma(y3, r3[j], fma(y2, r2[j], fma(y1, r1[j],
    /// fma(y0, r0[j], dst[j]))))` — fused per term (reordered family).
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn accum4(dst: &mut [f64], y: [f64; 4], rows: [&[f64]; 4]) {
        let w = dst.len();
        debug_assert!(rows.iter().all(|r| r.len() >= w));
        let (y0, y1, y2, y3) = (
            _mm512_set1_pd(y[0]),
            _mm512_set1_pd(y[1]),
            _mm512_set1_pd(y[2]),
            _mm512_set1_pd(y[3]),
        );
        let (r0, r1, r2, r3) = (
            rows[0].as_ptr(),
            rows[1].as_ptr(),
            rows[2].as_ptr(),
            rows[3].as_ptr(),
        );
        let d = dst.as_mut_ptr();
        let mut j = 0usize;
        while j + LANES <= w {
            let mut acc = _mm512_loadu_pd(d.add(j));
            acc = _mm512_fmadd_pd(y0, _mm512_loadu_pd(r0.add(j)), acc);
            acc = _mm512_fmadd_pd(y1, _mm512_loadu_pd(r1.add(j)), acc);
            acc = _mm512_fmadd_pd(y2, _mm512_loadu_pd(r2.add(j)), acc);
            acc = _mm512_fmadd_pd(y3, _mm512_loadu_pd(r3.add(j)), acc);
            _mm512_storeu_pd(d.add(j), acc);
            j += LANES;
        }
        while j < w {
            dst[j] = y[3].mul_add(
                rows[3][j],
                y[2].mul_add(rows[2][j], y[1].mul_add(rows[1][j], y[0].mul_add(rows[0][j], dst[j]))),
            );
            j += 1;
        }
    }

    /// `dst[j] = fma(y, src[j], dst[j])` — fused (reordered family).
    #[inline]
    #[target_feature(enable = "avx512f")]
    unsafe fn accum1(dst: &mut [f64], y: f64, src: &[f64]) {
        let w = dst.len();
        debug_assert!(src.len() >= w);
        let yv = _mm512_set1_pd(y);
        let s = src.as_ptr();
        let d = dst.as_mut_ptr();
        let mut j = 0usize;
        while j + LANES <= w {
            let acc = _mm512_fmadd_pd(yv, _mm512_loadu_pd(s.add(j)), _mm512_loadu_pd(d.add(j)));
            _mm512_storeu_pd(d.add(j), acc);
            j += LANES;
        }
        while j < w {
            dst[j] = y.mul_add(src[j], dst[j]);
            j += 1;
        }
    }
}

/// AArch64 NEON backend: 2 × f64 lanes on the panel-width axis with
/// **separate** multiply and add per term (`vfmaq_f64` is deliberately
/// not used). Bitwise family, same reasoning as `avx2`.
#[cfg(target_arch = "aarch64")]
pub mod neon {
    use super::{Mat, ACC_BLOCK};
    use core::arch::aarch64::*;

    const LANES: usize = 2;

    simd_panel_kernels!("neon", std::arch::is_aarch64_feature_detected!("neon"));

    /// `dst[j] = dst[j] + y0·r0[j] + … + y3·r3[j]`, unfused, in order.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn accum4(dst: &mut [f64], y: [f64; 4], rows: [&[f64]; 4]) {
        let w = dst.len();
        debug_assert!(rows.iter().all(|r| r.len() >= w));
        let (y0, y1, y2, y3) = (
            vdupq_n_f64(y[0]),
            vdupq_n_f64(y[1]),
            vdupq_n_f64(y[2]),
            vdupq_n_f64(y[3]),
        );
        let (r0, r1, r2, r3) = (
            rows[0].as_ptr(),
            rows[1].as_ptr(),
            rows[2].as_ptr(),
            rows[3].as_ptr(),
        );
        let d = dst.as_mut_ptr();
        let mut j = 0usize;
        while j + LANES <= w {
            let mut acc = vld1q_f64(d.add(j));
            acc = vaddq_f64(acc, vmulq_f64(y0, vld1q_f64(r0.add(j))));
            acc = vaddq_f64(acc, vmulq_f64(y1, vld1q_f64(r1.add(j))));
            acc = vaddq_f64(acc, vmulq_f64(y2, vld1q_f64(r2.add(j))));
            acc = vaddq_f64(acc, vmulq_f64(y3, vld1q_f64(r3.add(j))));
            vst1q_f64(d.add(j), acc);
            j += LANES;
        }
        while j < w {
            dst[j] = dst[j] + y[0] * rows[0][j] + y[1] * rows[1][j] + y[2] * rows[2][j]
                + y[3] * rows[3][j];
            j += 1;
        }
    }

    /// `dst[j] += y·src[j]` — one unfused mul+add per element.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn accum1(dst: &mut [f64], y: f64, src: &[f64]) {
        let w = dst.len();
        debug_assert!(src.len() >= w);
        let yv = vdupq_n_f64(y);
        let s = src.as_ptr();
        let d = dst.as_mut_ptr();
        let mut j = 0usize;
        while j + LANES <= w {
            let acc = vaddq_f64(vld1q_f64(d.add(j)), vmulq_f64(yv, vld1q_f64(s.add(j))));
            vst1q_f64(d.add(j), acc);
            j += LANES;
        }
        while j < w {
            dst[j] += y * src[j];
            j += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

fn unsupported_arch(b: KernelBackend) -> ! {
    panic!(
        "kernel backend '{}' is not compiled for this architecture",
        b.name()
    )
}

/// `out += Y_k · V_c` where `Y_k` is held as its packed transpose `yt`
/// (`c_k × R`) and `V_c` is the support-row gather of `v`, via the
/// process-selected backend. Bitwise identical to
/// [`reference::spmm_yt_v`] for every input under any backend in the
/// bitwise family.
pub fn spmm_yt_v(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
    spmm_yt_v_with(active_backend(), yt, support, v, out);
}

/// [`spmm_yt_v`] through an explicit backend (conformance sweeps and
/// per-ISA bench cells; production code uses the process-selected form).
pub fn spmm_yt_v_with(backend: KernelBackend, yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
    debug_assert_eq!(yt.rows(), support.len(), "support/yt row mismatch");
    debug_assert_eq!(out.shape(), (yt.cols(), v.cols()), "spmm output shape");
    match backend {
        KernelBackend::Scalar => reference::spmm_yt_v(yt, support, v, out),
        KernelBackend::Blocked => blocked::spmm_yt_v(yt, support, v, out),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => avx2::spmm_yt_v(yt, support, v, out),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx512 => avx512::spmm_yt_v(yt, support, v, out),
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => neon::spmm_yt_v(yt, support, v, out),
        other => unsupported_arch(other),
    }
}

/// `dst += Σ_p vals[p] · dense(cols[p],:)` — one CSR row against a dense
/// panel, via the process-selected backend. Bitwise identical to
/// [`reference::sparse_row_axpy`] under any bitwise-family backend.
pub fn sparse_row_axpy(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64]) {
    sparse_row_axpy_with(active_backend(), vals, cols, dense, dst);
}

/// [`sparse_row_axpy`] through an explicit backend.
pub fn sparse_row_axpy_with(
    backend: KernelBackend,
    vals: &[f64],
    cols: &[u32],
    dense: &Mat,
    dst: &mut [f64],
) {
    debug_assert_eq!(vals.len(), cols.len(), "vals/cols length mismatch");
    debug_assert_eq!(dst.len(), dense.cols(), "dst width mismatch");
    match backend {
        KernelBackend::Scalar => reference::sparse_row_axpy(vals, cols, dense, dst),
        KernelBackend::Blocked => blocked::sparse_row_axpy(vals, cols, dense, dst),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => avx2::sparse_row_axpy(vals, cols, dense, dst),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx512 => avx512::sparse_row_axpy(vals, cols, dense, dst),
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => neon::sparse_row_axpy(vals, cols, dense, dst),
        other => unsupported_arch(other),
    }
}

/// `out = yrowᵀ · H` (overwrites `out`): one packed row of `Y_kᵀ` against
/// the `R×R` factor — the `Z_k = Y_kᵀ H` row kernel of the mode-2/mode-3
/// sweeps, via the process-selected backend. Bitwise identical to
/// [`reference::zt_row`] under any bitwise-family backend.
pub fn zt_row(yrow: &[f64], h: &Mat, out: &mut [f64]) {
    zt_row_with(active_backend(), yrow, h, out);
}

/// [`zt_row`] through an explicit backend.
pub fn zt_row_with(backend: KernelBackend, yrow: &[f64], h: &Mat, out: &mut [f64]) {
    debug_assert_eq!(yrow.len(), h.rows(), "yrow/H row mismatch");
    debug_assert_eq!(out.len(), h.cols(), "out width mismatch");
    match backend {
        KernelBackend::Scalar => reference::zt_row(yrow, h, out),
        KernelBackend::Blocked => blocked::zt_row(yrow, h, out),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => avx2::zt_row(yrow, h, out),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx512 => avx512::zt_row(yrow, h, out),
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => neon::zt_row(yrow, h, out),
        other => unsupported_arch(other),
    }
}

/// `c += AᵀB` without materializing `Aᵀ`, via the process-selected
/// backend. Bitwise identical to [`reference::atb`] under any
/// bitwise-family backend.
pub fn atb_into(a: &Mat, b: &Mat, c: &mut Mat) {
    atb_into_with(active_backend(), a, b, c);
}

/// [`atb_into`] through an explicit backend.
pub fn atb_into_with(backend: KernelBackend, a: &Mat, b: &Mat, c: &mut Mat) {
    let (ka, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "atb inner-dim mismatch");
    assert_eq!(c.shape(), (m, n), "atb output shape mismatch");
    match backend {
        KernelBackend::Scalar => reference::atb(a, b, c),
        KernelBackend::Blocked => blocked::atb_into(a, b, c),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => avx2::atb_into(a, b, c),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx512 => avx512::atb_into(a, b, c),
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => neon::atb_into(a, b, c),
        other => unsupported_arch(other),
    }
}

/// `g += AᵀA`: upper triangle then mirror, via the process-selected
/// backend. Bitwise identical to [`reference::gram`] under any
/// bitwise-family backend.
pub fn gram_into(a: &Mat, g: &mut Mat) {
    gram_into_with(active_backend(), a, g);
}

/// [`gram_into`] through an explicit backend.
pub fn gram_into_with(backend: KernelBackend, a: &Mat, g: &mut Mat) {
    let (_, n) = a.shape();
    assert_eq!(g.shape(), (n, n), "gram output shape mismatch");
    match backend {
        KernelBackend::Scalar => reference::gram(a, g),
        KernelBackend::Blocked => blocked::gram_into(a, g),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx2 => avx2::gram_into(a, g),
        #[cfg(target_arch = "x86_64")]
        KernelBackend::Avx512 => avx512::gram_into(a, g),
        #[cfg(target_arch = "aarch64")]
        KernelBackend::Neon => neon::gram_into(a, g),
        other => unsupported_arch(other),
    }
}

// ---------------------------------------------------------------------------
// Reordered family
// ---------------------------------------------------------------------------

/// Dot product with 4 independent accumulators (breaks the dependency
/// chain so several FMAs stay in flight). **Reordered** relative to
/// [`reference::dot_seq`]: ULP-bounded, not bitwise — see the module
/// docs' determinism contract. Not backend-dispatched: its schedule is
/// already portable and identical on every host.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += x[i] * y[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn bits_eq(a: &Mat, b: &Mat) -> bool {
        a.shape() == b.shape()
            && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    fn random_support(rng: &mut Pcg64, c: usize, j: usize) -> Vec<u32> {
        assert!(c <= j);
        let mut ids: Vec<u32> = (0..j as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(c);
        ids.sort_unstable();
        ids
    }

    /// yt with exact zeros sprinkled in (exercises both skip paths).
    fn random_yt(rng: &mut Pcg64, c: usize, r: usize) -> Mat {
        Mat::from_fn(c, r, |_, _| if rng.chance(0.2) { 0.0 } else { rng.normal() })
    }

    /// One fast unit-level guard per kernel, run through the
    /// process-selected backend (whatever auto-detection picked on this
    /// host — any bitwise-family member must pass these assertions
    /// unchanged). The *exhaustive* per-backend differential sweeps
    /// (every detected ISA × R ∈ {1..=16, 17, 32} × ragged/empty
    /// operands × zero / denormal / NaN regimes) live in
    /// `rust/tests/kernel_conformance.rs` — this smoke test only keeps
    /// `cargo test --lib` self-contained.
    #[test]
    fn selected_backend_smoke_bitwise() {
        assert!(active_backend().is_bitwise(), "auto-selection must stay bitwise");
        let mut rng = Pcg64::seed(601);
        let (r, c) = (7usize, 9usize); // block + ragged tail, unrolled width
        let j = c + 5;
        let support = random_support(&mut rng, c, j);
        let yt = random_yt(&mut rng, c, r);
        let v = Mat::rand_normal(j, r, &mut rng);
        let mut blocked = Mat::zeros(r, r);
        let mut refr = Mat::zeros(r, r);
        spmm_yt_v(&yt, &support, &v, &mut blocked);
        reference::spmm_yt_v(&yt, &support, &v, &mut refr);
        assert!(bits_eq(&blocked, &refr), "spmm");

        let h = Mat::rand_normal(r, r, &mut rng);
        let yrow: Vec<f64> =
            (0..r).map(|_| if rng.chance(0.3) { 0.0 } else { rng.normal() }).collect();
        let mut z_blocked = vec![1.0f64; r]; // nonzero: zt_row must overwrite
        let mut z_ref = vec![2.0f64; r];
        zt_row(&yrow, &h, &mut z_blocked);
        reference::zt_row(&yrow, &h, &mut z_ref);
        for (x, y) in z_blocked.iter().zip(&z_ref) {
            assert_eq!(x.to_bits(), y.to_bits(), "zt_row");
        }

        let a = random_yt(&mut rng, c, r);
        let b = random_yt(&mut rng, c, r);
        let mut c_blocked = Mat::zeros(r, r);
        let mut c_ref = Mat::zeros(r, r);
        atb_into(&a, &b, &mut c_blocked);
        reference::atb(&a, &b, &mut c_ref);
        assert!(bits_eq(&c_blocked, &c_ref), "atb");
        let mut g_blocked = Mat::zeros(r, r);
        let mut g_ref = Mat::zeros(r, r);
        gram_into(&a, &mut g_blocked);
        reference::gram(&a, &mut g_ref);
        assert!(bits_eq(&g_blocked, &g_ref), "gram");

        let cols: Vec<u32> = (0..c).map(|_| rng.range(0, j) as u32).collect();
        let vals: Vec<f64> = (0..c).map(|_| rng.normal()).collect();
        let dense = Mat::rand_normal(j, r, &mut rng);
        let mut s_blocked = vec![0.5f64; r];
        let mut s_ref = vec![0.5f64; r];
        sparse_row_axpy(&vals, &cols, &dense, &mut s_blocked);
        reference::sparse_row_axpy(&vals, &cols, &dense, &mut s_ref);
        for (x, y) in s_blocked.iter().zip(&s_ref) {
            assert_eq!(x.to_bits(), y.to_bits(), "sparse_row_axpy");
        }
    }

    /// Every *bitwise* backend the host supports agrees bit-for-bit with
    /// the reference on a block+tail shape (the deep grid lives in the
    /// conformance suite; this keeps `--lib` covering each ISA at all).
    #[test]
    fn detected_bitwise_backends_smoke_bitwise() {
        let mut rng = Pcg64::seed(602);
        let (r, c) = (6usize, 11usize);
        let j = c + 3;
        let support = random_support(&mut rng, c, j);
        let yt = random_yt(&mut rng, c, r);
        let v = Mat::rand_normal(j, r, &mut rng);
        let mut want = Mat::zeros(r, r);
        reference::spmm_yt_v(&yt, &support, &v, &mut want);
        for backend in KernelBackend::detected() {
            if !backend.is_bitwise() {
                continue;
            }
            let mut got = Mat::zeros(r, r);
            spmm_yt_v_with(backend, &yt, &support, &v, &mut got);
            assert!(bits_eq(&got, &want), "spmm via {}", backend.name());
        }
    }

    #[test]
    fn backend_names_parse_roundtrip() {
        for b in KernelBackend::ALL {
            assert_eq!(KernelBackend::parse(b.name()), Ok(b));
        }
        assert!(KernelBackend::parse("sse9").is_err());
        assert!(KernelBackend::parse("").is_err());
    }

    #[test]
    fn scalar_and_blocked_always_supported_and_auto_is_bitwise() {
        assert!(KernelBackend::Scalar.is_supported());
        assert!(KernelBackend::Blocked.is_supported());
        let auto = KernelBackend::auto();
        assert!(auto.is_bitwise(), "auto-selection may never pick a reordered backend");
        assert!(auto.is_supported());
        assert!(KernelBackend::detected().contains(&auto));
        // The reordered family is exactly avx512 (+ the free-standing dot).
        for b in KernelBackend::ALL {
            assert_eq!(b.is_bitwise(), b != KernelBackend::Avx512);
        }
    }

    #[test]
    fn set_backend_roundtrips_and_rejects_unsupported() {
        let prior = active_backend();
        set_backend(KernelBackend::Scalar).unwrap();
        assert_eq!(active_backend(), KernelBackend::Scalar);
        // Restore so parallel lib tests keep their (bitwise) selection.
        set_backend(prior).unwrap();
        assert_eq!(active_backend(), prior);
        for b in KernelBackend::ALL {
            if !b.is_supported() {
                let err = set_backend(b).unwrap_err();
                assert!(err.contains(b.name()), "error names the backend: {err}");
                assert_eq!(active_backend(), prior, "failed set must not change selection");
            }
        }
    }

    #[test]
    fn dot_matches_seq_on_exact_inputs() {
        // integer-valued inputs: both orders are exact
        for n in 0..20 {
            let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let y: Vec<f64> = (0..n).map(|i| (2 * i) as f64).collect();
            assert_eq!(dot(&x, &y), reference::dot_seq(&x, &y));
        }
    }
}
