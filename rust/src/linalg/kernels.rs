//! Register-blocked micro-kernels for the ALS hot loops, behind one
//! dispatch point.
//!
//! Profiling after the PR 1–2 fusions leaves the iteration time inside two
//! rank-1-update loop shapes, and this module owns both:
//!
//! * **Shape A — sparse-support rows × dense panel.** `Y_k·V` restricted
//!   to the column support ([`spmm_yt_v`], powering
//!   `PackedSlice::yk_times_v{,_fused}` and therefore the pack-fused
//!   Procrustes→mode-1 sweep), and the CSR-row AXPY `X_k·V`
//!   ([`sparse_row_axpy`], powering `Csr::matmul_dense` inside the
//!   Procrustes target).
//! * **Shape B — dense-transpose × dense panel.** The per-row
//!   `Z_k(c,:) = Y_k(:,j_c)ᵀ H` kernel ([`zt_row`], the mode-2/mode-3
//!   sweeps), and the panel forms `AᵀB` ([`atb_into`], `blas::matmul_at_b`)
//!   and `AᵀA` ([`gram_into`], `blas::gram`) that the normal equations and
//!   Procrustes hit every iteration.
//!
//! ## Blocking schedule
//!
//! Every kernel blocks the **accumulation axis** by [`ACC_BLOCK`] = 4:
//! four coefficient/row pairs are held in registers and applied to the
//! destination row in one pass, quartering the destination's load/store
//! round-trips (the bottleneck of the scalar form, which re-streams the
//! output row once per accumulation step). The per-slice kernels
//! additionally monomorphize the panel width for `R ≤` [`R_UNROLL_MAX`]
//! (the `#[inline(always)]` body is instantiated with a `const` width, so
//! LLVM fully unrolls and vectorizes the inner loop at the exact rank) —
//! the R-unrolled fast path for the paper's R ∈ {5..40} sweet spot.
//!
//! The schedule is **fixed and data-only**: which variant runs depends
//! only on operand shapes, never on values, worker counts, or timing, so
//! kernel selection can never perturb the repo's bitwise-determinism
//! contracts.
//!
//! ## Determinism contract
//!
//! Two families, asserted by `rust/tests/kernel_conformance.rs`:
//!
//! * **Order-preserving (bitwise).** [`spmm_yt_v`], [`sparse_row_axpy`],
//!   [`zt_row`], [`atb_into`], [`gram_into`] produce results **bitwise
//!   identical** to their scalar references in [`reference`] for *every*
//!   input (zeros, denormals, NaN propagation included): the 4-wide block
//!   applies its terms left-to-right in the same accumulation-axis order
//!   as the scalar loop, and exact-zero skips are preserved term-by-term,
//!   so each output element sees the identical floating-point sequence.
//!   Swapping the blocked and reference kernels can never move a
//!   trajectory by even one ulp.
//! * **Reordered (ULP-bounded).** [`dot`] keeps its 4 independent
//!   accumulators (the dependency-chain break that lets FMAs overlap) and
//!   is therefore *not* bitwise against the sequential
//!   [`reference::dot_seq`]; conformance pins it to a tight ULP
//!   envelope (and to exact equality on same-sign denormal inputs, where
//!   every partial addition is exact).
//!
//! ## Adding a kernel shape
//!
//! 1. Write the scalar loop in [`reference`] first — its floating-point
//!    order *is* the contract.
//! 2. Add the blocked form with the same per-element term order (or
//!    document it in the reordered family) and a single `pub fn` dispatch
//!    that picks variants by shape only.
//! 3. Extend `kernel_conformance.rs` with the new shape's differential
//!    sweep (R sweep, ragged/empty operands, zero and denormal values),
//!    `prop_invariants.rs` if the kernel feeds a pooled reduction, and a
//!    blocked-vs-scalar A/B cell in `benches/micro_linalg.rs`.
//!
//! Callers (`parafac2::intermediate`, `parafac2::mttkrp`,
//! `sparse::csr`, `linalg::blas`) go through the dispatch functions and
//! never select variants themselves.

use super::dense::Mat;

/// Register block over the accumulation axis: 4 coefficient/row pairs in
/// flight per destination-row pass.
pub const ACC_BLOCK: usize = 4;

/// Panel widths `1..=R_UNROLL_MAX` get a monomorphized (fully unrolled)
/// inner loop in the per-slice kernels; wider panels take the same blocked
/// body with a runtime width.
pub const R_UNROLL_MAX: usize = 16;

/// Scalar reference kernels. Their loop order defines the floating-point
/// sequence the order-preserving blocked kernels must reproduce bit for
/// bit; they also serve as the slow-but-obvious implementations the
/// conformance harness and the `micro_linalg` A/B cells diff against.
pub mod reference {
    use super::Mat;

    /// Shape A reference: `out += Σ_c yt(c,:)ᵀ ⊗ v(support[c],:)` — the
    /// pre-blocking `yk_times_v` loop (exact-zero coefficients skipped).
    pub fn spmm_yt_v(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
        for (c, &j) in support.iter().enumerate() {
            let yrow = yt.row(c);
            let vrow = v.row(j as usize);
            for (i, &yv) in yrow.iter().enumerate() {
                if yv == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for (o, &vv) in orow.iter_mut().zip(vrow) {
                    *o += yv * vv;
                }
            }
        }
    }

    /// Shape A reference: `dst += Σ_p vals[p] · dense(cols[p],:)` — one
    /// CSR row times a dense panel (no zero skip: stored zeros are rare
    /// and the historical loop applied them).
    pub fn sparse_row_axpy(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64]) {
        for (&x, &c) in vals.iter().zip(cols) {
            let drow = dense.row(c as usize);
            for (o, &d) in dst.iter_mut().zip(drow) {
                *o += x * d;
            }
        }
    }

    /// Shape B reference: `out = yrowᵀ · H` (overwrites `out`; exact-zero
    /// coefficients skipped) — the pre-blocking `yt_row_times_h`.
    pub fn zt_row(yrow: &[f64], h: &Mat, out: &mut [f64]) {
        out.fill(0.0);
        for (i, &yv) in yrow.iter().enumerate() {
            if yv == 0.0 {
                continue;
            }
            let hrow = h.row(i);
            for (o, &hv) in out.iter_mut().zip(hrow) {
                *o += yv * hv;
            }
        }
    }

    /// Shape B reference: `c += AᵀB` by outer products over rows of `A`
    /// (exact-zero coefficients skipped) — the pre-blocking
    /// `matmul_at_b`.
    pub fn atb(a: &Mat, b: &Mat, c: &mut Mat) {
        let ka = a.rows();
        for k in 0..ka {
            let arow = a.row(k);
            let brow = b.row(k);
            for (i, &aki) in arow.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let crow = c.row_mut(i);
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aki * bv;
                }
            }
        }
    }

    /// Shape B reference: `g += AᵀA` upper triangle, then mirror (exact
    /// zeros skipped) — the pre-blocking `gram`.
    pub fn gram(a: &Mat, g: &mut Mat) {
        let (k, n) = a.shape();
        for r in 0..k {
            let row = a.row(r);
            for i in 0..n {
                let ai = row[i];
                if ai == 0.0 {
                    continue;
                }
                let grow = g.row_mut(i);
                for j in i..n {
                    grow[j] += ai * row[j];
                }
            }
        }
        super::mirror_upper(g);
    }

    /// Strictly sequential dot product — the order baseline for the
    /// reordered [`super::dot`].
    pub fn dot_seq(x: &[f64], y: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), y.len());
        let mut s = 0.0;
        for (&a, &b) in x.iter().zip(y) {
            s += a * b;
        }
        s
    }
}

/// Copy the upper triangle of a square matrix onto the lower one.
fn mirror_upper(g: &mut Mat) {
    let n = g.rows();
    for i in 0..n {
        for j in 0..i {
            g[(i, j)] = g[(j, i)];
        }
    }
}

/// Shape-only width dispatch: monomorphize the inner loop for
/// `1..=R_UNROLL_MAX`, fall through to the runtime-width body otherwise.
/// (Selection depends on shapes alone; every arm computes bitwise the
/// same result, so dispatch can never perturb determinism.)
macro_rules! dispatch_width {
    ($w:expr, $mono:ident, $body:ident, ($($a:expr),+)) => {
        match $w {
            1 => $mono::<1>($($a),+),
            2 => $mono::<2>($($a),+),
            3 => $mono::<3>($($a),+),
            4 => $mono::<4>($($a),+),
            5 => $mono::<5>($($a),+),
            6 => $mono::<6>($($a),+),
            7 => $mono::<7>($($a),+),
            8 => $mono::<8>($($a),+),
            9 => $mono::<9>($($a),+),
            10 => $mono::<10>($($a),+),
            11 => $mono::<11>($($a),+),
            12 => $mono::<12>($($a),+),
            13 => $mono::<13>($($a),+),
            14 => $mono::<14>($($a),+),
            15 => $mono::<15>($($a),+),
            16 => $mono::<16>($($a),+),
            w => $body($($a),+, w),
        }
    };
}

// ---------------------------------------------------------------------------
// Shape A: sparse-support rows × dense panel
// ---------------------------------------------------------------------------

/// `out += Y_k · V_c` where `Y_k` is held as its packed transpose `yt`
/// (`c_k × R`) and `V_c` is the support-row gather of `v`. Bitwise
/// identical to [`reference::spmm_yt_v`] for every input.
pub fn spmm_yt_v(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
    debug_assert_eq!(yt.rows(), support.len(), "support/yt row mismatch");
    debug_assert_eq!(out.shape(), (yt.cols(), v.cols()), "spmm output shape");
    dispatch_width!(v.cols(), spmm_mono, spmm_body, (yt, support, v, out));
}

#[inline(always)]
fn spmm_mono<const W: usize>(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat) {
    spmm_body(yt, support, v, out, W);
}

#[inline(always)]
fn spmm_body(yt: &Mat, support: &[u32], v: &Mat, out: &mut Mat, w: usize) {
    let r = yt.cols();
    let n = support.len();
    let mut c = 0usize;
    while c + ACC_BLOCK <= n {
        let v0 = &v.row(support[c] as usize)[..w];
        let v1 = &v.row(support[c + 1] as usize)[..w];
        let v2 = &v.row(support[c + 2] as usize)[..w];
        let v3 = &v.row(support[c + 3] as usize)[..w];
        for i in 0..r {
            let y0 = yt[(c, i)];
            let y1 = yt[(c + 1, i)];
            let y2 = yt[(c + 2, i)];
            let y3 = yt[(c + 3, i)];
            let orow = &mut out.row_mut(i)[..w];
            if y0 != 0.0 && y1 != 0.0 && y2 != 0.0 && y3 != 0.0 {
                // Left-to-right: the identical per-element term order the
                // scalar reference produces with four sequential `+=`.
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = *o + y0 * v0[j] + y1 * v1[j] + y2 * v2[j] + y3 * v3[j];
                }
            } else {
                // Preserve the reference's exact-zero skip term-by-term.
                for (y, vr) in [(y0, v0), (y1, v1), (y2, v2), (y3, v3)] {
                    if y == 0.0 {
                        continue;
                    }
                    for (o, &vv) in orow.iter_mut().zip(vr) {
                        *o += y * vv;
                    }
                }
            }
        }
        c += ACC_BLOCK;
    }
    // Ragged tail in reference order.
    for cc in c..n {
        let vrow = &v.row(support[cc] as usize)[..w];
        let yrow = yt.row(cc);
        for (i, &yv) in yrow.iter().enumerate() {
            if yv == 0.0 {
                continue;
            }
            let orow = &mut out.row_mut(i)[..w];
            for (o, &vv) in orow.iter_mut().zip(vrow) {
                *o += yv * vv;
            }
        }
    }
}

/// `dst += Σ_p vals[p] · dense(cols[p],:)` — one CSR row against a dense
/// panel. Bitwise identical to [`reference::sparse_row_axpy`].
pub fn sparse_row_axpy(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64]) {
    debug_assert_eq!(vals.len(), cols.len(), "vals/cols length mismatch");
    debug_assert_eq!(dst.len(), dense.cols(), "dst width mismatch");
    dispatch_width!(dense.cols(), sparse_row_mono, sparse_row_body, (vals, cols, dense, dst));
}

#[inline(always)]
fn sparse_row_mono<const W: usize>(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64]) {
    sparse_row_body(vals, cols, dense, dst, W);
}

#[inline(always)]
fn sparse_row_body(vals: &[f64], cols: &[u32], dense: &Mat, dst: &mut [f64], w: usize) {
    let dst = &mut dst[..w];
    let n = vals.len();
    let mut p = 0usize;
    while p + ACC_BLOCK <= n {
        let (x0, x1, x2, x3) = (vals[p], vals[p + 1], vals[p + 2], vals[p + 3]);
        let d0 = &dense.row(cols[p] as usize)[..w];
        let d1 = &dense.row(cols[p + 1] as usize)[..w];
        let d2 = &dense.row(cols[p + 2] as usize)[..w];
        let d3 = &dense.row(cols[p + 3] as usize)[..w];
        // No zero skip here — the reference applies every stored entry —
        // so the fast path is unconditional.
        for (j, o) in dst.iter_mut().enumerate() {
            *o = *o + x0 * d0[j] + x1 * d1[j] + x2 * d2[j] + x3 * d3[j];
        }
        p += ACC_BLOCK;
    }
    for pp in p..n {
        let x = vals[pp];
        let drow = &dense.row(cols[pp] as usize)[..w];
        for (o, &d) in dst.iter_mut().zip(drow) {
            *o += x * d;
        }
    }
}

// ---------------------------------------------------------------------------
// Shape B: dense-transpose × dense panel
// ---------------------------------------------------------------------------

/// `out = yrowᵀ · H` (overwrites `out`): one packed row of `Y_kᵀ` against
/// the `R×R` factor — the `Z_k = Y_kᵀ H` row kernel of the mode-2/mode-3
/// sweeps. Bitwise identical to [`reference::zt_row`].
pub fn zt_row(yrow: &[f64], h: &Mat, out: &mut [f64]) {
    debug_assert_eq!(yrow.len(), h.rows(), "yrow/H row mismatch");
    debug_assert_eq!(out.len(), h.cols(), "out width mismatch");
    dispatch_width!(h.cols(), zt_row_mono, zt_row_body, (yrow, h, out));
}

#[inline(always)]
fn zt_row_mono<const W: usize>(yrow: &[f64], h: &Mat, out: &mut [f64]) {
    zt_row_body(yrow, h, out, W);
}

#[inline(always)]
fn zt_row_body(yrow: &[f64], h: &Mat, out: &mut [f64], w: usize) {
    let out = &mut out[..w];
    out.fill(0.0);
    let n = yrow.len();
    let mut i = 0usize;
    while i + ACC_BLOCK <= n {
        let (y0, y1, y2, y3) = (yrow[i], yrow[i + 1], yrow[i + 2], yrow[i + 3]);
        let h0 = &h.row(i)[..w];
        let h1 = &h.row(i + 1)[..w];
        let h2 = &h.row(i + 2)[..w];
        let h3 = &h.row(i + 3)[..w];
        if y0 != 0.0 && y1 != 0.0 && y2 != 0.0 && y3 != 0.0 {
            for (j, o) in out.iter_mut().enumerate() {
                *o = *o + y0 * h0[j] + y1 * h1[j] + y2 * h2[j] + y3 * h3[j];
            }
        } else {
            for (y, hr) in [(y0, h0), (y1, h1), (y2, h2), (y3, h3)] {
                if y == 0.0 {
                    continue;
                }
                for (o, &hv) in out.iter_mut().zip(hr) {
                    *o += y * hv;
                }
            }
        }
        i += ACC_BLOCK;
    }
    for ii in i..n {
        let yv = yrow[ii];
        if yv == 0.0 {
            continue;
        }
        let hrow = &h.row(ii)[..w];
        for (o, &hv) in out.iter_mut().zip(hrow) {
            *o += yv * hv;
        }
    }
}

/// `c += AᵀB` without materializing `Aᵀ` (outer products over rows of
/// `A`, 4 rows in flight). Bitwise identical to [`reference::atb`].
pub fn atb_into(a: &Mat, b: &Mat, c: &mut Mat) {
    let (ka, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "atb inner-dim mismatch");
    assert_eq!(c.shape(), (m, n), "atb output shape mismatch");
    let mut k = 0usize;
    while k + ACC_BLOCK <= ka {
        let a0 = a.row(k);
        let a1 = a.row(k + 1);
        let a2 = a.row(k + 2);
        let a3 = a.row(k + 3);
        let b0 = b.row(k);
        let b1 = b.row(k + 1);
        let b2 = b.row(k + 2);
        let b3 = b.row(k + 3);
        for i in 0..m {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            let crow = c.row_mut(i);
            if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                for (j, cv) in crow.iter_mut().enumerate() {
                    *cv = *cv + x0 * b0[j] + x1 * b1[j] + x2 * b2[j] + x3 * b3[j];
                }
            } else {
                for (x, br) in [(x0, b0), (x1, b1), (x2, b2), (x3, b3)] {
                    if x == 0.0 {
                        continue;
                    }
                    for (cv, &bv) in crow.iter_mut().zip(br) {
                        *cv += x * bv;
                    }
                }
            }
        }
        k += ACC_BLOCK;
    }
    for kk in k..ka {
        let arow = a.row(kk);
        let brow = b.row(kk);
        for (i, &aki) in arow.iter().enumerate() {
            if aki == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aki * bv;
            }
        }
    }
}

/// `g += AᵀA`: upper triangle with 4 rows of `A` in flight, then mirror.
/// Bitwise identical to [`reference::gram`].
pub fn gram_into(a: &Mat, g: &mut Mat) {
    let (k, n) = a.shape();
    assert_eq!(g.shape(), (n, n), "gram output shape mismatch");
    let mut r = 0usize;
    while r + ACC_BLOCK <= k {
        let r0 = a.row(r);
        let r1 = a.row(r + 1);
        let r2 = a.row(r + 2);
        let r3 = a.row(r + 3);
        for i in 0..n {
            let (x0, x1, x2, x3) = (r0[i], r1[i], r2[i], r3[i]);
            let grow = &mut g.row_mut(i)[i..];
            let (t0, t1, t2, t3) = (&r0[i..], &r1[i..], &r2[i..], &r3[i..]);
            if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                for (j, gv) in grow.iter_mut().enumerate() {
                    *gv = *gv + x0 * t0[j] + x1 * t1[j] + x2 * t2[j] + x3 * t3[j];
                }
            } else {
                for (x, tr) in [(x0, t0), (x1, t1), (x2, t2), (x3, t3)] {
                    if x == 0.0 {
                        continue;
                    }
                    for (gv, &tv) in grow.iter_mut().zip(tr) {
                        *gv += x * tv;
                    }
                }
            }
        }
        r += ACC_BLOCK;
    }
    for rr in r..k {
        let row = a.row(rr);
        for i in 0..n {
            let ai = row[i];
            if ai == 0.0 {
                continue;
            }
            let grow = g.row_mut(i);
            for j in i..n {
                grow[j] += ai * row[j];
            }
        }
    }
    mirror_upper(g);
}

// ---------------------------------------------------------------------------
// Reordered family
// ---------------------------------------------------------------------------

/// Dot product with 4 independent accumulators (breaks the dependency
/// chain so several FMAs stay in flight). **Reordered** relative to
/// [`reference::dot_seq`]: ULP-bounded, not bitwise — see the module
/// docs' determinism contract.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += x[i] * y[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn bits_eq(a: &Mat, b: &Mat) -> bool {
        a.shape() == b.shape()
            && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    fn random_support(rng: &mut Pcg64, c: usize, j: usize) -> Vec<u32> {
        assert!(c <= j);
        let mut ids: Vec<u32> = (0..j as u32).collect();
        rng.shuffle(&mut ids);
        ids.truncate(c);
        ids.sort_unstable();
        ids
    }

    /// yt with exact zeros sprinkled in (exercises both skip paths).
    fn random_yt(rng: &mut Pcg64, c: usize, r: usize) -> Mat {
        Mat::from_fn(c, r, |_, _| if rng.chance(0.2) { 0.0 } else { rng.normal() })
    }

    /// One fast unit-level guard per kernel. The *exhaustive* differential
    /// sweeps (R ∈ {1..=16, 17, 32}, ragged/empty operands, zero /
    /// denormal / NaN regimes) live in `rust/tests/kernel_conformance.rs`
    /// — this smoke test only keeps `cargo test --lib` self-contained.
    #[test]
    fn blocked_kernels_smoke_bitwise() {
        let mut rng = Pcg64::seed(601);
        let (r, c) = (7usize, 9usize); // block + ragged tail, unrolled width
        let j = c + 5;
        let support = random_support(&mut rng, c, j);
        let yt = random_yt(&mut rng, c, r);
        let v = Mat::rand_normal(j, r, &mut rng);
        let mut blocked = Mat::zeros(r, r);
        let mut refr = Mat::zeros(r, r);
        spmm_yt_v(&yt, &support, &v, &mut blocked);
        reference::spmm_yt_v(&yt, &support, &v, &mut refr);
        assert!(bits_eq(&blocked, &refr), "spmm");

        let h = Mat::rand_normal(r, r, &mut rng);
        let yrow: Vec<f64> =
            (0..r).map(|_| if rng.chance(0.3) { 0.0 } else { rng.normal() }).collect();
        let mut z_blocked = vec![1.0f64; r]; // nonzero: zt_row must overwrite
        let mut z_ref = vec![2.0f64; r];
        zt_row(&yrow, &h, &mut z_blocked);
        reference::zt_row(&yrow, &h, &mut z_ref);
        for (x, y) in z_blocked.iter().zip(&z_ref) {
            assert_eq!(x.to_bits(), y.to_bits(), "zt_row");
        }

        let a = random_yt(&mut rng, c, r);
        let b = random_yt(&mut rng, c, r);
        let mut c_blocked = Mat::zeros(r, r);
        let mut c_ref = Mat::zeros(r, r);
        atb_into(&a, &b, &mut c_blocked);
        reference::atb(&a, &b, &mut c_ref);
        assert!(bits_eq(&c_blocked, &c_ref), "atb");
        let mut g_blocked = Mat::zeros(r, r);
        let mut g_ref = Mat::zeros(r, r);
        gram_into(&a, &mut g_blocked);
        reference::gram(&a, &mut g_ref);
        assert!(bits_eq(&g_blocked, &g_ref), "gram");

        let cols: Vec<u32> = (0..c).map(|_| rng.range(0, j) as u32).collect();
        let vals: Vec<f64> = (0..c).map(|_| rng.normal()).collect();
        let dense = Mat::rand_normal(j, r, &mut rng);
        let mut s_blocked = vec![0.5f64; r];
        let mut s_ref = vec![0.5f64; r];
        sparse_row_axpy(&vals, &cols, &dense, &mut s_blocked);
        reference::sparse_row_axpy(&vals, &cols, &dense, &mut s_ref);
        for (x, y) in s_blocked.iter().zip(&s_ref) {
            assert_eq!(x.to_bits(), y.to_bits(), "sparse_row_axpy");
        }
    }

    #[test]
    fn dot_matches_seq_on_exact_inputs() {
        // integer-valued inputs: both orders are exact
        for n in 0..20 {
            let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let y: Vec<f64> = (0..n).map(|i| (2 * i) as f64).collect();
            assert_eq!(dot(&x, &y), reference::dot_seq(&x, &y));
        }
    }
}
