//! Matrix-multiply kernels for row-major [`Mat`].
//!
//! The whole native hot path of SPARTan reduces to small/medium GEMMs
//! (`Y_k V` is `R×c_k · c_k×R` with R ≤ 64), so these kernels matter. The
//! main loop order is `i-k-j` ("axpy" form): for row-major storage the
//! inner `j` loop streams both `B.row(k)` and `C.row(i)` contiguously,
//! which LLVM auto-vectorizes well. A panel-blocked variant kicks in for
//! larger operands to keep the B panel in L1/L2.
//!
//! The transpose-times-panel forms ([`matmul_at_b`], [`gram`]) and the
//! [`dot`] reduction delegate to the register-blocked micro-kernels in
//! [`super::kernels`] — the single dispatch point for the ALS hot shapes.
//! `matmul_at_b`/`gram` sit in the order-preserving family (bitwise
//! identical to the scalar references); `dot` is in the reordered,
//! ULP-bounded family (see the kernel module's determinism contract).

use super::dense::Mat;
use super::kernels;

/// Tunable blocking parameters (also exercised by the ablation bench).
const BLOCK_K: usize = 128;
const BLOCK_J: usize = 256;

/// C = A · B.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_acc(&mut c, a, b, 1.0);
    c
}

/// C += alpha · A · B  (C must already have the right shape).
pub fn gemm_acc(c: &mut Mat, a: &Mat, b: &Mat, alpha: f64) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "gemm inner-dim mismatch: {ka} vs {kb}");
    assert_eq!(c.shape(), (m, n), "gemm output shape mismatch");
    if m == 0 || n == 0 || ka == 0 {
        return;
    }
    // Small problems: straight i-k-j, no blocking overhead.
    if ka <= BLOCK_K && n <= BLOCK_J {
        for i in 0..m {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for (k, &aik) in arow.iter().enumerate() {
                let s = alpha * aik;
                if s == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += s * bv;
                }
            }
        }
        return;
    }
    // Blocked: panels of B (BLOCK_K × BLOCK_J) stay cache-resident across
    // the full sweep over rows of A.
    let mut k0 = 0;
    while k0 < ka {
        let k1 = (k0 + BLOCK_K).min(ka);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + BLOCK_J).min(n);
            for i in 0..m {
                let arow = &a.row(i)[k0..k1];
                let crow = &mut c.row_mut(i)[j0..j1];
                for (k, &aik) in arow.iter().enumerate() {
                    let s = alpha * aik;
                    if s == 0.0 {
                        continue;
                    }
                    let brow = &b.row(k0 + k)[j0..j1];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += s * bv;
                    }
                }
            }
            j0 = j1;
        }
        k0 = k1;
    }
}

/// C = Aᵀ · B without materializing Aᵀ.
///
/// For row-major A this is again an `i(k)-j` streaming pattern: row k of A
/// contributes outer products `A(k,:)ᵀ · B(k,:)`. Runs on the
/// register-blocked [`kernels::atb_into`] (4 rows of A in flight; bitwise
/// identical to the scalar form).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    let (ka, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "atb inner-dim mismatch");
    let mut c = Mat::zeros(m, n);
    kernels::atb_into(a, b, &mut c);
    c
}

/// C = A · Bᵀ without materializing Bᵀ (inner loop is a dot product of two
/// contiguous rows).
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    let (m, ka) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(ka, kb, "abt inner-dim mismatch");
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..n {
            crow[j] = dot(arow, b.row(j));
        }
    }
    c
}

/// Gram matrix AᵀA (symmetric; computes upper triangle and mirrors).
/// Runs on the register-blocked [`kernels::gram_into`] (bitwise identical
/// to the scalar form).
pub fn gram(a: &Mat) -> Mat {
    let n = a.cols();
    let mut g = Mat::zeros(n, n);
    kernels::gram_into(a, &mut g);
    g
}

/// Dot product of two equal-length slices ([`kernels::dot`]: 4 independent
/// accumulators, the kernel layer's reordered / ULP-bounded family).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    kernels::dot(x, y)
}

/// y = xᵀ·A for a row vector x (length = A.rows()); returns length A.cols().
pub fn vec_mat(x: &[f64], a: &Mat) -> Vec<f64> {
    assert_eq!(x.len(), a.rows());
    let mut y = vec![0.0; a.cols()];
    for (k, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        for (yv, &av) in y.iter_mut().zip(a.row(k)) {
            *yv += xv * av;
        }
    }
    y
}

/// y = A·x for a column vector x (length = A.cols()); returns length A.rows().
pub fn mat_vec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), a.cols());
    (0..a.rows()).map(|i| dot(a.row(i), x)).collect()
}

/// Hadamard (element-wise) product of two matrices.
pub fn hadamard(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.shape(), b.shape());
    let mut c = a.clone();
    for (cv, &bv) in c.data_mut().iter_mut().zip(b.data()) {
        *cv *= bv;
    }
    c
}

/// Multiply each row of `a` element-wise by the vector `w` in place
/// (the `rowhad` epilogue of SPARTan's mode-1 kernel).
pub fn rowhad_inplace(a: &mut Mat, w: &[f64]) {
    assert_eq!(a.cols(), w.len());
    for i in 0..a.rows() {
        for (av, &wv) in a.row_mut(i).iter_mut().zip(w) {
            *av *= wv;
        }
    }
}

/// Khatri-Rao product (column-wise Kronecker): A ∈ m×r, B ∈ n×r → mn×r.
/// Only used by reference implementations and the baseline comparator —
/// SPARTan's whole point is *not* materializing this.
pub fn khatri_rao(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "khatri-rao rank mismatch");
    let (m, r) = a.shape();
    let n = b.rows();
    let mut out = Mat::zeros(m * n, r);
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            let brow = b.row(j);
            let orow = out.row_mut(i * n + j);
            for c in 0..r {
                orow[c] = arow[c] * brow[c];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|k| a[(i, k)] * b[(k, j)]).sum()
        })
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut rng = Pcg64::seed(5);
        for (m, k, n) in [(1, 1, 1), (3, 4, 5), (17, 33, 9), (40, 300, 40), (130, 260, 300)] {
            let a = Mat::rand_normal(m, k, &mut rng);
            let b = Mat::rand_normal(k, n, &mut rng);
            let c = matmul(&a, &b);
            let want = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&want) < 1e-9, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn gemm_acc_accumulates_with_alpha() {
        let mut rng = Pcg64::seed(6);
        let a = Mat::rand_normal(4, 6, &mut rng);
        let b = Mat::rand_normal(6, 3, &mut rng);
        let mut c = Mat::rand_normal(4, 3, &mut rng);
        let c0 = c.clone();
        gemm_acc(&mut c, &a, &b, 2.5);
        let mut want = naive_matmul(&a, &b);
        want.scale(2.5);
        want.axpy(1.0, &c0);
        assert!(c.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn at_b_and_a_bt_match_explicit_transpose() {
        let mut rng = Pcg64::seed(7);
        let a = Mat::rand_normal(8, 5, &mut rng);
        let b = Mat::rand_normal(8, 6, &mut rng);
        assert!(matmul_at_b(&a, &b).max_abs_diff(&matmul(&a.transpose(), &b)) < 1e-10);
        let b2 = Mat::rand_normal(6, 5, &mut rng);
        assert!(matmul_a_bt(&a, &b2).max_abs_diff(&matmul(&a, &b2.transpose())) < 1e-10);
    }

    #[test]
    fn gram_is_symmetric_and_correct() {
        let mut rng = Pcg64::seed(8);
        let a = Mat::rand_normal(20, 7, &mut rng);
        let g = gram(&a);
        let want = matmul(&a.transpose(), &a);
        assert!(g.max_abs_diff(&want) < 1e-9);
        for i in 0..7 {
            for j in 0..7 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for n in 0..10 {
            let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let y: Vec<f64> = (0..n).map(|i| (i * 2) as f64).collect();
            let want: f64 = (0..n).map(|i| (i * i * 2) as f64).sum();
            assert_eq!(dot(&x, &y), want);
        }
    }

    #[test]
    fn vec_mat_mat_vec() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(vec_mat(&[1.0, 0.0, 2.0], &a), vec![11.0, 14.0]);
        assert_eq!(mat_vec(&a, &[2.0, 1.0]), vec![4.0, 10.0, 16.0]);
    }

    #[test]
    fn hadamard_and_rowhad() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        assert_eq!(hadamard(&a, &b).data(), &[5.0, 12.0, 21.0, 32.0]);
        let mut c = a.clone();
        rowhad_inplace(&mut c, &[10.0, 100.0]);
        assert_eq!(c.data(), &[10.0, 200.0, 30.0, 400.0]);
    }

    #[test]
    fn khatri_rao_definition() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]); // 2x2
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0], &[9.0, 10.0]]); // 3x2
        let kr = khatri_rao(&a, &b); // 6x2
        assert_eq!(kr.shape(), (6, 2));
        // first block = a(0,:) scaled rows of b
        assert_eq!(kr.row(0), &[5.0, 12.0]);
        assert_eq!(kr.row(2), &[9.0, 20.0]);
        // second block
        assert_eq!(kr.row(3), &[15.0, 24.0]);
        assert_eq!(kr.row(5), &[27.0, 40.0]);
    }
}
