//! ASCII table renderer for bench output (mirrors the paper's tables).

/// Render rows as an aligned ASCII table with a header row.
pub fn render(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), ncols, "row arity mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            out.push('+');
            for _ in 0..w + 2 {
                out.push('-');
            }
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    out.push('|');
    for (h, w) in header.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    sep(&mut out);
    for row in rows {
        out.push('|');
        for (cell, w) in row.iter().zip(&widths) {
            out.push_str(&format!(" {cell:<w$} |"));
        }
        out.push('\n');
    }
    sep(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let t = render(
            &["name", "time"],
            &[
                vec!["spartan".into(), "1.2s".into()],
                vec!["baseline".into(), "22.4s".into()],
            ],
        );
        assert!(t.contains("| spartan "));
        assert!(t.contains("| baseline "));
        // all lines same width
        let lens: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        render(&["a", "b"], &[vec!["x".into()]]);
    }
}
