//! Bench-trend diffing: the logic behind `spartan bench-diff` and CI's
//! `bench-trend` gate.
//!
//! Both sides are directories of `bench_results/*.json` files (the schema
//! in [`super`]): the *old* side is the previous run's
//! `bench-results-<sha>` artifact (or the committed `BENCH_*.json`
//! history seeds on a first run), the *new* side is the current run.
//! Cells are keyed `<bench>/<measurement name>` — with `@<backend>`
//! appended when the measurement records a `backend` (the SIMD A/B
//! cells), so runs that differ only in a config field are treated as
//! distinct cells (added/removed) instead of being mis-compared against
//! each other. Each cell's statistic is the **median** of its raw
//! `iter_secs` samples (medians shrug off the single-iteration outliers
//! that shared CI runners love to produce; `mean_secs` is the fallback
//! for measurements without samples).
//!
//! Classification per cell, with `max_regress` (CI: 0.10) and `min_iters`
//! (CI: 5):
//!
//! * new median > old × (1 + max_regress) and both sides have ≥
//!   `min_iters` samples → **regression** (the gate fails);
//! * over the threshold but either side has fewer samples → **warn-only**
//!   (too noisy to block on);
//! * new median < old × (1 − max_regress) → improvement (reported);
//! * cells present on only one side → added/removed (reported, never
//!   fatal — benches come and go with the code).
//!
//! An empty old side (genuinely first run) gates nothing: every cell is
//! "added" and the exit is clean, so the trend job bootstraps itself.

use crate::util::json::{self, Json};
use crate::util::timer::fmt_secs;
use std::collections::BTreeMap;
use std::path::Path;

/// One comparable bench cell.
#[derive(Clone, Debug, PartialEq)]
pub struct Cell {
    /// `<bench>/<measurement name>`, plus `@<backend>` when the
    /// measurement carries a `backend` field.
    pub id: String,
    /// Median of the raw per-iteration wall times (or `mean_secs`).
    pub median_secs: f64,
    /// Number of samples behind the median.
    pub iters: usize,
}

/// Median of a non-empty sample set (average of the two middles for even
/// lengths).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty sample set");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
    let n = s.len();
    if n % 2 == 1 {
        s[n / 2]
    } else {
        0.5 * (s[n / 2 - 1] + s[n / 2])
    }
}

/// Extract the cells of one parsed `bench_results/*.json` document.
pub fn cells_from_json(doc: &Json) -> Vec<Cell> {
    let bench = doc.get("bench").and_then(|j| j.as_str()).unwrap_or("?").to_string();
    let mut out = Vec::new();
    let Some(ms) = doc.get("measurements").and_then(|j| j.as_arr()) else {
        return out;
    };
    for m in ms {
        let Some(name) = m.get("name").and_then(|j| j.as_str()) else {
            continue;
        };
        let samples: Vec<f64> = m
            .get("iter_secs")
            .and_then(|j| j.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .unwrap_or_default();
        let (median_secs, iters) = if samples.is_empty() {
            match m.get("mean_secs").and_then(|j| j.as_f64()) {
                Some(x) => (x, m.get("iters").and_then(|j| j.as_usize()).unwrap_or(1)),
                None => continue,
            }
        } else {
            (median(&samples), samples.len())
        };
        // Config fields that change what a measurement *is* must split
        // the cell id — otherwise an old `name` cell would be diffed
        // against a new, differently-configured run of the same name.
        let id = match m.get("backend").and_then(|j| j.as_str()) {
            Some(backend) => format!("{bench}/{name}@{backend}"),
            None => format!("{bench}/{name}"),
        };
        out.push(Cell { id, median_secs, iters });
    }
    out
}

/// Load every `*.json` under `dir` (sorted for stable output order). A
/// missing directory is an error; an empty one is an empty baseline.
pub fn load_cells(dir: &Path) -> Result<Vec<Cell>, String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
    let mut paths: Vec<_> = rd
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map_or(false, |x| x == "json"))
        .collect();
    paths.sort();
    let mut cells = Vec::new();
    for p in paths {
        let text =
            std::fs::read_to_string(&p).map_err(|e| format!("reading {}: {e}", p.display()))?;
        let doc = json::parse(&text).map_err(|e| format!("{}: {e}", p.display()))?;
        cells.extend(cells_from_json(&doc));
    }
    Ok(cells)
}

/// One old-vs-new cell delta.
#[derive(Clone, Debug)]
pub struct Delta {
    pub id: String,
    pub old_secs: f64,
    pub new_secs: f64,
    /// `new/old − 1` (positive = slower).
    pub frac: f64,
    /// min(old iters, new iters) — the confidence proxy.
    pub iters: usize,
}

/// Full classification of a diff.
#[derive(Clone, Debug, Default)]
pub struct TrendReport {
    pub regressions: Vec<Delta>,
    /// Over the threshold but under `min_iters` samples: warn-only.
    pub warned: Vec<Delta>,
    pub improved: Vec<Delta>,
    pub steady: usize,
    pub added: Vec<String>,
    pub removed: Vec<String>,
}

/// Diff two cell sets.
pub fn diff(old: &[Cell], new: &[Cell], max_regress: f64, min_iters: usize) -> TrendReport {
    let old_map: BTreeMap<&str, &Cell> = old.iter().map(|c| (c.id.as_str(), c)).collect();
    let new_map: BTreeMap<&str, &Cell> = new.iter().map(|c| (c.id.as_str(), c)).collect();
    let mut rep = TrendReport::default();
    for (id, n) in &new_map {
        let Some(o) = old_map.get(id) else {
            rep.added.push((*id).to_string());
            continue;
        };
        if o.median_secs <= 0.0 {
            rep.steady += 1; // degenerate baseline: nothing to gate on
            continue;
        }
        let d = Delta {
            id: (*id).to_string(),
            old_secs: o.median_secs,
            new_secs: n.median_secs,
            frac: n.median_secs / o.median_secs - 1.0,
            iters: o.iters.min(n.iters),
        };
        if d.frac > max_regress {
            if d.iters < min_iters {
                rep.warned.push(d);
            } else {
                rep.regressions.push(d);
            }
        } else if d.frac < -max_regress {
            rep.improved.push(d);
        } else {
            rep.steady += 1;
        }
    }
    for id in old_map.keys() {
        if !new_map.contains_key(id) {
            rep.removed.push((*id).to_string());
        }
    }
    rep
}

fn delta_line(tag: &str, d: &Delta) -> String {
    format!(
        "{tag} {} {:+.1}% ({} → {}, {} iters)\n",
        d.id,
        d.frac * 100.0,
        fmt_secs(d.old_secs),
        fmt_secs(d.new_secs),
        d.iters
    )
}

/// Human-readable report (one line per noteworthy cell + a summary).
pub fn render(rep: &TrendReport, max_regress: f64, min_iters: usize) -> String {
    let mut s = String::new();
    for d in &rep.regressions {
        s.push_str(&delta_line("REGRESSION", d));
    }
    for d in &rep.warned {
        s.push_str(&delta_line(&format!("warn (<{min_iters} iters)"), d));
    }
    for d in &rep.improved {
        s.push_str(&delta_line("improved", d));
    }
    for id in &rep.added {
        s.push_str(&format!("new cell {id}\n"));
    }
    for id in &rep.removed {
        s.push_str(&format!("removed cell {id}\n"));
    }
    s.push_str(&format!(
        "bench-diff: {} regression(s) past {:.0}%, {} warn-only, {} improved, {} steady, {} new, {} removed\n",
        rep.regressions.len(),
        max_regress * 100.0,
        rep.warned.len(),
        rep.improved.len(),
        rep.steady,
        rep.added.len(),
        rep.removed.len()
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(id: &str, med: f64, iters: usize) -> Cell {
        Cell { id: id.into(), median_secs: med, iters }
    }

    #[test]
    fn median_odd_even_and_outlier_resistance() {
        assert_eq!(median(&[3.0]), 3.0);
        assert_eq!(median(&[1.0, 3.0]), 2.0);
        assert_eq!(median(&[9.0, 1.0, 2.0]), 2.0);
        // one 100× outlier does not move the median
        assert_eq!(median(&[1.0, 1.0, 1.0, 1.0, 100.0]), 1.0);
    }

    #[test]
    fn diff_classifies_cells() {
        let old = vec![
            cell("a/x", 1.0, 5),
            cell("a/noisy", 1.0, 2),
            cell("a/fast", 1.0, 5),
            cell("a/flat", 1.0, 5),
            cell("a/gone", 1.0, 5),
        ];
        let new = vec![
            cell("a/x", 1.2, 5),     // +20% with enough iters → regression
            cell("a/noisy", 1.5, 2), // +50% but 2 iters → warn-only
            cell("a/fast", 0.5, 5),  // −50% → improved
            cell("a/flat", 1.05, 5), // +5% → steady
            cell("a/new", 1.0, 5),   // no baseline → added
        ];
        let rep = diff(&old, &new, 0.10, 5);
        assert_eq!(rep.regressions.len(), 1);
        assert_eq!(rep.regressions[0].id, "a/x");
        assert!((rep.regressions[0].frac - 0.2).abs() < 1e-12);
        assert_eq!(rep.warned.len(), 1);
        assert_eq!(rep.warned[0].id, "a/noisy");
        assert_eq!(rep.improved.len(), 1);
        assert_eq!(rep.steady, 1);
        assert_eq!(rep.added, vec!["a/new".to_string()]);
        assert_eq!(rep.removed, vec!["a/gone".to_string()]);
        let text = render(&rep, 0.10, 5);
        assert!(text.contains("REGRESSION a/x"), "{text}");
        assert!(text.contains("1 regression(s)"), "{text}");
    }

    #[test]
    fn empty_baseline_gates_nothing() {
        let new = vec![cell("a/x", 1.0, 5)];
        let rep = diff(&[], &new, 0.10, 5);
        assert!(rep.regressions.is_empty());
        assert_eq!(rep.added.len(), 1);
    }

    #[test]
    fn cells_from_json_prefers_iter_secs_median() {
        let doc = json::parse(
            r#"{"bench": "b", "measurements": [
                {"name": "m", "iters": 3, "mean_secs": 9.0,
                 "iter_secs": [1.0, 100.0, 2.0]},
                {"name": "no_samples", "iters": 4, "mean_secs": 0.5,
                 "iter_secs": []},
                {"name": "useless"}
            ]}"#,
        )
        .unwrap();
        let cells = cells_from_json(&doc);
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0], cell("b/m", 2.0, 3)); // median, not the mean
        assert_eq!(cells[1], cell("b/no_samples", 0.5, 4)); // mean fallback
    }

    #[test]
    fn backend_field_splits_the_cell_id() {
        let doc = json::parse(
            r#"{"bench": "b", "measurements": [
                {"name": "m", "backend": "avx2", "iter_secs": [1.0]},
                {"name": "m", "backend": "blocked", "iter_secs": [2.0]},
                {"name": "m", "iter_secs": [3.0]}
            ]}"#,
        )
        .unwrap();
        let cells = cells_from_json(&doc);
        assert_eq!(
            cells,
            vec![
                cell("b/m@avx2", 1.0, 1),
                cell("b/m@blocked", 2.0, 1),
                cell("b/m", 3.0, 1),
            ]
        );
        // a backend added to an existing measurement is a new cell, not
        // a comparison against the un-suffixed old one
        let rep = diff(&[cells[2].clone()], &cells[..2].to_vec(), 0.10, 1);
        assert!(rep.regressions.is_empty(), "{rep:?}");
        assert_eq!(rep.added.len(), 2);
        assert_eq!(rep.removed, vec!["b/m".to_string()]);
    }

    #[test]
    fn load_cells_reads_a_directory_and_skips_non_json() {
        let dir = std::env::temp_dir().join("spartan_trend_load_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("one.json"),
            r#"{"bench": "one", "measurements": [{"name": "m", "iter_secs": [0.5]}]}"#,
        )
        .unwrap();
        std::fs::write(dir.join("notes.txt"), "not json").unwrap();
        let cells = load_cells(&dir).unwrap();
        assert_eq!(cells, vec![cell("one/m", 0.5, 1)]);
        std::fs::remove_dir_all(&dir).ok();
        assert!(load_cells(&dir).is_err(), "missing dir is an error");
    }

    #[test]
    fn seed_snapshot_is_a_valid_empty_baseline() {
        // The committed bench_results/BENCH_SEED.json must parse and
        // contribute zero cells (history bootstrap contract of the CI
        // bench-trend job).
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("bench_results/BENCH_SEED.json");
        let text = std::fs::read_to_string(&path).expect("committed seed snapshot");
        let doc = json::parse(&text).expect("seed snapshot JSON");
        assert!(cells_from_json(&doc).is_empty());
    }
}
