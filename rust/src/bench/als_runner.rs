//! Shared machinery for the paper-reproduction benches: time PARAFAC2-ALS
//! *per iteration* (the paper's metric — "time in minutes of one
//! iteration", Tables 1 / Figs 5–7), with warmup-iteration discard and an
//! OoM-aware result type for the baseline columns.

use crate::parafac2::als::{fit_parafac2_traced, Backend, Parafac2Config};
use crate::sparse::IrregularTensor;

/// Outcome of one benchmark cell.
#[derive(Clone, Debug)]
pub enum CellResult {
    /// Mean seconds per ALS iteration (after warmup discard) + iteration count.
    Time { secs_per_iter: f64, iters: usize },
    /// The engine exhausted its memory budget — the paper's "OoM".
    OutOfMemory,
}

impl CellResult {
    pub fn render(&self) -> String {
        match self {
            CellResult::Time { secs_per_iter, .. } => {
                crate::util::timer::fmt_secs(*secs_per_iter)
            }
            CellResult::OutOfMemory => "OoM".to_string(),
        }
    }

    pub fn secs(&self) -> Option<f64> {
        match self {
            CellResult::Time { secs_per_iter, .. } => Some(*secs_per_iter),
            CellResult::OutOfMemory => None,
        }
    }
}

/// Iterations measured per cell (plus 1 discarded warmup iteration).
/// `SPARTAN_BENCH_FAST=1` drops to a single measured iteration. The paper
/// averages 10 iterations; on this single-core testbed we average
/// `measure` (per-iteration variance of ALS is ≪ the cross-method gaps —
/// recorded in EXPERIMENTS.md).
pub fn bench_iters() -> (usize, usize) {
    if std::env::var("SPARTAN_BENCH_FAST").as_deref() == Ok("1") {
        (1, 1) // warmup, measured
    } else {
        (1, 3)
    }
}

/// Time one engine on one dataset: returns mean secs/iter or OoM.
pub fn time_als(
    data: &IrregularTensor,
    rank: usize,
    backend: Backend,
    mem_budget: Option<u64>,
) -> CellResult {
    let (warmup, measure) = bench_iters();
    let cfg = Parafac2Config {
        rank,
        max_iters: warmup + measure,
        tol: 0.0, // never converge early — we're timing iterations
        nonneg: true,
        workers: 0,
        seed: 42,
        backend,
        mem_budget,
        ..Default::default()
    };
    let mut iter_secs: Vec<f64> = Vec::new();
    let res = fit_parafac2_traced(data, &cfg, &mut |rec| {
        iter_secs.push(rec.procrustes_secs + rec.cp_secs);
    });
    match res {
        Ok(_) => {
            let measured = &iter_secs[warmup.min(iter_secs.len().saturating_sub(1))..];
            let mean = measured.iter().sum::<f64>() / measured.len().max(1) as f64;
            CellResult::Time { secs_per_iter: mean, iters: measured.len() }
        }
        Err(crate::parafac2::FitError::OutOfMemory(_)) => CellResult::OutOfMemory,
        Err(e) => panic!("bench fit failed: {e}"),
    }
}

/// Per-iteration (SSE, fit) trajectory of a fit — used to check that the
/// fused sweep's convergence path is deterministic: bitwise identical
/// across worker counts (chunk-ordered reductions guarantee it).
pub fn fit_trajectory(
    data: &IrregularTensor,
    rank: usize,
    backend: Backend,
    workers: usize,
    iters: usize,
) -> Vec<(f64, f64)> {
    let cfg = Parafac2Config {
        rank,
        max_iters: iters,
        tol: 0.0,
        nonneg: true,
        workers,
        seed: 42,
        backend,
        mem_budget: None,
        ..Default::default()
    };
    let mut traj = Vec::with_capacity(iters);
    fit_parafac2_traced(data, &cfg, &mut |rec| traj.push((rec.sse, rec.fit)))
        .expect("trajectory fit failed");
    traj
}

/// Speedup string "N.N×" for a (spartan, baseline) pair.
pub fn speedup(spartan: &CellResult, baseline: &CellResult) -> String {
    match (spartan.secs(), baseline.secs()) {
        (Some(s), Some(b)) if s > 0.0 => format!("{:.1}×", b / s),
        (Some(_), None) => "∞ (baseline OoM)".to_string(),
        _ => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::synthetic::{generate, SyntheticSpec};

    #[test]
    fn time_als_measures_and_reports() {
        let data = generate(&SyntheticSpec {
            k: 30,
            j: 15,
            max_i_k: 6,
            target_nnz: 2_000,
            rank: 2,
            noise: 0.0,
            seed: 1,
        })
        .tensor;
        let r = time_als(&data, 2, Backend::Spartan, None);
        match r {
            CellResult::Time { secs_per_iter, iters } => {
                assert!(secs_per_iter >= 0.0);
                assert!(iters >= 1);
            }
            _ => panic!("expected time"),
        }
        assert!(!r.render().is_empty());
    }

    #[test]
    fn table1_config_trajectory_bitwise_deterministic_across_workers() {
        // A scaled-down instance of the Table-1 synthetic config (same
        // generator, same density profile as benches/table1_synthetic.rs):
        // the fused sweep must produce the exact same SSE/fit trajectory
        // at every worker count — bitwise, not approximately.
        let data = generate(&SyntheticSpec {
            k: 126,
            j: 50,
            max_i_k: 10,
            target_nnz: 12_000,
            rank: 4,
            noise: 0.01,
            seed: 42,
        })
        .tensor;
        let reference = fit_trajectory(&data, 4, Backend::Spartan, 1, 6);
        assert_eq!(reference.len(), 6);
        for workers in [2usize, 4, 7] {
            let traj = fit_trajectory(&data, 4, Backend::Spartan, workers, 6);
            for (i, (a, b)) in reference.iter().zip(&traj).enumerate() {
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "SSE iter {i}, {workers} workers");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "fit iter {i}, {workers} workers");
            }
        }
    }

    #[test]
    fn oom_cell_renders() {
        let data = generate(&SyntheticSpec {
            k: 20,
            j: 10,
            max_i_k: 5,
            target_nnz: 1_000,
            rank: 2,
            noise: 0.0,
            seed: 2,
        })
        .tensor;
        let r = time_als(&data, 2, Backend::Baseline, Some(64));
        assert!(matches!(r, CellResult::OutOfMemory));
        assert_eq!(r.render(), "OoM");
        assert_eq!(speedup(&CellResult::Time { secs_per_iter: 1.0, iters: 1 }, &r), "∞ (baseline OoM)");
    }
}
