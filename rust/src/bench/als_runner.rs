//! Shared machinery for the paper-reproduction benches: time PARAFAC2-ALS
//! *per iteration* (the paper's metric — "time in minutes of one
//! iteration", Tables 1 / Figs 5–7), with warmup-iteration discard and an
//! OoM-aware result type for the baseline columns.

use crate::bench::Measurement;
use crate::parafac2::als::{fit_parafac2_traced, Backend, Parafac2Config};
use crate::sparse::IrregularTensor;

/// Outcome of one benchmark cell.
#[derive(Clone, Debug)]
pub enum CellResult {
    /// Mean seconds per ALS iteration (after warmup discard) + iteration count.
    Time { secs_per_iter: f64, iters: usize },
    /// The engine exhausted its memory budget — the paper's "OoM".
    OutOfMemory,
}

impl CellResult {
    pub fn render(&self) -> String {
        match self {
            CellResult::Time { secs_per_iter, .. } => {
                crate::util::timer::fmt_secs(*secs_per_iter)
            }
            CellResult::OutOfMemory => "OoM".to_string(),
        }
    }

    pub fn secs(&self) -> Option<f64> {
        match self {
            CellResult::Time { secs_per_iter, .. } => Some(*secs_per_iter),
            CellResult::OutOfMemory => None,
        }
    }
}

/// Iterations measured per cell (plus 1 discarded warmup iteration).
/// **Both** modes measure at least **5** iterations per cell: CI's
/// `bench-trend` gate treats cells with fewer than 5 samples as warn-only
/// (too noisy to block on), so a smaller count — in either the
/// `SPARTAN_BENCH_FAST=1` smoke configuration *or* a full-size run whose
/// JSON later seeds a baseline — would quietly exempt every ALS-fit cell
/// from the >10% median gate. The paper averages 10 iterations; we
/// average `measure` (per-iteration variance of ALS is ≪ the
/// cross-method gaps — recorded in EXPERIMENTS.md).
pub fn bench_iters() -> (usize, usize) {
    // (warmup, measured) — measured stays ≥ trend::MIN-ITERS(5) in every
    // mode so no configuration can produce permanently warn-only cells.
    (1, 5)
}

/// One timed ALS run with its raw per-iteration wall times and the exact
/// kernel-work counters of the whole fit — everything the
/// `bench_results/*.json` schema publishes per cell.
#[derive(Clone, Debug)]
pub struct AlsRun {
    pub cell: CellResult,
    /// Wall time of every measured iteration (warmup discarded).
    pub iter_secs: Vec<f64>,
    /// Total ALS iterations the fit executed — warmup included, so this
    /// is the normalizer for the fit-wide counters below, NOT
    /// `iter_secs.len()`.
    pub fit_iters: u64,
    /// `Y_k·V` products over the whole fit (see `FitStats::yv_products`).
    pub yv_products: u64,
    /// Cold packed-slice traversals over the whole fit
    /// (see `FitStats::traversals`).
    pub traversals: u64,
    /// Cold X passes over the whole fit through the resident compact-X
    /// arena (see `FitStats::x_traversals`): K for the pack + K per
    /// iteration + K for the final report pass.
    pub x_traversals: u64,
    /// Steady-state resident footprint of the fit's data-plane arenas
    /// (see `FitStats::heap_bytes`).
    pub heap_bytes: u64,
    /// Successful mid-fit shard re-attaches (see
    /// `FitStats::shard_reconnects`). Local bench fits never shard, so
    /// this is 0 — published anyway so the chaos/recovery counters share
    /// the one bench JSON schema.
    pub shard_reconnects: u64,
    /// Reconnect attempts while recovering lost shards (see
    /// `FitStats::shard_retries`). 0 for local bench fits.
    pub shard_retries: u64,
}

impl AlsRun {
    /// Fold this run into a named [`Measurement`] carrying the raw
    /// per-iteration samples and the exact work counters (`None` for OoM
    /// cells — there is nothing to summarize). The counters are
    /// **fit-wide** (warmup iterations included), so `fit_iters` rides
    /// along as their normalizer — `yv_products / (K · fit_iters) == 1`
    /// for the SPARTan engine, even though `iters`/`iter_secs` count only
    /// the measured (post-warmup) iterations.
    pub fn measurement(&self, name: &str) -> Option<Measurement> {
        if self.iter_secs.is_empty() {
            return None;
        }
        Some(crate::bench::summarize(name, &self.iter_secs).with_counters(vec![
            ("fit_iters".to_string(), self.fit_iters),
            ("yv_products".to_string(), self.yv_products),
            ("traversals".to_string(), self.traversals),
            ("x_traversals".to_string(), self.x_traversals),
            ("heap_bytes".to_string(), self.heap_bytes),
            ("shard_reconnects".to_string(), self.shard_reconnects),
            ("shard_retries".to_string(), self.shard_retries),
        ]))
    }
}

/// Time one engine on one dataset: returns mean secs/iter or OoM.
pub fn time_als(
    data: &IrregularTensor,
    rank: usize,
    backend: Backend,
    mem_budget: Option<u64>,
) -> CellResult {
    time_als_detailed(data, rank, backend, mem_budget).cell
}

/// [`time_als`] also capturing the per-iteration wall times and the
/// fit-wide `yv_products` / `traversals` counters for the JSON export.
pub fn time_als_detailed(
    data: &IrregularTensor,
    rank: usize,
    backend: Backend,
    mem_budget: Option<u64>,
) -> AlsRun {
    let (warmup, measure) = bench_iters();
    let cfg = Parafac2Config {
        rank,
        max_iters: warmup + measure,
        tol: 0.0, // never converge early — we're timing iterations
        nonneg: true,
        workers: 0,
        seed: 42,
        backend,
        mem_budget,
        ..Default::default()
    };
    let mut iter_secs: Vec<f64> = Vec::new();
    let res = fit_parafac2_traced(data, &cfg, &mut |rec| {
        iter_secs.push(rec.procrustes_secs + rec.cp_secs);
    });
    match res {
        Ok(model) => {
            let fit_iters = iter_secs.len() as u64;
            let measured =
                iter_secs[warmup.min(iter_secs.len().saturating_sub(1))..].to_vec();
            let mean = measured.iter().sum::<f64>() / measured.len().max(1) as f64;
            AlsRun {
                cell: CellResult::Time { secs_per_iter: mean, iters: measured.len() },
                iter_secs: measured,
                fit_iters,
                yv_products: model.stats.yv_products,
                traversals: model.stats.traversals,
                x_traversals: model.stats.x_traversals,
                heap_bytes: model.stats.heap_bytes,
                shard_reconnects: model.stats.shard_reconnects,
                shard_retries: model.stats.shard_retries,
            }
        }
        Err(crate::parafac2::FitError::OutOfMemory(_)) => AlsRun {
            cell: CellResult::OutOfMemory,
            iter_secs: Vec::new(),
            fit_iters: 0,
            yv_products: 0,
            traversals: 0,
            x_traversals: 0,
            heap_bytes: 0,
            shard_reconnects: 0,
            shard_retries: 0,
        },
        Err(e) => panic!("bench fit failed: {e}"),
    }
}

/// Per-iteration (SSE, fit) trajectory of a fit — used to check that the
/// fused sweep's convergence path is deterministic: bitwise identical
/// across worker counts (chunk-ordered reductions guarantee it).
pub fn fit_trajectory(
    data: &IrregularTensor,
    rank: usize,
    backend: Backend,
    workers: usize,
    iters: usize,
) -> Vec<(f64, f64)> {
    let cfg = Parafac2Config {
        rank,
        max_iters: iters,
        tol: 0.0,
        nonneg: true,
        workers,
        seed: 42,
        backend,
        mem_budget: None,
        ..Default::default()
    };
    let mut traj = Vec::with_capacity(iters);
    fit_parafac2_traced(data, &cfg, &mut |rec| traj.push((rec.sse, rec.fit)))
        .expect("trajectory fit failed");
    traj
}

/// Golden-trajectory fixtures: **bit-exact** serialization of a fit's
/// per-iteration (SSE, fit) path plus the final factor matrices, stored as
/// hex-encoded IEEE-754 bits (JSON float round-trips must not be trusted
/// with a bitwise contract). The checked-in fixture pins the exact
/// floating-point summation order of the whole ALS stack: any kernel swap
/// that reorders an accumulation fails the comparison and must re-bless
/// the fixture explicitly (`SPARTAN_BLESS=1 cargo test golden`) instead of
/// drifting silently. Order-preserving kernel changes (the
/// `linalg::kernels` blocked family) pass untouched by construction.
pub mod golden {
    use crate::linalg::Mat;
    use crate::util::json::Json;

    /// The pinned content: per-iteration SSE/fit plus the final H/V/W.
    #[derive(Clone, Debug)]
    pub struct GoldenTrajectory {
        pub sse: Vec<f64>,
        pub fit: Vec<f64>,
        pub h: Mat,
        pub v: Mat,
        pub w: Mat,
    }

    fn f64_to_json(x: f64) -> Json {
        Json::str(format!("{:016x}", x.to_bits()))
    }

    fn f64_from_json(j: &Json) -> Option<f64> {
        u64::from_str_radix(j.as_str()?, 16).ok().map(f64::from_bits)
    }

    fn vec_to_json(xs: &[f64]) -> Json {
        Json::arr(xs.iter().map(|&x| f64_to_json(x)))
    }

    fn vec_from_json(j: &Json) -> Option<Vec<f64>> {
        j.as_arr()?.iter().map(f64_from_json).collect()
    }

    fn mat_to_json(m: &Mat) -> Json {
        Json::obj(vec![
            ("rows", Json::num(m.rows() as f64)),
            ("cols", Json::num(m.cols() as f64)),
            ("bits", vec_to_json(m.data())),
        ])
    }

    fn mat_from_json(j: &Json) -> Option<Mat> {
        let rows = j.get("rows")?.as_usize()?;
        let cols = j.get("cols")?.as_usize()?;
        let data = vec_from_json(j.get("bits")?)?;
        if data.len() != rows * cols {
            return None;
        }
        Some(Mat::from_vec(rows, cols, data))
    }

    impl GoldenTrajectory {
        pub fn to_json(&self) -> Json {
            Json::obj(vec![
                ("format", Json::str("spartan-golden-trajectory-v1")),
                ("encoding", Json::str("ieee754-f64-bits-hex")),
                ("sse", vec_to_json(&self.sse)),
                ("fit", vec_to_json(&self.fit)),
                ("h", mat_to_json(&self.h)),
                ("v", mat_to_json(&self.v)),
                ("w", mat_to_json(&self.w)),
            ])
        }

        pub fn from_json(j: &Json) -> Option<GoldenTrajectory> {
            Some(GoldenTrajectory {
                sse: vec_from_json(j.get("sse")?)?,
                fit: vec_from_json(j.get("fit")?)?,
                h: mat_from_json(j.get("h")?)?,
                v: mat_from_json(j.get("v")?)?,
                w: mat_from_json(j.get("w")?)?,
            })
        }

        /// Bitwise comparison; `Err` describes the first divergence.
        pub fn bitwise_eq(&self, other: &GoldenTrajectory) -> Result<(), String> {
            fn cmp_vec(name: &str, a: &[f64], b: &[f64]) -> Result<(), String> {
                if a.len() != b.len() {
                    return Err(format!("{name}: length {} vs {}", a.len(), b.len()));
                }
                for (i, (x, y)) in a.iter().zip(b).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("{name}[{i}]: {x:e} vs {y:e}"));
                    }
                }
                Ok(())
            }
            fn cmp_mat(name: &str, a: &Mat, b: &Mat) -> Result<(), String> {
                if a.shape() != b.shape() {
                    return Err(format!("{name}: shape {:?} vs {:?}", a.shape(), b.shape()));
                }
                cmp_vec(name, a.data(), b.data())
            }
            cmp_vec("sse", &self.sse, &other.sse)?;
            cmp_vec("fit", &self.fit, &other.fit)?;
            cmp_mat("h", &self.h, &other.h)?;
            cmp_mat("v", &self.v, &other.v)?;
            cmp_mat("w", &self.w, &other.w)
        }
    }
}

/// Speedup string "N.N×" for a (spartan, baseline) pair.
pub fn speedup(spartan: &CellResult, baseline: &CellResult) -> String {
    match (spartan.secs(), baseline.secs()) {
        (Some(s), Some(b)) if s > 0.0 => format!("{:.1}×", b / s),
        (Some(_), None) => "∞ (baseline OoM)".to_string(),
        _ => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::synthetic::{generate, SyntheticSpec};

    #[test]
    fn time_als_measures_and_reports() {
        let data = generate(&SyntheticSpec {
            k: 30,
            j: 15,
            max_i_k: 6,
            target_nnz: 2_000,
            rank: 2,
            noise: 0.0,
            seed: 1,
        })
        .tensor;
        let run = time_als_detailed(&data, 2, Backend::Spartan, None);
        match run.cell {
            CellResult::Time { secs_per_iter, iters } => {
                assert!(secs_per_iter >= 0.0);
                assert!(iters >= 1);
                assert_eq!(run.iter_secs.len(), iters);
            }
            _ => panic!("expected time"),
        }
        assert!(!run.cell.render().is_empty());
        // the SPARTan engine's exact work counters ride along for the
        // JSON export: one Y·V per subject per iteration, one traversal
        // per subject per iteration (+ the final-report mode-3 pass)
        let k = data.k() as u64;
        assert!(run.fit_iters >= 1);
        // the fit-wide counters normalize by fit_iters (warmup included):
        // one Y·V per subject per iteration, one traversal per subject
        // per iteration plus the final-report mode-3 pass
        assert_eq!(run.yv_products, run.fit_iters * k);
        assert_eq!(run.traversals, (run.fit_iters + 1) * k);
        // one cold X pass per subject per iteration through the resident
        // arena, plus the pack and the final report pass
        assert_eq!(run.x_traversals, (run.fit_iters + 2) * k);
        assert!(run.heap_bytes > 0);
        // local fits never shard — the recovery counters publish as 0
        assert_eq!(run.shard_reconnects, 0);
        assert_eq!(run.shard_retries, 0);
        let m = run.measurement("cell").expect("timed run summarizes");
        assert_eq!(m.counters.len(), 7);

        // OoM cells summarize to None
        let oom = time_als_detailed(&data, 2, Backend::Baseline, Some(64));
        assert!(matches!(oom.cell, CellResult::OutOfMemory));
        assert!(oom.measurement("oom").is_none());
    }

    #[test]
    fn table1_config_trajectory_bitwise_deterministic_across_workers() {
        // A scaled-down instance of the Table-1 synthetic config (same
        // generator, same density profile as benches/table1_synthetic.rs):
        // the fused sweep must produce the exact same SSE/fit trajectory
        // at every worker count — bitwise, not approximately.
        let data = generate(&SyntheticSpec {
            k: 126,
            j: 50,
            max_i_k: 10,
            target_nnz: 12_000,
            rank: 4,
            noise: 0.01,
            seed: 42,
        })
        .tensor;
        let reference = fit_trajectory(&data, 4, Backend::Spartan, 1, 6);
        assert_eq!(reference.len(), 6);
        for workers in [2usize, 4, 7] {
            let traj = fit_trajectory(&data, 4, Backend::Spartan, workers, 6);
            for (i, (a, b)) in reference.iter().zip(&traj).enumerate() {
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "SSE iter {i}, {workers} workers");
                assert_eq!(a.1.to_bits(), b.1.to_bits(), "fit iter {i}, {workers} workers");
            }
        }
    }

    #[test]
    fn golden_fixture_roundtrips_bit_exact() {
        use crate::util::json;
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seed(55);
        // include values a naive float JSON path would mangle
        let mut h = crate::linalg::Mat::rand_normal(3, 3, &mut rng);
        h[(0, 0)] = -0.0;
        h[(1, 1)] = 5e-324; // smallest denormal
        h[(2, 2)] = 0.1 + 0.2; // classic non-terminating binary fraction
        let g = golden::GoldenTrajectory {
            sse: vec![1.0 / 3.0, f64::MIN_POSITIVE, 1e300],
            fit: vec![0.9999999999999999],
            h: h.clone(),
            v: crate::linalg::Mat::rand_normal(4, 3, &mut rng),
            w: crate::linalg::Mat::rand_normal(5, 3, &mut rng),
        };
        let text = g.to_json().pretty();
        let back = golden::GoldenTrajectory::from_json(&json::parse(&text).unwrap()).unwrap();
        g.bitwise_eq(&back).expect("bit-exact roundtrip");
        assert_eq!(back.h[(0, 0)].to_bits(), (-0.0f64).to_bits(), "signed zero preserved");
        // and the comparison really has teeth
        let mut tweaked = back;
        tweaked.h[(1, 2)] = f64::from_bits(tweaked.h[(1, 2)].to_bits() ^ 1);
        assert!(g.bitwise_eq(&tweaked).is_err(), "one-ulp tweak must be caught");
    }

    /// THE golden-trajectory gate: a small Table-1-config fit must match
    /// the checked-in fixture **bitwise** — per-iteration SSE and fit
    /// values and the final factors. A kernel swap that changes any
    /// summation order must re-bless explicitly
    /// (`SPARTAN_BLESS=1 cargo test golden` + commit the fixture) rather
    /// than drift silently. On a checkout without the fixture (or under
    /// SPARTAN_BLESS=1) the test writes it and passes, printing a
    /// reminder to commit — self-bootstrapping, since the fixture can
    /// only be produced by an actual fit.
    #[test]
    fn golden_trajectory_fixture_pins_summation_order() {
        let data = generate(&SyntheticSpec {
            k: 126,
            j: 50,
            max_i_k: 10,
            target_nnz: 12_000,
            rank: 4,
            noise: 0.01,
            seed: 42,
        })
        .tensor;
        let cfg = Parafac2Config {
            rank: 4,
            max_iters: 6,
            tol: 0.0,
            nonneg: true,
            workers: 3, // irrelevant to the bits: trajectories are
            // worker-count invariant (asserted elsewhere in this module)
            seed: 42,
            backend: Backend::Spartan,
            mem_budget: None,
            ..Default::default()
        };
        let mut sse = Vec::new();
        let mut fit = Vec::new();
        let model = fit_parafac2_traced(&data, &cfg, &mut |rec| {
            sse.push(rec.sse);
            fit.push(rec.fit);
        })
        .expect("golden fit");
        let got = golden::GoldenTrajectory {
            sse,
            fit,
            h: model.h.clone(),
            v: model.v.clone(),
            w: model.w.clone(),
        };
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("rust/tests/fixtures/golden_trajectory_table1.json");
        let bless = std::env::var("SPARTAN_BLESS").as_deref() == Ok("1");
        if bless || !path.exists() {
            std::fs::create_dir_all(path.parent().unwrap()).expect("fixtures dir");
            std::fs::write(&path, got.to_json().pretty()).expect("writing fixture");
            eprintln!(
                "golden_trajectory: blessed {} — commit this file to pin the trajectory",
                path.display()
            );
            // Self-blessing keeps fresh checkouts green, but it also means
            // the bitwise gate is OFF until the fixture is committed. Make
            // that state impossible to miss where it matters: under
            // SPARTAN_REQUIRE_GOLDEN=1 (set it in CI once the fixture is
            // committed) a missing fixture is a hard failure, not a bless.
            assert!(
                bless || std::env::var("SPARTAN_REQUIRE_GOLDEN").as_deref() != Ok("1"),
                "golden trajectory fixture missing at {} but SPARTAN_REQUIRE_GOLDEN=1 — \
                 the bitwise gate is not allowed to self-bless here; commit the fixture \
                 (it was just generated at that path)",
                path.display()
            );
            return;
        }
        let text = std::fs::read_to_string(&path).expect("reading fixture");
        let want = golden::GoldenTrajectory::from_json(
            &crate::util::json::parse(&text).expect("fixture JSON"),
        )
        .expect("fixture schema");
        if let Err(msg) = want.bitwise_eq(&got) {
            panic!(
                "golden trajectory diverged from {} at {msg}. A change altered the \
                 floating-point summation order of the ALS stack; if intentional, \
                 re-bless with `SPARTAN_BLESS=1 cargo test golden` and commit the fixture.",
                path.display()
            );
        }
    }

    #[test]
    fn oom_cell_renders() {
        let data = generate(&SyntheticSpec {
            k: 20,
            j: 10,
            max_i_k: 5,
            target_nnz: 1_000,
            rank: 2,
            noise: 0.0,
            seed: 2,
        })
        .tensor;
        let r = time_als(&data, 2, Backend::Baseline, Some(64));
        assert!(matches!(r, CellResult::OutOfMemory));
        assert_eq!(r.render(), "OoM");
        assert_eq!(speedup(&CellResult::Time { secs_per_iter: 1.0, iters: 1 }, &r), "∞ (baseline OoM)");
    }
}
