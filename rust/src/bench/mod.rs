//! Criterion-lite: a small benchmarking harness (the offline crate set has
//! no criterion). Provides warmup + timed iterations, mean/σ/min, table
//! rendering that mirrors the paper's tables, and JSON export so
//! EXPERIMENTS.md numbers are regenerable.
//!
//! ## The `bench_results/*.json` schema
//!
//! Every bench binary writes one JSON file per run via [`write_results`]
//! (the directory is created on demand; CI uploads it as an artifact):
//!
//! ```json
//! {
//!   "bench": "<file stem>",
//!   "context": { "config": { ... free-form bench configuration ... } },
//!   "measurements": [
//!     {
//!       "name": "...", "iters": N,
//!       "backend": "scalar|blocked|avx2|avx512|neon",
//!       "mean_secs": ..., "std_secs": ..., "min_secs": ..., "max_secs": ...,
//!       "iter_secs": [ ...wall-time of every measured iteration... ],
//!       "counters": { "fit_iters": ..., "yv_products": ..., "traversals": ...,
//!                     "x_traversals": ..., "heap_bytes": ...,
//!                     "shard_reconnects": ..., "shard_retries": ... }
//!     }
//!   ]
//! }
//! ```
//!
//! `iter_secs` holds the raw per-iteration wall times behind the summary
//! statistics. `counters` (present where the bench measures an ALS fit)
//! holds the exact kernel-work tallies over the **whole fit, warmup
//! included** — normalize by `fit_iters`, not `iters`:
//! `yv_products / (K·fit_iters) == 1`,
//! `traversals / (K·fit_iters) ≈ 1` (one extra K from the final report
//! pass), and `x_traversals / (K·fit_iters) ≈ 1` (one cold X pass per
//! subject per iteration through the resident compact-X arena, plus the
//! one-time pack and the final report pass) for the SPARTan engine — see
//! `metrics::flops`. `heap_bytes` is the steady-state resident footprint
//! of the fit's data-plane arenas (the residency the arena trades for the
//! halved X traffic). `shard_reconnects`/`shard_retries` count the
//! sharded-fit recovery path (successful mid-fit re-attaches and the
//! reconnect attempts behind them — see `FitStats`); local bench fits
//! never shard, so both are 0 here. That makes the perf trajectory across
//! PRs machine-checkable, not eyeballed.
//!
//! `backend` (optional) names the kernel backend the measurement ran on
//! (`linalg::kernels::KernelBackend::name()`) — the per-ISA A/B cells.
//! `trend::cells_from_json` folds it into the cell id
//! (`<bench>/<name>@<backend>`), so a measurement that changes backend is
//! a new cell, never a regression against the old one.

pub mod als_runner;
pub mod table;
pub mod trend;

use crate::util::json::Json;
use crate::util::timer::{fmt_secs, Stopwatch};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
    /// Raw wall time of every measured iteration (the samples behind the
    /// summary statistics), exported as `iter_secs`.
    pub samples: Vec<f64>,
    /// Exact work counters (e.g. `yv_products`, `traversals`) exported as
    /// the `counters` object; empty for pure wall-time measurements.
    pub counters: Vec<(String, u64)>,
    /// Kernel backend the measurement ran on, exported as `backend`;
    /// `None` for measurements that don't touch the kernel layer.
    pub backend: Option<String>,
}

impl Measurement {
    /// Attach exact work counters (builder-style).
    pub fn with_counters(mut self, counters: Vec<(String, u64)>) -> Measurement {
        self.counters = counters;
        self
    }

    /// Record the kernel backend this cell ran on (builder-style). The
    /// trend differ keys the cell as `<bench>/<name>@<backend>`.
    pub fn with_backend(mut self, backend: &str) -> Measurement {
        self.backend = Some(backend.to_string());
        self
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![("name", Json::str(self.name.clone()))];
        if let Some(b) = &self.backend {
            fields.push(("backend", Json::str(b.clone())));
        }
        fields.extend([
            ("iters", Json::num(self.iters as f64)),
            ("mean_secs", Json::num(self.mean_secs)),
            ("std_secs", Json::num(self.std_secs)),
            ("min_secs", Json::num(self.min_secs)),
            ("max_secs", Json::num(self.max_secs)),
            ("iter_secs", Json::arr(self.samples.iter().map(|&s| Json::num(s)))),
        ]);
        if !self.counters.is_empty() {
            fields.push((
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    pub fn summary(&self) -> String {
        format!(
            "{}: mean {} ± {} (min {}, {} iters)",
            self.name,
            fmt_secs(self.mean_secs),
            fmt_secs(self.std_secs),
            fmt_secs(self.min_secs),
            self.iters
        )
    }
}

/// Harness configuration. `SPARTAN_BENCH_FAST=1` shrinks everything for
/// smoke runs (CI / test of the bench binaries themselves).
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Hard cap on total measurement time; stop early past it.
    pub max_total_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if std::env::var("SPARTAN_BENCH_FAST").as_deref() == Ok("1") {
            BenchConfig { warmup_iters: 0, measure_iters: 1, max_total_secs: 30.0 }
        } else {
            BenchConfig { warmup_iters: 1, measure_iters: 3, max_total_secs: 600.0 }
        }
    }
}

/// Run a benchmark: `f` is invoked once per iteration and must do the full
/// unit of work (e.g. one PARAFAC2-ALS iteration). Returns the measurement.
pub fn bench(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.measure_iters);
    let total = Stopwatch::start();
    for _ in 0..cfg.measure_iters.max(1) {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed_secs());
        if total.elapsed_secs() > cfg.max_total_secs {
            break;
        }
    }
    summarize(name, &samples)
}

/// Build a measurement from raw samples.
pub fn summarize(name: &str, samples: &[f64]) -> Measurement {
    assert!(!samples.is_empty());
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    Measurement {
        name: name.to_string(),
        iters: samples.len(),
        mean_secs: mean,
        std_secs: var.sqrt(),
        min_secs: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_secs: samples.iter().cloned().fold(0.0, f64::max),
        samples: samples.to_vec(),
        counters: Vec::new(),
        backend: None,
    }
}

/// Write a set of measurements (plus free-form context) to a JSON file
/// under `bench_results/`.
pub fn write_results(file_stem: &str, context: Json, measurements: &[Measurement]) -> std::path::PathBuf {
    let dir = std::path::Path::new("bench_results");
    std::fs::create_dir_all(dir).ok();
    let out = Json::obj(vec![
        ("bench", Json::str(file_stem)),
        ("context", context),
        (
            "measurements",
            Json::arr(measurements.iter().map(|m| m.to_json())),
        ),
    ]);
    let path = dir.join(format!("{file_stem}.json"));
    std::fs::write(&path, out.pretty()).expect("writing bench results");
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarizes() {
        let cfg = BenchConfig { warmup_iters: 1, measure_iters: 4, max_total_secs: 10.0 };
        let mut count = 0usize;
        let m = bench("noop", &cfg, || {
            count += 1;
        });
        assert_eq!(count, 5); // 1 warmup + 4 measured
        assert_eq!(m.iters, 4);
        assert!(m.mean_secs >= 0.0);
        assert!(m.min_secs <= m.mean_secs && m.mean_secs <= m.max_secs + 1e-12);
    }

    #[test]
    fn summarize_statistics() {
        let m = summarize("x", &[1.0, 2.0, 3.0]);
        assert!((m.mean_secs - 2.0).abs() < 1e-12);
        assert_eq!(m.min_secs, 1.0);
        assert_eq!(m.max_secs, 3.0);
        assert!((m.std_secs - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn json_has_fields() {
        let m = summarize("x", &[0.5]);
        let j = m.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("iters").unwrap().as_usize().unwrap(), 1);
        let secs = j.get("iter_secs").unwrap().as_arr().unwrap();
        assert_eq!(secs.len(), 1);
        assert_eq!(secs[0].as_f64().unwrap(), 0.5);
        assert!(j.get("counters").is_none(), "no counters unless attached");
    }

    #[test]
    fn json_counters_round_trip() {
        let m = summarize("fit", &[0.25, 0.75])
            .with_counters(vec![("yv_products".into(), 120), ("traversals".into(), 60)]);
        let j = m.to_json();
        let c = j.get("counters").unwrap();
        assert_eq!(c.get("yv_products").unwrap().as_usize().unwrap(), 120);
        assert_eq!(c.get("traversals").unwrap().as_usize().unwrap(), 60);
        assert_eq!(j.get("iter_secs").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn json_backend_field_is_optional_and_round_trips() {
        let plain = summarize("x", &[0.5]);
        assert!(plain.to_json().get("backend").is_none(), "no backend unless attached");
        let tagged = summarize("x", &[0.5]).with_backend("avx2");
        assert_eq!(tagged.to_json().get("backend").unwrap().as_str().unwrap(), "avx2");
    }

    #[test]
    fn write_results_creates_dir_and_file() {
        // The CI bench lane depends on this contract: the directory is
        // created on demand and one JSON lands per run.
        let m = summarize("x", &[0.1]);
        let path = write_results("selftest_bench_io", Json::obj(vec![]), &[m]);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("selftest_bench_io"));
        assert!(text.contains("iter_secs"));
        std::fs::remove_file(&path).ok();
    }
}
