//! Criterion-lite: a small benchmarking harness (the offline crate set has
//! no criterion). Provides warmup + timed iterations, mean/σ/min, table
//! rendering that mirrors the paper's tables, and JSON export so
//! EXPERIMENTS.md numbers are regenerable.

pub mod als_runner;
pub mod table;

use crate::util::json::Json;
use crate::util::timer::{fmt_secs, Stopwatch};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub std_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl Measurement {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_secs", Json::num(self.mean_secs)),
            ("std_secs", Json::num(self.std_secs)),
            ("min_secs", Json::num(self.min_secs)),
            ("max_secs", Json::num(self.max_secs)),
        ])
    }

    pub fn summary(&self) -> String {
        format!(
            "{}: mean {} ± {} (min {}, {} iters)",
            self.name,
            fmt_secs(self.mean_secs),
            fmt_secs(self.std_secs),
            fmt_secs(self.min_secs),
            self.iters
        )
    }
}

/// Harness configuration. `SPARTAN_BENCH_FAST=1` shrinks everything for
/// smoke runs (CI / test of the bench binaries themselves).
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Hard cap on total measurement time; stop early past it.
    pub max_total_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if std::env::var("SPARTAN_BENCH_FAST").as_deref() == Ok("1") {
            BenchConfig { warmup_iters: 0, measure_iters: 1, max_total_secs: 30.0 }
        } else {
            BenchConfig { warmup_iters: 1, measure_iters: 3, max_total_secs: 600.0 }
        }
    }
}

/// Run a benchmark: `f` is invoked once per iteration and must do the full
/// unit of work (e.g. one PARAFAC2-ALS iteration). Returns the measurement.
pub fn bench(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.measure_iters);
    let total = Stopwatch::start();
    for _ in 0..cfg.measure_iters.max(1) {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed_secs());
        if total.elapsed_secs() > cfg.max_total_secs {
            break;
        }
    }
    summarize(name, &samples)
}

/// Build a measurement from raw samples.
pub fn summarize(name: &str, samples: &[f64]) -> Measurement {
    assert!(!samples.is_empty());
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    Measurement {
        name: name.to_string(),
        iters: samples.len(),
        mean_secs: mean,
        std_secs: var.sqrt(),
        min_secs: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_secs: samples.iter().cloned().fold(0.0, f64::max),
    }
}

/// Write a set of measurements (plus free-form context) to a JSON file
/// under `bench_results/`.
pub fn write_results(file_stem: &str, context: Json, measurements: &[Measurement]) -> std::path::PathBuf {
    let dir = std::path::Path::new("bench_results");
    std::fs::create_dir_all(dir).ok();
    let out = Json::obj(vec![
        ("bench", Json::str(file_stem)),
        ("context", context),
        (
            "measurements",
            Json::arr(measurements.iter().map(|m| m.to_json())),
        ),
    ]);
    let path = dir.join(format!("{file_stem}.json"));
    std::fs::write(&path, out.pretty()).expect("writing bench results");
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_summarizes() {
        let cfg = BenchConfig { warmup_iters: 1, measure_iters: 4, max_total_secs: 10.0 };
        let mut count = 0usize;
        let m = bench("noop", &cfg, || {
            count += 1;
        });
        assert_eq!(count, 5); // 1 warmup + 4 measured
        assert_eq!(m.iters, 4);
        assert!(m.mean_secs >= 0.0);
        assert!(m.min_secs <= m.mean_secs && m.mean_secs <= m.max_secs + 1e-12);
    }

    #[test]
    fn summarize_statistics() {
        let m = summarize("x", &[1.0, 2.0, 3.0]);
        assert!((m.mean_secs - 2.0).abs() < 1e-12);
        assert_eq!(m.min_secs, 1.0);
        assert_eq!(m.max_secs, 3.0);
        assert!((m.std_secs - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn json_has_fields() {
        let m = summarize("x", &[0.5]);
        let j = m.to_json();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("iters").unwrap().as_usize().unwrap(), 1);
    }
}
