//! Load-aware chunking of subjects.
//!
//! Subjects have wildly varying nonzero counts (the paper's EHR data is
//! heavy-tailed), so chunking `0..K` uniformly can leave one chunk holding
//! most of the work. [`balanced_chunks`] greedily cuts the subject range
//! into contiguous chunks of approximately equal *weight* (nnz), which the
//! scheduler then distributes dynamically.

use std::ops::Range;

/// Split `0..weights.len()` into contiguous ranges whose weight sums are
/// each ≈ `total / target_chunks` (at least 1 item per chunk).
pub fn balanced_chunks(weights: &[u64], target_chunks: usize) -> Vec<Range<usize>> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let target_chunks = target_chunks.clamp(1, n);
    let total: u64 = weights.iter().sum();
    let per_chunk = (total / target_chunks as u64).max(1);
    let mut out = Vec::with_capacity(target_chunks);
    let mut start = 0usize;
    let mut acc = 0u64;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        if acc >= per_chunk && i + 1 > start {
            out.push(start..i + 1);
            start = i + 1;
            acc = 0;
        }
    }
    if start < n {
        out.push(start..n);
    }
    out
}

/// Fixed subject-chunk size used by the PARAFAC2 kernels.
///
/// A *fixed* size (rather than `n / workers`) makes every parallel
/// reduction bit-for-bit deterministic across worker counts: chunk
/// boundaries — and therefore floating-point summation order — depend only
/// on the data, never on the machine. 64 subjects per chunk keeps
/// scheduling overhead < 1% at the workloads in the paper's sweeps while
/// still load-balancing heavy-tailed subjects. The persistent pool's
/// dynamic chunk cursor (see [`crate::threadpool::Pool`]) hands these
/// fixed chunks to whichever worker is free, so load balance is dynamic
/// while the reduction order stays fixed.
pub const SUBJECT_CHUNK: usize = 64;

/// Heuristic chunk size for a uniform split of `n` items across `workers`,
/// targeting ~4 chunks per worker for load balance without scheduling
/// overhead. (Use [`SUBJECT_CHUNK`] where cross-run determinism matters.)
pub fn default_chunk_size(n: usize, workers: usize) -> usize {
    (n / (workers.max(1) * 4)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range_exactly() {
        let w = vec![1u64; 100];
        let chunks = balanced_chunks(&w, 7);
        let mut covered = vec![false; 100];
        for c in &chunks {
            for i in c.clone() {
                assert!(!covered[i], "double covered {i}");
                covered[i] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn skewed_weights_get_balanced() {
        // one huge subject at the front
        let mut w = vec![1u64; 99];
        w.insert(0, 1000);
        let chunks = balanced_chunks(&w, 4);
        // the huge subject must be alone in its chunk
        assert_eq!(chunks[0], 0..1);
    }

    #[test]
    fn empty_and_single() {
        assert!(balanced_chunks(&[], 4).is_empty());
        assert_eq!(balanced_chunks(&[5], 4), vec![0..1]);
    }

    #[test]
    fn default_chunk_size_reasonable() {
        assert_eq!(default_chunk_size(0, 4), 1);
        assert!(default_chunk_size(1000, 4) >= 1);
        assert!(default_chunk_size(1000, 4) <= 1000);
    }
}
