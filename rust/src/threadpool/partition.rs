//! Load-aware chunking of subjects.
//!
//! Subjects have wildly varying nonzero counts (the paper's EHR data is
//! heavy-tailed), so chunking `0..K` uniformly can leave one chunk holding
//! most of the work. [`balanced_chunks`] greedily cuts the subject range
//! into contiguous chunks of approximately equal *weight* (nnz), and a
//! [`ChunkPlan`] freezes those boundaries for a whole fit so that every
//! parallel kernel call chunks the subjects identically.
//!
//! ## The determinism contract
//!
//! Every reduction in the PARAFAC2 kernels merges per-chunk partials in
//! chunk order, so results are bit-for-bit identical across worker counts
//! **iff the chunk boundaries themselves never depend on the worker
//! count**. Both plan constructors honor that: [`ChunkPlan::fixed`] cuts
//! at multiples of [`SUBJECT_CHUNK`] (depends only on K), and
//! [`ChunkPlan::balanced`] cuts by cumulative weight against a target
//! chunk count of `K.div_ceil(SUBJECT_CHUNK)` (depends only on K and the
//! per-subject weights, i.e. only on the data).

use std::ops::Range;

/// Split `0..weights.len()` into contiguous ranges whose weight sums are
/// each ≈ `total / target_chunks` (at least 1 item per chunk).
pub fn balanced_chunks(weights: &[u64], target_chunks: usize) -> Vec<Range<usize>> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let target_chunks = target_chunks.clamp(1, n);
    let total: u64 = weights.iter().sum();
    let per_chunk = (total / target_chunks as u64).max(1);
    let mut out = Vec::with_capacity(target_chunks);
    let mut start = 0usize;
    let mut acc = 0u64;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        if acc >= per_chunk && i + 1 > start {
            out.push(start..i + 1);
            start = i + 1;
            acc = 0;
        }
    }
    if start < n {
        out.push(start..n);
    }
    out
}

/// Fixed subject-chunk size used by the PARAFAC2 kernels.
///
/// A *fixed* size (rather than `n / workers`) makes every parallel
/// reduction bit-for-bit deterministic across worker counts: chunk
/// boundaries — and therefore floating-point summation order — depend only
/// on the data, never on the machine. 64 subjects per chunk keeps
/// scheduling overhead < 1% at the workloads in the paper's sweeps. The
/// persistent pool's dynamic chunk cursor (see
/// [`crate::threadpool::Pool`]) hands chunks to whichever worker is free,
/// so load balance is dynamic while the reduction order stays fixed;
/// [`ChunkPlan::balanced`] additionally evens out the per-chunk *work* for
/// heavy-tailed cohorts.
pub const SUBJECT_CHUNK: usize = 64;

/// A frozen chunking of `0..items` into contiguous, disjoint, covering
/// ranges — the unit of scheduling for every per-subject parallel kernel.
///
/// One plan is computed per fit (boundaries depend only on the data, see
/// the module docs) and passed to every kernel call, so the fused
/// pack→mode-1 sweep, the standalone MTTKRPs, and the regression tests
/// comparing them all sum in exactly the same order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChunkPlan {
    ranges: Vec<Range<usize>>,
    items: usize,
}

impl ChunkPlan {
    /// Fixed-size chunks of [`SUBJECT_CHUNK`] subjects (the pre-balancing
    /// behavior; still the right default when no weights are available).
    pub fn fixed(items: usize) -> ChunkPlan {
        ChunkPlan::fixed_size(items, SUBJECT_CHUNK)
    }

    /// Fixed-size chunks of an explicit size (tests / ablations).
    pub fn fixed_size(items: usize, chunk: usize) -> ChunkPlan {
        let chunk = chunk.max(1);
        let ranges = (0..items.div_ceil(chunk))
            .map(|c| c * chunk..((c + 1) * chunk).min(items))
            .collect();
        ChunkPlan { ranges, items }
    }

    /// Weight-balanced chunks: boundaries cut by cumulative `weights`
    /// (per-subject nnz in the ALS driver) against a target chunk count of
    /// `items.div_ceil(SUBJECT_CHUNK)` — the same chunk count a fixed plan
    /// would use, but with heavy subjects isolated so no chunk dominates
    /// the critical path. Depends only on the weights, never on the
    /// worker count.
    pub fn balanced(weights: &[u64]) -> ChunkPlan {
        let items = weights.len();
        let ranges = balanced_chunks(weights, items.div_ceil(SUBJECT_CHUNK));
        ChunkPlan { ranges, items }
    }

    /// Rebuild a plan from explicit ranges (the sharded coordinator rebases
    /// a contiguous run of global chunks to a shard-local `0..K_s` plan).
    /// The ranges must be a contiguous, disjoint, non-empty cover of
    /// `0..items` — anything else would silently change the reduction
    /// order the determinism contract pins.
    pub fn from_ranges(ranges: Vec<Range<usize>>, items: usize) -> Result<ChunkPlan, String> {
        let plan = ChunkPlan { ranges, items };
        if !plan.covers(items) {
            return Err(format!(
                "ranges do not contiguously cover 0..{items}: {:?}",
                plan.ranges
            ));
        }
        Ok(plan)
    }

    /// The frozen ranges, in subject order.
    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }

    /// Number of chunks.
    pub fn n_chunks(&self) -> usize {
        self.ranges.len()
    }

    /// Number of items covered (`0..items`).
    pub fn items(&self) -> usize {
        self.items
    }

    /// Check that this plan covers exactly `0..n` (kernel entry points
    /// assert it: a plan built for a different tensor would silently
    /// mis-chunk).
    pub fn covers(&self, n: usize) -> bool {
        if self.items != n {
            return false;
        }
        let mut at = 0usize;
        for r in &self.ranges {
            if r.start != at || r.end <= r.start {
                return false;
            }
            at = r.end;
        }
        at == n
    }
}

/// Heuristic chunk size for a uniform split of `n` items across `workers`,
/// targeting ~4 chunks per worker for load balance without scheduling
/// overhead. (Use a [`ChunkPlan`] where cross-run determinism matters.)
pub fn default_chunk_size(n: usize, workers: usize) -> usize {
    (n / (workers.max(1) * 4)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range_exactly() {
        let w = vec![1u64; 100];
        let chunks = balanced_chunks(&w, 7);
        let mut covered = vec![false; 100];
        for c in &chunks {
            for i in c.clone() {
                assert!(!covered[i], "double covered {i}");
                covered[i] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn skewed_weights_get_balanced() {
        // one huge subject at the front
        let mut w = vec![1u64; 99];
        w.insert(0, 1000);
        let chunks = balanced_chunks(&w, 4);
        // the huge subject must be alone in its chunk
        assert_eq!(chunks[0], 0..1);
    }

    #[test]
    fn empty_and_single() {
        assert!(balanced_chunks(&[], 4).is_empty());
        assert_eq!(balanced_chunks(&[5], 4), vec![0..1]);
    }

    #[test]
    fn default_chunk_size_reasonable() {
        assert_eq!(default_chunk_size(0, 4), 1);
        assert!(default_chunk_size(1000, 4) >= 1);
        assert!(default_chunk_size(1000, 4) <= 1000);
    }

    #[test]
    fn fixed_plan_matches_fixed_chunking() {
        let p = ChunkPlan::fixed(130);
        assert_eq!(p.ranges(), &[0..64, 64..128, 128..130]);
        assert!(p.covers(130));
        assert_eq!(p.items(), 130);
        assert_eq!(p.n_chunks(), 3);
        let empty = ChunkPlan::fixed(0);
        assert_eq!(empty.n_chunks(), 0);
        assert!(empty.covers(0));
    }

    #[test]
    fn balanced_plan_covers_and_isolates_heavy_subject() {
        // heavy-tailed cohort: subject 40 holds ~50% of the nnz
        let mut w = vec![10u64; 200];
        w[40] = 2000;
        let p = ChunkPlan::balanced(&w);
        assert!(p.covers(200));
        // boundaries are uneven (not multiples of SUBJECT_CHUNK)
        assert_ne!(p, ChunkPlan::fixed(200));
        // the greedy cut closes the chunk right after the heavy subject
        // (its weight alone exceeds the per-chunk budget)
        let heavy = p.ranges().iter().find(|r| r.contains(&40)).unwrap().clone();
        assert_eq!(heavy.end, 41, "heavy chunk {heavy:?}");
    }

    #[test]
    fn balanced_plan_depends_only_on_weights() {
        let w: Vec<u64> = (0..150).map(|i| 1 + (i * 37) as u64 % 91).collect();
        // same weights → same plan, regardless of how often it's built
        assert_eq!(ChunkPlan::balanced(&w), ChunkPlan::balanced(&w));
        // uniform weights → the greedy cut lands on (near-)uniform chunks
        let u = ChunkPlan::balanced(&[3u64; 128]);
        assert!(u.covers(128));
        assert_eq!(u.n_chunks(), 2);
    }

    #[test]
    fn from_ranges_validates_cover() {
        let ok = ChunkPlan::from_ranges(vec![0..3, 3..7, 7..10], 10).unwrap();
        assert!(ok.covers(10));
        assert_eq!(ok.n_chunks(), 3);
        assert!(ChunkPlan::from_ranges(vec![0..3, 4..10], 10).is_err());
        assert!(ChunkPlan::from_ranges(vec![0..10], 11).is_err());
        assert!(ChunkPlan::from_ranges(vec![0..3, 3..3, 3..10], 10).is_err());
        // rebasing a run of global chunks: [10..14, 14..20) - 10 → local
        let local = ChunkPlan::from_ranges(vec![0..4, 4..10], 10).unwrap();
        assert_eq!(local.ranges(), &[0..4, 4..10]);
    }

    #[test]
    fn covers_rejects_wrong_size_or_gaps() {
        let p = ChunkPlan::fixed(10);
        assert!(!p.covers(11));
        let gap = ChunkPlan { ranges: vec![0..3, 4..10], items: 10 };
        assert!(!gap.covers(10));
        let overlap = ChunkPlan { ranges: vec![0..5, 3..10], items: 10 };
        assert!(!overlap.covers(10));
    }
}
