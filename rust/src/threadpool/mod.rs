//! Scoped data-parallel execution over the K subjects.
//!
//! The paper's kernels are "fully parallelizable w.r.t. the K subjects"
//! (§4.1) and the reference implementation leans on Matlab's parallel
//! pool. The offline crate set has no rayon, so this is a small scoped
//! pool built on `std::thread::scope`:
//!
//! * work is split into contiguous chunks of subjects,
//! * workers pull chunk ids from an atomic counter (dynamic load balance —
//!   subjects have wildly different nnz, so static splits would skew),
//! * per-chunk results are returned **in chunk order**, so reductions are
//!   bit-for-bit deterministic regardless of thread scheduling.

pub mod partition;

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A lightweight handle describing how much parallelism to use.
/// (Threads are spawned per call via `std::thread::scope`; at the chunk
/// sizes used by the kernels, spawn cost is noise.)
#[derive(Clone, Debug)]
pub struct Pool {
    workers: usize,
}

impl Pool {
    /// `workers = 0` resolves to the machine's available parallelism.
    pub fn new(workers: usize) -> Pool {
        let resolved = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        Pool { workers: resolved.max(1) }
    }

    /// Single-threaded pool (useful to measure parallel overhead).
    pub fn serial() -> Pool {
        Pool { workers: 1 }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Apply `f` to chunk index ranges covering `0..n`, returning per-chunk
    /// results **ordered by chunk id**.
    pub fn par_chunk_results<R, F>(&self, n: usize, chunk: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
    {
        let chunk = chunk.max(1);
        let n_chunks = n.div_ceil(chunk);
        if n_chunks == 0 {
            return Vec::new();
        }
        // Serial fast path: no synchronization, no spawns.
        if self.workers == 1 || n_chunks == 1 {
            return (0..n_chunks)
                .map(|c| f(c * chunk..((c + 1) * chunk).min(n)))
                .collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<R>>> =
            Mutex::new((0..n_chunks).map(|_| None).collect());
        let threads = self.workers.min(n_chunks);
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let c = next.fetch_add(1, Ordering::Relaxed);
                    if c >= n_chunks {
                        break;
                    }
                    let r = f(c * chunk..((c + 1) * chunk).min(n));
                    slots.lock().unwrap()[c] = Some(r);
                });
            }
        });
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("chunk result missing"))
            .collect()
    }

    /// Parallel fold: per-chunk partial results merged in chunk order
    /// (deterministic).
    pub fn par_fold<R, F, M>(&self, n: usize, chunk: usize, f: F, mut merge: M) -> Option<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
        M: FnMut(R, R) -> R,
    {
        let mut parts = self.par_chunk_results(n, chunk, f).into_iter();
        let first = parts.next()?;
        Some(parts.fold(first, |acc, x| merge(acc, x)))
    }

    /// Parallel for-each over indices.
    pub fn par_for<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.par_chunk_results(n, chunk, |range| {
            for i in range {
                f(i);
            }
        });
    }

    /// Parallel map preserving order.
    pub fn par_map<T, F>(&self, n: usize, chunk: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let nested =
            self.par_chunk_results(n, chunk, |range| range.map(&f).collect::<Vec<T>>());
        let mut out = Vec::with_capacity(n);
        for v in nested {
            out.extend(v);
        }
        out
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::new(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunk_results_cover_everything_in_order() {
        let pool = Pool::new(4);
        let res = pool.par_chunk_results(10, 3, |r| r.collect::<Vec<usize>>());
        assert_eq!(res.len(), 4);
        assert_eq!(res[0], vec![0, 1, 2]);
        assert_eq!(res[3], vec![9]);
    }

    #[test]
    fn par_fold_deterministic_sum() {
        let pool = Pool::new(8);
        let want: u64 = (0..1000u64).sum();
        for _ in 0..5 {
            let got = pool
                .par_fold(1000, 7, |r| r.map(|i| i as u64).sum::<u64>(), |a, b| a + b)
                .unwrap();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn par_fold_empty_is_none() {
        let pool = Pool::new(2);
        assert_eq!(pool.par_fold(0, 4, |_| 1u32, |a, b| a + b), None);
    }

    #[test]
    fn par_for_touches_each_once() {
        let pool = Pool::new(4);
        let counter = AtomicU64::new(0);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.par_for(100, 9, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_order_preserved() {
        let pool = Pool::new(4);
        let out = pool.par_map(57, 5, |i| i * i);
        assert_eq!(out, (0..57).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_matches_parallel() {
        let serial = Pool::serial();
        let par = Pool::new(4);
        let f = |r: Range<usize>| r.map(|i| (i as f64).sqrt()).sum::<f64>();
        let a = serial.par_fold(500, 13, f, |x, y| x + y).unwrap();
        let b = par.par_fold(500, 13, f, |x, y| x + y).unwrap();
        assert_eq!(a, b); // bitwise equal because merge order is fixed
    }

    #[test]
    fn workers_resolved() {
        assert!(Pool::new(0).workers() >= 1);
        assert_eq!(Pool::new(3).workers(), 3);
        assert_eq!(Pool::serial().workers(), 1);
    }
}
