//! Persistent data-parallel worker pool for the per-subject kernels.
//!
//! The paper's kernels are "fully parallelizable w.r.t. the K subjects"
//! (§4.1) and the reference implementation leans on Matlab's parallel
//! pool. The offline crate set has no rayon, so this is a small
//! hand-rolled pool. Earlier revisions spawned threads per call via
//! `std::thread::scope`; an ALS iteration makes several pool calls
//! (Procrustes, then each CP kernel), so the spawn/join cost was paid 4+
//! times per iteration. The pool is now **persistent**:
//!
//! ## Threading model
//!
//! * [`Pool::new`] spawns `workers - 1` long-lived threads (the caller of
//!   each parallel operation acts as the remaining worker, so
//!   `Pool::serial()` spawns nothing and runs inline with zero
//!   synchronization).
//! * Each parallel call publishes **one job** — an erased chunk executor
//!   plus an atomic chunk cursor — into a shared FIFO **job queue** guarded
//!   by a `Mutex`/`Condvar`; idle workers wake, help the oldest live job by
//!   pulling chunk ids from its cursor until it is exhausted, then move to
//!   the next queued job or go back to sleep. Work is therefore dynamically
//!   load-balanced (subjects have wildly different nnz, so static splits
//!   would skew).
//! * The queue is what makes one pool **shareable across concurrent
//!   fits**: any number of threads may publish jobs simultaneously (the
//!   resident service multiplexes every running [`crate::parafac2::FitSession`]
//!   over one worker set this way). Each publisher always participates in
//!   *its own* job, so a job makes progress even while the workers are
//!   busy helping an older one; workers drain jobs oldest-first.
//! * The caller participates in the chunk loop, then blocks on a
//!   completion latch counting finished chunks. Only after every chunk
//!   has finished does the call return, which is what makes lending the
//!   caller's stack closure to `'static` worker threads sound: no worker
//!   can touch the closure after the latch releases (late workers only
//!   observe an exhausted cursor and never dereference the task again).
//! * Per-chunk results are stored **by chunk id** and merged in chunk
//!   order, so every reduction is bit-for-bit deterministic regardless of
//!   thread scheduling, worker count, or what *other* jobs are in flight
//!   on the same pool (concurrent jobs share workers, never chunks — see
//!   `concurrent_jobs_bitwise_equal_standalone` below and the end-to-end
//!   teeth in `rust/tests/service_e2e.rs`).
//! * A panic inside a chunk is caught, the latch still advances (no
//!   deadlock), and the payload is re-thrown on the calling thread after
//!   the job drains.
//! * Jobs do not nest: a parallel call issued from inside a running chunk
//!   (tracked by a thread-local, so it is per-thread, not per-pool) runs
//!   inline serially — same results, no deadlock.
//!
//! Cloning a [`Pool`] shares the same workers; the threads shut down when
//! the last handle drops.

pub mod partition;

pub use partition::ChunkPlan;

use std::cell::Cell;
use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased pointer to a caller-stack chunk executor (`Fn(chunk_id)`).
/// Sound to send across threads because the publishing call blocks until
/// every chunk has completed before its referent goes out of scope.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

/// Raw base pointer used by [`Pool::par_chunks_mut`] to hand disjoint
/// `&mut` sub-slices to workers.
struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Completion latch + first-panic slot for one job.
struct JobStatus {
    /// Chunks not yet finished; guarded so the caller can sleep on it.
    remaining: Mutex<usize>,
    done_cv: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

#[derive(Clone)]
struct Job {
    task: TaskRef,
    n_chunks: usize,
    next: Arc<AtomicUsize>,
    status: Arc<JobStatus>,
}

/// FIFO of published jobs — what workers watch. A job is pushed by its
/// publisher and removed by that same publisher once the completion latch
/// releases; workers additionally drop fully-claimed jobs from the front
/// so the scan never lingers on dead work. Multiple publishers (concurrent
/// fits sharing one pool) simply interleave here.
struct JobQueue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<JobQueue>,
    work_cv: Condvar,
}

thread_local! {
    /// True while *this thread* is executing a chunk of some job. A
    /// parallel call issued while set runs inline (publishing a nested job
    /// could deadlock the latch the outer chunk is counted in, and inline
    /// execution preserves the exact serial chunk order anyway). Tracking
    /// this per-thread — rather than per-pool as a "some job is active"
    /// flag — is what lets *other* threads keep publishing top-level jobs
    /// concurrently.
    static IN_JOB: Cell<bool> = const { Cell::new(false) };
}

struct PoolCore {
    workers: usize,
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Drop for PoolCore {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim chunks from the cursor until exhausted. Shared by workers and the
/// publishing caller. Sets the thread-local [`IN_JOB`] flag around each
/// chunk so nested parallel calls run inline.
fn run_chunks(job: &Job) {
    loop {
        let c = job.next.fetch_add(1, Ordering::Relaxed);
        if c >= job.n_chunks {
            break;
        }
        // SAFETY: the task outlives the job — the publishing call blocks
        // until `remaining` hits 0, and this deref happens strictly before
        // this chunk's decrement below. (A worker that grabs the job clone
        // *after* completion only ever observes an exhausted cursor above
        // and never reaches this deref.)
        let task = unsafe { &*job.task.0 };
        IN_JOB.with(|f| f.set(true));
        let result = catch_unwind(AssertUnwindSafe(|| task(c)));
        IN_JOB.with(|f| f.set(false));
        if let Err(payload) = result {
            let mut slot = job.status.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        let mut remaining = job.status.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            job.status.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if q.shutdown {
                    return;
                }
                // Drop fully-claimed jobs off the front (their publisher
                // still holds a clone for the latch wait, so this only
                // trims the scan), then help the oldest live job.
                while q
                    .jobs
                    .front()
                    .is_some_and(|j| j.next.load(Ordering::Relaxed) >= j.n_chunks)
                {
                    q.jobs.pop_front();
                }
                if let Some(front) = q.jobs.front() {
                    break front.clone();
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        run_chunks(&job);
    }
}

/// A persistent worker pool. Cheap to clone (handles share workers).
#[derive(Clone)]
pub struct Pool {
    core: Arc<PoolCore>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("workers", &self.core.workers).finish()
    }
}

impl Pool {
    /// `workers = 0` resolves to the machine's available parallelism.
    /// Spawns `workers - 1` persistent threads (the caller is worker 0).
    pub fn new(workers: usize) -> Pool {
        let resolved = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            workers
        };
        let workers = resolved.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(JobQueue { jobs: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(workers.saturating_sub(1));
        for i in 1..workers {
            let sh = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("spartan-worker-{i}"))
                .spawn(move || worker_loop(sh));
            if let Ok(h) = spawned {
                handles.push(h);
            }
        }
        // If any spawn failed, the pool still works with fewer threads —
        // correctness never depends on the worker count.
        Pool {
            core: Arc::new(PoolCore {
                workers: handles.len() + 1,
                shared,
                handles: Mutex::new(handles),
            }),
        }
    }

    /// Single-threaded pool (useful to measure parallel overhead). Runs
    /// everything inline; no threads are spawned.
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    pub fn workers(&self) -> usize {
        self.core.workers
    }

    /// Execute `task(c)` for every `c in 0..n_chunks`, either inline
    /// (serial pool, single chunk, or nested inside a running chunk on
    /// this thread) or by publishing a job to the shared queue with the
    /// caller participating. Concurrent top-level publishers — e.g. two
    /// `FitSession`s stepping on one pool — queue independently and each
    /// block only on their own latch.
    fn run_job(&self, n_chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        if self.core.workers == 1 || n_chunks <= 1 || IN_JOB.with(|f| f.get()) {
            // Nested parallel calls (issued from inside a running chunk)
            // run inline — identical chunk order, no deadlock.
            for c in 0..n_chunks {
                task(c);
            }
            return;
        }
        let job = Job {
            task: TaskRef(task as *const (dyn Fn(usize) + Sync)),
            n_chunks,
            next: Arc::new(AtomicUsize::new(0)),
            status: Arc::new(JobStatus {
                remaining: Mutex::new(n_chunks),
                done_cv: Condvar::new(),
                panic: Mutex::new(None),
            }),
        };
        {
            let mut q = self.core.shared.queue.lock().unwrap();
            q.jobs.push_back(job.clone());
        }
        self.core.shared.work_cv.notify_all();
        run_chunks(&job);
        {
            let mut remaining = job.status.remaining.lock().unwrap();
            while *remaining != 0 {
                remaining = job.status.done_cv.wait(remaining).unwrap();
            }
        }
        {
            // Workers may already have trimmed it off the front; `retain`
            // is then a no-op. Identity is the status Arc — tasks can be
            // byte-identical across jobs, the latch never is.
            let mut q = self.core.shared.queue.lock().unwrap();
            q.jobs.retain(|j| !Arc::ptr_eq(&j.status, &job.status));
        }
        if let Some(payload) = job.status.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }

    /// Core ranged executor: apply `f` to `range_of(c)` for every chunk id
    /// `c in 0..n_chunks`, returning per-chunk results **ordered by chunk
    /// id**. `range_of` must be cheap and pure — it is re-evaluated on
    /// whichever worker claims the chunk.
    fn par_ranged<R, F, G>(&self, n_chunks: usize, range_of: G, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
        G: Fn(usize) -> Range<usize> + Sync,
    {
        if n_chunks == 0 {
            return Vec::new();
        }
        // Serial fast path: no synchronization.
        if self.core.workers == 1 || n_chunks == 1 {
            return (0..n_chunks).map(|c| f(range_of(c))).collect();
        }
        let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n_chunks).map(|_| None).collect());
        let task = |c: usize| {
            let r = f(range_of(c));
            slots.lock().unwrap()[c] = Some(r);
        };
        self.run_job(n_chunks, &task);
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("chunk result missing"))
            .collect()
    }

    /// Apply `f` to fixed-size chunk ranges covering `0..n`, returning
    /// per-chunk results **ordered by chunk id**.
    pub fn par_chunk_results<R, F>(&self, n: usize, chunk: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
    {
        let chunk = chunk.max(1);
        self.par_ranged(n.div_ceil(chunk), |c| c * chunk..((c + 1) * chunk).min(n), f)
    }

    /// Apply `f` to the frozen ranges of a [`ChunkPlan`] (weight-balanced
    /// or fixed — the kernels never care which), returning per-chunk
    /// results ordered by chunk id. Boundaries come from the plan, so the
    /// chunk-ordered merge downstream is bitwise deterministic across
    /// worker counts.
    pub fn par_plan_results<R, F>(&self, plan: &ChunkPlan, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
    {
        let ranges = plan.ranges();
        self.par_ranged(ranges.len(), |c| ranges[c].clone(), f)
    }

    /// Plan-driven parallel mutation: hand each plan range of `items` to
    /// `f(start_index, chunk_slice)` as a disjoint `&mut` sub-slice,
    /// returning per-chunk results ordered by chunk id. The arena-reuse
    /// path (repacking `Y_k` slices in place, refreshing per-subject
    /// scratch) needs disjoint `&mut` access from workers; plan ranges
    /// never overlap (asserted), so handing out raw-pointer-derived
    /// sub-slices is sound.
    pub fn par_plan_chunks_mut<T, R, F>(&self, items: &mut [T], plan: &ChunkPlan, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut [T]) -> R + Sync,
    {
        let n = items.len();
        assert!(plan.covers(n), "chunk plan does not cover the {n} items");
        let ranges = plan.ranges();
        let n_chunks = ranges.len();
        if n_chunks == 0 {
            return Vec::new();
        }
        if self.core.workers == 1 || n_chunks == 1 {
            let mut out = Vec::with_capacity(n_chunks);
            let mut rest: &mut [T] = items;
            for r in ranges {
                let (sub, tail) = std::mem::take(&mut rest).split_at_mut(r.end - r.start);
                rest = tail;
                out.push(f(r.start, sub));
            }
            return out;
        }
        let base = SendPtr(items.as_mut_ptr());
        let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n_chunks).map(|_| None).collect());
        let task = |c: usize| {
            let r = &ranges[c];
            // SAFETY: plan ranges are disjoint sub-ranges of `items`
            // (checked by `covers` above), which the caller exclusively
            // borrows for the duration of the job.
            let sub =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(r.start), r.end - r.start) };
            let out = f(r.start, sub);
            slots.lock().unwrap()[c] = Some(out);
        };
        self.run_job(n_chunks, &task);
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("chunk result missing"))
            .collect()
    }

    /// Plan-driven parallel mutation **with per-chunk scratch**: like
    /// [`Pool::par_plan_chunks_mut`], but additionally hands chunk `c` the
    /// exclusive `&mut scratch[c]`. The arena-backed Procrustes sweep
    /// keeps one scratch arena per *chunk* (plans are frozen per fit, so
    /// the chunk count is stable and scratch buffers reach their
    /// high-water sizes during the first iteration and are reused
    /// thereafter — scratch assignment depends only on the chunk id, never
    /// on which worker claims it, so results stay bitwise deterministic
    /// across worker counts).
    pub fn par_plan_zip_mut<T, S, R, F>(
        &self,
        items: &mut [T],
        scratch: &mut [S],
        plan: &ChunkPlan,
        f: F,
    ) -> Vec<R>
    where
        T: Send,
        S: Send,
        R: Send,
        F: Fn(usize, &mut [T], &mut S) -> R + Sync,
    {
        let n = items.len();
        assert!(plan.covers(n), "chunk plan does not cover the {n} items");
        let ranges = plan.ranges();
        let n_chunks = ranges.len();
        assert_eq!(
            scratch.len(),
            n_chunks,
            "need exactly one scratch slot per plan chunk"
        );
        if n_chunks == 0 {
            return Vec::new();
        }
        if self.core.workers == 1 || n_chunks == 1 {
            let mut out = Vec::with_capacity(n_chunks);
            let mut rest: &mut [T] = items;
            for (r, s) in ranges.iter().zip(scratch.iter_mut()) {
                let (sub, tail) = std::mem::take(&mut rest).split_at_mut(r.end - r.start);
                rest = tail;
                out.push(f(r.start, sub, s));
            }
            return out;
        }
        let base = SendPtr(items.as_mut_ptr());
        let scratch_base = SendPtr(scratch.as_mut_ptr());
        let slots: Mutex<Vec<Option<R>>> = Mutex::new((0..n_chunks).map(|_| None).collect());
        let task = |c: usize| {
            let r = &ranges[c];
            // SAFETY: plan ranges are disjoint sub-ranges of `items`
            // (checked by `covers`), and chunk `c` is claimed by exactly
            // one worker, so `scratch[c]` is touched by exactly one thread;
            // the caller exclusively borrows both slices for the job.
            let sub =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(r.start), r.end - r.start) };
            let s = unsafe { &mut *scratch_base.0.add(c) };
            let out = f(r.start, sub, s);
            slots.lock().unwrap()[c] = Some(out);
        };
        self.run_job(n_chunks, &task);
        slots
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("chunk result missing"))
            .collect()
    }

    /// Fixed-size-chunk parallel mutation (see [`Pool::par_plan_chunks_mut`]
    /// for the plan-driven variant the PARAFAC2 kernels use).
    pub fn par_chunks_mut<T, R, F>(&self, items: &mut [T], chunk: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut [T]) -> R + Sync,
    {
        self.par_plan_chunks_mut(items, &ChunkPlan::fixed_size(items.len(), chunk), f)
    }

    /// Parallel fold: per-chunk partial results merged in chunk order
    /// (deterministic).
    pub fn par_fold<R, F, M>(&self, n: usize, chunk: usize, f: F, mut merge: M) -> Option<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
        M: FnMut(R, R) -> R,
    {
        let mut parts = self.par_chunk_results(n, chunk, f).into_iter();
        let first = parts.next()?;
        Some(parts.fold(first, |acc, x| merge(acc, x)))
    }

    /// Plan-driven parallel fold: per-chunk partials over the plan's
    /// frozen ranges, merged in chunk order (deterministic across worker
    /// counts because the boundaries come from the plan).
    pub fn par_plan_fold<R, F, M>(&self, plan: &ChunkPlan, f: F, mut merge: M) -> Option<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
        M: FnMut(R, R) -> R,
    {
        let mut parts = self.par_plan_results(plan, f).into_iter();
        let first = parts.next()?;
        Some(parts.fold(first, |acc, x| merge(acc, x)))
    }

    /// Parallel for-each over indices.
    pub fn par_for<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.par_chunk_results(n, chunk, |range| {
            for i in range {
                f(i);
            }
        });
    }

    /// Parallel map preserving order.
    pub fn par_map<T, F>(&self, n: usize, chunk: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let nested =
            self.par_chunk_results(n, chunk, |range| range.map(&f).collect::<Vec<T>>());
        let mut out = Vec::with_capacity(n);
        for v in nested {
            out.extend(v);
        }
        out
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::new(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunk_results_cover_everything_in_order() {
        let pool = Pool::new(4);
        let res = pool.par_chunk_results(10, 3, |r| r.collect::<Vec<usize>>());
        assert_eq!(res.len(), 4);
        assert_eq!(res[0], vec![0, 1, 2]);
        assert_eq!(res[3], vec![9]);
    }

    #[test]
    fn par_fold_deterministic_sum() {
        let pool = Pool::new(8);
        let want: u64 = (0..1000u64).sum();
        for _ in 0..5 {
            let got = pool
                .par_fold(1000, 7, |r| r.map(|i| i as u64).sum::<u64>(), |a, b| a + b)
                .unwrap();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn par_fold_empty_is_none() {
        let pool = Pool::new(2);
        assert_eq!(pool.par_fold(0, 4, |_| 1u32, |a, b| a + b), None);
    }

    #[test]
    fn par_for_touches_each_once() {
        let pool = Pool::new(4);
        let counter = AtomicU64::new(0);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.par_for(100, 9, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
            counter.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_order_preserved() {
        let pool = Pool::new(4);
        let out = pool.par_map(57, 5, |i| i * i);
        assert_eq!(out, (0..57).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_matches_parallel() {
        let serial = Pool::serial();
        let par = Pool::new(4);
        let f = |r: Range<usize>| r.map(|i| (i as f64).sqrt()).sum::<f64>();
        let a = serial.par_fold(500, 13, f, |x, y| x + y).unwrap();
        let b = par.par_fold(500, 13, f, |x, y| x + y).unwrap();
        assert_eq!(a, b); // bitwise equal because merge order is fixed
    }

    #[test]
    fn workers_resolved() {
        assert!(Pool::new(0).workers() >= 1);
        assert_eq!(Pool::new(3).workers(), 3);
        assert_eq!(Pool::serial().workers(), 1);
    }

    #[test]
    fn persistent_workers_survive_many_jobs() {
        // The same pool handles a long sequence of parallel calls — the
        // regression this guards: per-call spawn pools leak no state, a
        // persistent pool must not deadlock or cross-talk between jobs.
        let pool = Pool::new(3);
        for round in 0..200usize {
            let got = pool
                .par_fold(97, 5, |r| r.map(|i| i + round).sum::<usize>(), |a, b| a + b)
                .unwrap();
            let want: usize = (0..97).map(|i| i + round).sum();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn clones_share_workers() {
        let pool = Pool::new(4);
        let clone = pool.clone();
        assert_eq!(pool.workers(), clone.workers());
        let a = pool.par_map(40, 3, |i| i * 2);
        let b = clone.par_map(40, 3, |i| i * 2);
        assert_eq!(a, b);
    }

    #[test]
    fn par_chunks_mut_disjoint_updates() {
        let pool = Pool::new(4);
        let mut data: Vec<u64> = (0..103).collect();
        let starts = pool.par_chunks_mut(&mut data, 10, |start, sub| {
            for (i, x) in sub.iter_mut().enumerate() {
                *x = (start + i) as u64 * 3;
            }
            start
        });
        assert_eq!(starts, (0..11).map(|c| c * 10).collect::<Vec<_>>());
        assert!(data.iter().enumerate().all(|(i, &x)| x == i as u64 * 3));
    }

    #[test]
    fn par_chunks_mut_serial_matches_parallel() {
        let run = |pool: &Pool| {
            let mut data = vec![1.0f64; 64];
            pool.par_chunks_mut(&mut data, 7, |start, sub| {
                for (i, x) in sub.iter_mut().enumerate() {
                    *x = ((start + i) as f64).sin();
                }
            });
            data
        };
        assert_eq!(run(&Pool::serial()), run(&Pool::new(5)));
    }

    #[test]
    fn par_plan_results_uneven_ranges_in_order() {
        // heavy-tailed weights ⇒ uneven, data-dependent boundaries
        let mut w = vec![1u64; 199];
        w.insert(0, 10_000);
        let plan = ChunkPlan::balanced(&w);
        assert!(plan.covers(200));
        assert!(plan.n_chunks() > 1);
        for pool in [Pool::serial(), Pool::new(4)] {
            let got = pool.par_plan_results(&plan, |r| r.clone());
            assert_eq!(got.as_slice(), plan.ranges());
        }
    }

    #[test]
    fn par_plan_fold_bitwise_across_worker_counts() {
        let mut w = vec![3u64; 150];
        w[77] = 5_000;
        let plan = ChunkPlan::balanced(&w);
        let f = |r: Range<usize>| r.map(|i| 1.0 / (1.0 + i as f64)).sum::<f64>();
        let want = Pool::serial().par_plan_fold(&plan, f, |a, b| a + b).unwrap();
        for workers in [2usize, 4, 7] {
            let got = Pool::new(workers).par_plan_fold(&plan, f, |a, b| a + b).unwrap();
            assert_eq!(want.to_bits(), got.to_bits(), "{workers} workers");
        }
    }

    #[test]
    fn par_plan_chunks_mut_uneven_disjoint_updates() {
        let mut w = vec![1u64; 90];
        w[10] = 700;
        let plan = ChunkPlan::balanced(&w);
        assert!(plan.n_chunks() > 1);
        for pool in [Pool::serial(), Pool::new(4)] {
            let mut data = vec![0u64; 90];
            let starts = pool.par_plan_chunks_mut(&mut data, &plan, |start, sub| {
                for (i, x) in sub.iter_mut().enumerate() {
                    *x = (start + i) as u64 * 3;
                }
                start
            });
            assert_eq!(
                starts,
                plan.ranges().iter().map(|r| r.start).collect::<Vec<_>>()
            );
            assert!(data.iter().enumerate().all(|(i, &x)| x == i as u64 * 3));
        }
    }

    #[test]
    fn par_plan_zip_mut_exclusive_scratch_per_chunk() {
        let mut w = vec![1u64; 90];
        w[10] = 700; // heavy-tailed ⇒ uneven, multi-chunk plan
        let plan = ChunkPlan::balanced(&w);
        assert!(plan.n_chunks() > 1);
        for pool in [Pool::serial(), Pool::new(4)] {
            let mut data = vec![0u64; 90];
            let mut scratch = vec![0u64; plan.n_chunks()];
            let sums = pool.par_plan_zip_mut(&mut data, &mut scratch, &plan, |start, sub, s| {
                for (i, x) in sub.iter_mut().enumerate() {
                    *x = (start + i) as u64;
                    *s += *x; // scratch accumulates across this chunk only
                }
                *s
            });
            assert!(data.iter().enumerate().all(|(i, &x)| x == i as u64));
            // per-chunk scratch sums match the chunk ranges exactly
            for (c, r) in plan.ranges().iter().enumerate() {
                let want: u64 = (r.start as u64..r.end as u64).sum();
                assert_eq!(scratch[c], want, "chunk {c}");
                assert_eq!(sums[c], want, "chunk {c}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "one scratch slot per plan chunk")]
    fn par_plan_zip_mut_rejects_scratch_mismatch() {
        let plan = ChunkPlan::fixed(8);
        let mut data = vec![0u32; 8];
        let mut scratch = vec![0u32; plan.n_chunks() + 1];
        Pool::serial().par_plan_zip_mut(&mut data, &mut scratch, &plan, |_, _, _| ());
    }

    #[test]
    #[should_panic(expected = "chunk plan does not cover")]
    fn par_plan_chunks_mut_rejects_mismatched_plan() {
        let plan = ChunkPlan::fixed(8);
        let mut data = vec![0u32; 9];
        Pool::serial().par_plan_chunks_mut(&mut data, &plan, |_, _| ());
    }

    #[test]
    fn nested_calls_run_inline() {
        let pool = Pool::new(3);
        let outer = pool.par_chunk_results(6, 2, |r| {
            // nested call from inside a running job must not deadlock
            pool.par_fold(10, 3, |q| q.sum::<usize>(), |a, b| a + b).unwrap() + r.len()
        });
        assert_eq!(outer, vec![47, 47, 47]);
    }

    #[test]
    fn concurrent_jobs_bitwise_equal_standalone() {
        // Two OS threads hammer one shared pool with interleaved
        // plan-folds — the shape of two FitSessions sharing a worker set.
        // Every result must be bitwise equal to the serial run: concurrent
        // jobs share workers, never chunks, and each job merges its own
        // chunk-ordered partials.
        let mut w = vec![2u64; 120];
        w[13] = 4_000; // heavy-tailed ⇒ uneven, multi-chunk plan
        let plan = ChunkPlan::balanced(&w);
        assert!(plan.n_chunks() > 1);
        let f_a = |r: Range<usize>| r.map(|i| (i as f64 + 1.0).ln()).sum::<f64>();
        let f_b = |r: Range<usize>| r.map(|i| 1.0 / (i as f64 + 2.0)).sum::<f64>();
        let want_a = Pool::serial().par_plan_fold(&plan, f_a, |x, y| x + y).unwrap();
        let want_b = Pool::serial().par_plan_fold(&plan, f_b, |x, y| x + y).unwrap();
        let pool = Pool::new(4);
        std::thread::scope(|s| {
            let plan = &plan;
            let pa = pool.clone();
            let ha = s.spawn(move || {
                (0..100)
                    .map(|_| pa.par_plan_fold(plan, f_a, |x, y| x + y).unwrap())
                    .collect::<Vec<f64>>()
            });
            let pb = pool.clone();
            let hb = s.spawn(move || {
                (0..100)
                    .map(|_| pb.par_plan_fold(plan, f_b, |x, y| x + y).unwrap())
                    .collect::<Vec<f64>>()
            });
            for (i, got) in ha.join().unwrap().into_iter().enumerate() {
                assert_eq!(got.to_bits(), want_a.to_bits(), "job A round {i}");
            }
            for (i, got) in hb.join().unwrap().into_iter().enumerate() {
                assert_eq!(got.to_bits(), want_b.to_bits(), "job B round {i}");
            }
        });
    }

    #[test]
    fn concurrent_mutating_jobs_stay_disjoint() {
        // Concurrent par_plan_chunks_mut jobs on one pool must never leak
        // chunks across jobs: each thread owns its buffer exclusively.
        let plan = ChunkPlan::fixed_size(96, 7);
        let pool = Pool::new(3);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4u64)
                .map(|t| {
                    let p = pool.clone();
                    let plan = &plan;
                    s.spawn(move || {
                        let mut data = vec![0u64; 96];
                        for round in 0..50 {
                            p.par_plan_chunks_mut(&mut data, plan, |start, sub| {
                                for (i, x) in sub.iter_mut().enumerate() {
                                    *x = (start + i) as u64 * 1000 + t * 10 + round % 10;
                                }
                            });
                            let want = |i: u64| i * 1000 + t * 10 + round % 10;
                            assert!(data.iter().enumerate().all(|(i, &x)| x == want(i as u64)));
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    #[test]
    fn panic_in_chunk_propagates_without_deadlock() {
        let pool = Pool::new(3);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.par_for(20, 2, |i| {
                if i == 11 {
                    panic!("boom at {i}");
                }
            });
        }));
        assert!(result.is_err());
        // pool still usable afterwards
        let ok = pool.par_fold(30, 4, |r| r.sum::<usize>(), |a, b| a + b).unwrap();
        assert_eq!(ok, (0..30).sum::<usize>());
    }
}
