//! Thin, safe wrapper over the `xla` crate's PJRT C-API bindings: create a
//! CPU client, load AOT artifacts from **HLO text** (the interchange
//! format — see python/compile/aot.py), compile, and execute with
//! f32 tensors.
//!
//! ## The `pjrt` feature
//!
//! The real implementation needs the `xla` bindings crate plus an XLA
//! toolchain; the offline build environment has neither, so execution is
//! gated behind the (off-by-default) `pjrt` cargo feature. The feature
//! compiles this wrapper against the `xla` dependency — by default the
//! vendored **API-pinning stub** (`rust/vendor/xla-stub`), which keeps
//! every line of this file type-checked in CI's feature-matrix lane
//! (`cargo check --all-targets --features pjrt`) while its constructors
//! fail with a descriptive runtime error; swap the path dependency for
//! the real bindings crate to actually execute. Without the feature this
//! module compiles a **feature-stub** with the identical API whose
//! constructors return a descriptive error. Either way every caller (CLI
//! subcommands, the `compare` table, the PJRT driver, the roundtrip
//! tests) handles missing artifacts/clients gracefully, so the native
//! SPARTan and baseline paths are unaffected.
//!
//! Adapted from the smoke-verified reference at /opt/xla-example.

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};
use std::path::Path;

/// A host-side f32 tensor with shape, converted to/from PJRT literals.
/// Pure host data — available with or without the `pjrt` feature.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> HostTensor {
        let n = dims.iter().product();
        HostTensor { dims, data: vec![0.0; n] }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data)
            .reshape(&dims_i64)
            .map_err(|e| anyhow!("literal reshape: {e:?}"))
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
        Ok(HostTensor::new(dims, data))
    }
}

/// A PJRT client (CPU). One per process is plenty; executables borrow it.
#[cfg(feature = "pjrt")]
pub struct PjrtContext {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl PjrtContext {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<PjrtContext> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        crate::debug!(
            "pjrt: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtContext { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<CompiledKernel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(CompiledKernel {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct CompiledKernel {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

#[cfg(feature = "pjrt")]
impl CompiledKernel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given inputs; returns the tuple elements (the AOT
    /// path lowers with `return_tuple=True`, so outputs arrive as one
    /// tuple literal).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let lit = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: empty result", self.name))?
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e:?}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{}: untuple: {e:?}", self.name))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(not(feature = "pjrt"))]
const STUB_ERROR: &str = "PJRT runtime unavailable: spartan was built without the `pjrt` \
     feature (the `xla` bindings and an XLA toolchain are required); \
     rebuild with `cargo build --features pjrt` after adding the `xla` \
     dependency, or use the native engine";

/// Stub PJRT client compiled when the `pjrt` feature is off: same API,
/// constructors fail with a descriptive error.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtContext {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtContext {
    /// Always fails in stub builds (see module docs).
    pub fn cpu() -> Result<PjrtContext> {
        Err(anyhow::anyhow!(STUB_ERROR))
    }

    pub fn platform_name(&self) -> String {
        "unavailable (built without the pjrt feature)".to_string()
    }

    /// Always fails in stub builds (see module docs).
    pub fn load_hlo_text(&self, path: &Path) -> Result<CompiledKernel> {
        Err(anyhow::anyhow!("{STUB_ERROR} (artifact: {})", path.display()))
    }
}

/// Stub compiled kernel (never constructible without the `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
pub struct CompiledKernel {
    name: String,
}

#[cfg(not(feature = "pjrt"))]
impl CompiledKernel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Always fails in stub builds (see module docs).
    pub fn run(&self, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        Err(anyhow::anyhow!("{STUB_ERROR} (kernel: {})", self.name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dims, vec![2, 3]);
        let z = HostTensor::zeros(vec![4, 5]);
        assert_eq!(z.data.len(), 20);
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_mismatch() {
        HostTensor::new(vec![2, 2], vec![0.0; 5]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_client_reports_missing_feature() {
        let err = PjrtContext::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    // Client-dependent tests live in rust/tests/pjrt_roundtrip.rs, which
    // require the artifacts to be built (`make artifacts`).
}
