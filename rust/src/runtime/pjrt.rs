//! Thin, safe wrapper over the `xla` crate's PJRT C-API bindings: create a
//! CPU client, load AOT artifacts from **HLO text** (the interchange
//! format — see python/compile/aot.py), compile, and execute with
//! f32 tensors.
//!
//! Adapted from the smoke-verified reference at /opt/xla-example.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// A PJRT client (CPU). One per process is plenty; executables borrow it.
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl PjrtContext {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<PjrtContext> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        crate::debug!(
            "pjrt: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(PjrtContext { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<CompiledKernel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(CompiledKernel { exe, name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default() })
    }
}

/// A host-side f32 tensor with shape, converted to/from PJRT literals.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> HostTensor {
        let n = dims.iter().product();
        HostTensor { dims, data: vec![0.0; n] }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data)
            .reshape(&dims_i64)
            .map_err(|e| anyhow!("literal reshape: {e:?}"))
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
        Ok(HostTensor::new(dims, data))
    }
}

/// A compiled artifact ready to execute.
pub struct CompiledKernel {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl CompiledKernel {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given inputs; returns the tuple elements (the AOT
    /// path lowers with `return_tuple=True`, so outputs arrive as one
    /// tuple literal).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let lit = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: empty result", self.name))?
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e:?}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{}: untuple: {e:?}", self.name))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dims, vec![2, 3]);
        let z = HostTensor::zeros(vec![4, 5]);
        assert_eq!(z.data.len(), 20);
    }

    #[test]
    #[should_panic]
    fn host_tensor_rejects_mismatch() {
        HostTensor::new(vec![2, 2], vec![0.0; 5]);
    }

    // Client-dependent tests live in rust/tests/pjrt_roundtrip.rs, which
    // require the artifacts to be built (`make artifacts`).
}
