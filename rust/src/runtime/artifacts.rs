//! Artifact manifest loading and executable caching.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing
//! every lowered HLO module: kind (`procrustes_pack`, `mttkrp_mode{1,2,3}`),
//! shape bucket (B, I, C, R) and file path. The registry indexes entries,
//! selects the smallest bucket that fits a request, and lazily
//! compiles+caches executables.

use super::pjrt::{CompiledKernel, PjrtContext};
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Kinds of AOT kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    ProcrustesPack,
    Mttkrp1,
    Mttkrp2,
    Mttkrp3,
}

impl Kind {
    fn parse(s: &str) -> Option<Kind> {
        match s {
            "procrustes_pack" => Some(Kind::ProcrustesPack),
            "mttkrp_mode1" => Some(Kind::Mttkrp1),
            "mttkrp_mode2" => Some(Kind::Mttkrp2),
            "mttkrp_mode3" => Some(Kind::Mttkrp3),
            _ => None,
        }
    }
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: Kind,
    pub path: PathBuf,
    pub b: usize,
    /// Observation bucket (procrustes only).
    pub i: Option<usize>,
    pub c: usize,
    pub r: usize,
}

/// Parsed manifest + lazily compiled executables.
pub struct ArtifactRegistry {
    pub batch: usize,
    pub rank: usize,
    pub i_buckets: Vec<usize>,
    pub c_buckets: Vec<usize>,
    entries: Vec<ArtifactEntry>,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<CompiledKernel>>>,
}

impl ArtifactRegistry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {} (run `make artifacts`)", manifest_path.display()))?;
        let root = json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let get_usize = |key: &str| -> Result<usize> {
            root.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest missing {key}"))
        };
        let version = get_usize("version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let batch = get_usize("batch")?;
        let rank = get_usize("rank")?;
        let buckets = |key: &str| -> Result<Vec<usize>> {
            Ok(root
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("manifest missing {key}"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect())
        };
        let mut i_buckets = buckets("i_buckets")?;
        let mut c_buckets = buckets("c_buckets")?;
        i_buckets.sort_unstable();
        c_buckets.sort_unstable();

        let mut entries = Vec::new();
        for e in root
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
        {
            let kind_s = e.get("kind").and_then(Json::as_str).unwrap_or("");
            let kind = Kind::parse(kind_s).ok_or_else(|| anyhow!("unknown kind {kind_s}"))?;
            entries.push(ArtifactEntry {
                name: e.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                kind,
                path: dir.join(e.get("path").and_then(Json::as_str).unwrap_or("")),
                b: e.get("b").and_then(Json::as_usize).unwrap_or(0),
                i: e.get("i").and_then(Json::as_usize),
                c: e.get("c").and_then(Json::as_usize).unwrap_or(0),
                r: e.get("r").and_then(Json::as_usize).unwrap_or(0),
            });
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(ArtifactRegistry {
            batch,
            rank,
            i_buckets,
            c_buckets,
            entries,
            dir: dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Smallest C bucket ≥ `c`, if any.
    pub fn c_bucket_for(&self, c: usize) -> Option<usize> {
        self.c_buckets.iter().copied().find(|&b| b >= c)
    }

    /// Smallest I bucket ≥ `i`, if any.
    pub fn i_bucket_for(&self, i: usize) -> Option<usize> {
        self.i_buckets.iter().copied().find(|&b| b >= i)
    }

    /// Find the entry for a kind at an exact bucket.
    pub fn find(&self, kind: Kind, i: Option<usize>, c: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.c == c && (kind != Kind::ProcrustesPack || e.i == i))
    }

    /// Get (compile-on-first-use) the executable for an entry.
    pub fn kernel(
        &self,
        ctx: &PjrtContext,
        kind: Kind,
        i: Option<usize>,
        c: usize,
    ) -> Result<std::sync::Arc<CompiledKernel>> {
        let entry = self
            .find(kind, i, c)
            .ok_or_else(|| anyhow!("no artifact for {kind:?} i={i:?} c={c}"))?;
        let mut cache = self.cache.lock().unwrap();
        if let Some(k) = cache.get(&entry.name) {
            return Ok(k.clone());
        }
        crate::info!("compiling artifact {}", entry.name);
        let k = std::sync::Arc::new(ctx.load_hlo_text(&entry.path)?);
        cache.insert(entry.name.clone(), k.clone());
        Ok(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
            "version": 1, "dtype": "f32", "batch": 4, "rank": 3,
            "i_buckets": [8, 32], "c_buckets": [4, 16],
            "polar_iters": 18,
            "entries": [
                {"name": "mttkrp_mode1_b4_c4_r3", "kind": "mttkrp_mode1",
                 "path": "m1.hlo.txt", "b": 4, "i": null, "c": 4, "r": 3,
                 "inputs": [[4,4,3],[4,4,3],[4,3]], "outputs": [[3,3]]},
                {"name": "procrustes_pack_b4_i8_c4_r3", "kind": "procrustes_pack",
                 "path": "pp.hlo.txt", "b": 4, "i": 8, "c": 4, "r": 3,
                 "inputs": [[4,8,4],[4,4,3],[3,3],[4,3]], "outputs": [[4,4,3],[4,8,3]]}
            ]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn manifest_parses_and_indexes() {
        let dir = std::env::temp_dir().join("spartan_manifest_test");
        write_fake_manifest(&dir);
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.batch, 4);
        assert_eq!(reg.rank, 3);
        assert_eq!(reg.c_bucket_for(3), Some(4));
        assert_eq!(reg.c_bucket_for(5), Some(16));
        assert_eq!(reg.c_bucket_for(17), None);
        assert_eq!(reg.i_bucket_for(9), Some(32));
        assert!(reg.find(Kind::Mttkrp1, None, 4).is_some());
        assert!(reg.find(Kind::Mttkrp1, None, 16).is_none());
        assert!(reg.find(Kind::ProcrustesPack, Some(8), 4).is_some());
        assert!(reg.find(Kind::ProcrustesPack, Some(32), 4).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_informative() {
        let err = match ArtifactRegistry::load(Path::new("/nonexistent")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
