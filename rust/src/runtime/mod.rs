//! The PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them on the XLA CPU client. Python never runs at decomposition time.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactRegistry, Kind};
pub use pjrt::{CompiledKernel, HostTensor, PjrtContext};
