//! The PARAFAC2-ALS outer loop (paper Algorithm 2) with pluggable step-2
//! backend: SPARTan's packed kernels or the Tensor-Toolbox-style baseline.
//!
//! Per iteration (SPARTan backend):
//! 1. **Pack-fused sweep over the resident compact-X arena** — stream
//!    each subject's iteration-invariant compact values exactly **once**
//!    (`C_k = X̃_k·V` against a gathered `V`-support panel; the
//!    `Y_k = Q_kᵀX̃_k` repack rides that pass), recompute `{Q_k}`, repack
//!    `{Y_k}` **in place** into the persistent slice arena, and emit the
//!    mode-1 MTTKRP `M¹` while each freshly packed slice is still
//!    cache-hot (DPar2-style; [`procrustes_pack_mode1`]). All per-subject
//!    temporaries live in per-chunk [`SubjectScratch`] arenas, so
//!    steady-state iterations allocate nothing in this phase.
//! 2. **CP step** — the rest of one fused CP-ALS iteration
//!    ([`cp_iteration_from_m1`]): H from the pre-computed `M¹`, then the
//!    mode-2 sweep (the iteration's **only** cold traversal of the packed
//!    slices, caching `Z_k = Y_kᵀ H`), then the mode-3 epilogue — so
//!    `Y_k·V` runs exactly once per subject and the packed slices are
//!    streamed cold exactly once per iteration (both asserted in
//!    `metrics::flops`).
//!
//! All per-subject work runs on one persistent [`Pool`] created per fit,
//! chunked by one per-fit [`ChunkPlan`] balanced on per-subject nnz
//! (heavy-tailed cohorts can't strand a sweep behind one overloaded
//! chunk; boundaries depend only on the data, so trajectories stay
//! bitwise identical across worker counts).
//!
//! The SSE tracked for convergence uses the decomposition
//! `‖X_k − Q_k M_k‖² = ‖X_k‖² − ‖Y_k‖² + ‖Y_k − M_k‖²` (exact whenever
//! `Q_kᵀQ_k = I`, i.e. all `I_k ≥ R`; slices shorter than the rank make it
//! an upper-bound approximation, which is also what the reference Matlab
//! implementation tracks).

use super::baseline::{cp_iteration_baseline, BaselinePhases};
use super::cp_als::{cp_iteration_from_m1, CpFactors, CpOptions};
use super::init::{initialize, InitMethod};
use super::intermediate::PackedY;
use super::model::{FitStats, Parafac2Model};
use super::mttkrp::FusedScratch;
use super::procrustes::{
    procrustes_all_into, procrustes_pack_mode1, scratch_heap_bytes, subject_plan, SubjectScratch,
};
use crate::sparse::{CompactX, IrregularTensor};
use crate::threadpool::Pool;
use crate::util::membudget::{BudgetExceeded, MemBudget};
use crate::util::timer::Stopwatch;
use std::sync::Arc;

/// Which step-2 engine to use.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// SPARTan (paper Algorithm 3): packed slices, no tensor
    /// materialization, no Khatri-Rao products.
    #[default]
    Spartan,
    /// "Sparse PARAFAC2" baseline: explicit COO tensor + TTB-style MTTKRP.
    Baseline,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "spartan" => Some(Backend::Spartan),
            "baseline" | "sparse-parafac2" => Some(Backend::Baseline),
            _ => None,
        }
    }
}

/// Fitting configuration.
#[derive(Clone, Debug)]
pub struct Parafac2Config {
    /// Target rank R.
    pub rank: usize,
    /// Maximum outer iterations.
    pub max_iters: usize,
    /// Convergence: stop when |ΔSSE|/SSE < tol.
    pub tol: f64,
    /// Non-negativity on V and `{S_k}` (paper §3.2).
    pub nonneg: bool,
    /// V initialization.
    pub init: InitMethod,
    /// Worker threads (0 = all cores).
    pub workers: usize,
    /// RNG seed for initialization.
    pub seed: u64,
    /// Step-2 engine.
    pub backend: Backend,
    /// Memory budget for the baseline's intermediates (None = unlimited).
    pub mem_budget: Option<u64>,
}

impl Default for Parafac2Config {
    fn default() -> Self {
        Parafac2Config {
            rank: 10,
            max_iters: 100,
            tol: 1e-6,
            nonneg: true,
            init: InitMethod::Random,
            workers: 0,
            seed: 42,
            backend: Backend::Spartan,
            mem_budget: None,
        }
    }
}

/// Fitting failure modes.
#[derive(Debug)]
pub enum FitError {
    /// The baseline exhausted its memory budget (the paper's "OoM").
    OutOfMemory(BudgetExceeded),
    /// Invalid configuration.
    Config(String),
}

impl std::fmt::Display for FitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FitError::OutOfMemory(e) => write!(f, "out of memory: {e}"),
            FitError::Config(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for FitError {}

/// Per-iteration progress (also exposed to benches for time-per-iteration
/// tables).
#[derive(Clone, Debug)]
pub struct IterationRecord {
    pub iter: usize,
    pub sse: f64,
    pub fit: f64,
    /// Seconds in the pack-fused sweep (Procrustes + repack + the mode-1
    /// MTTKRP it emits; the baseline backend's plain pack).
    pub procrustes_secs: f64,
    /// Seconds in the rest of the CP step (modes 2–3 + solves).
    pub cp_secs: f64,
}

/// Fit a PARAFAC2 model.
pub fn fit_parafac2(data: &IrregularTensor, cfg: &Parafac2Config) -> Result<Parafac2Model, FitError> {
    let mut records = Vec::new();
    fit_parafac2_traced(data, cfg, &mut |r| records.push(r))
}

/// Fit with a per-iteration callback (bench instrumentation).
pub fn fit_parafac2_traced(
    data: &IrregularTensor,
    cfg: &Parafac2Config,
    on_iter: &mut dyn FnMut(IterationRecord),
) -> Result<Parafac2Model, FitError> {
    if cfg.rank == 0 {
        return Err(FitError::Config("rank must be ≥ 1".into()));
    }
    if cfg.rank > data.j() {
        return Err(FitError::Config(format!(
            "rank {} exceeds variable count J={}",
            cfg.rank,
            data.j()
        )));
    }
    let pool = Pool::new(cfg.workers);
    let budget: Arc<MemBudget> = match cfg.mem_budget {
        Some(b) => MemBudget::limited(b),
        None => MemBudget::unlimited(),
    };
    let total_sw = Stopwatch::start();

    // Persistent per-fit arenas and schedule: the resident compact-X
    // arena (values + local column ids, packed once — every subsequent
    // Procrustes sweep streams it exactly once per subject), the packed-Y
    // slice buffers, the per-chunk sweep scratch, the fused sweep's Z_k
    // cache, and the nnz-balanced chunk plan are built once and reused
    // (refilled in place) by every iteration.
    let plan = subject_plan(data);
    let cx = CompactX::pack(data, &pool, &plan);
    // ‖X‖² served from the arena's pack-time per-slice caches — bitwise
    // identical to `data.fro_norm_sq()`, and the last fit-path read of
    // the original CSR goes away with it.
    let x_norm_sq = cx.norm_sq();
    let x_norm = x_norm_sq.sqrt();

    let init = initialize(data, cfg.rank, cfg.init, cfg.seed, &pool);
    let mut factors = CpFactors { h: init.h, v: init.v, w: init.w };
    let opts = CpOptions { nonneg: cfg.nonneg };

    let mut stats = FitStats::default();
    let mut baseline_phases = BaselinePhases::default();
    let mut prev_sse = f64::INFINITY;
    let mut iters_done = 0;

    let mut y = PackedY::empty(data.j());
    let mut scratch = FusedScratch::new();
    let mut sweep_scratch: Vec<SubjectScratch> = SubjectScratch::for_plan(&plan);

    for iter in 0..cfg.max_iters {
        // --- step 1: Procrustes + packing (into the arena); the SPARTan
        // backend also emits M¹ while each slice is cache-hot ------------
        let sw = Stopwatch::start();
        let fused = match cfg.backend {
            Backend::Spartan => Some(procrustes_pack_mode1(
                &cx,
                &factors.v,
                &factors.h,
                &factors.w,
                &pool,
                &plan,
                &mut y,
                &mut sweep_scratch,
            )),
            Backend::Baseline => {
                let _ = procrustes_all_into(
                    &cx,
                    &factors.v,
                    &factors.h,
                    &factors.w,
                    &pool,
                    &plan,
                    false,
                    &mut y,
                    &mut sweep_scratch,
                );
                None
            }
        };
        let procrustes_secs = sw.elapsed_secs();
        stats.procrustes_secs += procrustes_secs;

        // --- step 2: the rest of one CP-ALS iteration on Y ---------------
        let sw = Stopwatch::start();
        let cp_stats = match fused {
            Some(sweep) => cp_iteration_from_m1(
                &y,
                sweep.m1,
                sweep.yv_products,
                &mut factors,
                opts,
                &pool,
                &plan,
                &mut scratch,
            ),
            None => cp_iteration_baseline(&y, &mut factors, opts, &budget, &mut baseline_phases)
                .map_err(FitError::OutOfMemory)?,
        };
        let cp_secs = sw.elapsed_secs();
        stats.cp_secs += cp_secs;

        if iter == 0 {
            crate::debug!(
                "arena: compact X {} B, packed Y {} B, sweep scratch {} B, fused scratch {} B",
                cx.heap_bytes(),
                y.heap_bytes(),
                scratch_heap_bytes(&sweep_scratch),
                scratch.heap_bytes()
            );
        }

        let sse = (x_norm_sq - y.norm_sq() + cp_stats.y_residual_sq).max(0.0);
        let fit = 1.0 - sse.sqrt() / x_norm;
        stats.fit_history.push(fit);
        iters_done = iter + 1;
        on_iter(IterationRecord { iter, sse, fit, procrustes_secs, cp_secs });
        crate::debug!("iter {iter}: sse={sse:.6e} fit={fit:.6}");

        // --- convergence --------------------------------------------------
        if prev_sse.is_finite() {
            let denom = prev_sse.max(f64::MIN_POSITIVE);
            if (prev_sse - sse).abs() / denom < cfg.tol {
                prev_sse = sse;
                break;
            }
        }
        prev_sse = sse;
    }

    // Final pass: materialize Q_k for the fitted factors (kept out of the
    // loop so the loop's footprint stays at the packed-Y size), and
    // recompute the SSE against the refreshed Q_k so the reported fit is
    // exactly the returned model's (the refresh strictly improves on the
    // last tracked SSE). Reuses the same arena.
    let qs = procrustes_all_into(
        &cx,
        &factors.v,
        &factors.h,
        &factors.w,
        &pool,
        &plan,
        true,
        &mut y,
        &mut sweep_scratch,
    );
    let m3 = super::mttkrp::mttkrp_mode3(&y, &factors.h, &factors.v, &pool, &plan);
    let final_res = super::cp_als::residual_stats(&m3, &factors, y.norm_sq());
    let final_sse = (x_norm_sq - y.norm_sq() + final_res.y_residual_sq).max(0.0);
    stats.yv_products = y.yv_products();
    stats.traversals = y.traversals();
    stats.x_traversals = cx.x_traversals();
    stats.heap_bytes = cx.heap_bytes()
        + y.heap_bytes()
        + scratch_heap_bytes(&sweep_scratch)
        + scratch.heap_bytes();
    drop(y);

    stats.iterations = iters_done;
    stats.final_sse = final_sse;
    stats.final_fit = 1.0 - final_sse.sqrt() / x_norm;
    let _ = prev_sse;
    stats.total_secs = total_sw.elapsed_secs();
    stats.secs_per_iter = if iters_done > 0 {
        (stats.procrustes_secs + stats.cp_secs) / iters_done as f64
    } else {
        0.0
    };

    Ok(Parafac2Model {
        rank: cfg.rank,
        h: factors.h,
        v: factors.v,
        w: factors.w,
        q: qs.expect("keep_q requested"),
        stats,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, random_orthonormal, Mat};
    use crate::sparse::Csr;
    use crate::util::rng::Pcg64;

    /// Generate data exactly following a planted PARAFAC2 model.
    fn planted(rng: &mut Pcg64, k: usize, j: usize, r: usize) -> (IrregularTensor, Mat, Mat) {
        let h = Mat::rand_normal(r, r, rng);
        let v = Mat::rand_uniform(j, r, rng);
        let w = Mat::from_fn(k, r, |_, _| rng.uniform(0.5, 2.0));
        let slices: Vec<Csr> = (0..k)
            .map(|kk| {
                let ik = r + rng.range(3, 9);
                let q = random_orthonormal(ik, r, rng);
                let u = blas::matmul(&q, &h);
                let mut us = u;
                for i in 0..us.rows() {
                    for (c, x) in us.row_mut(i).iter_mut().enumerate() {
                        *x *= w[(kk, c)];
                    }
                }
                Csr::from_dense(&blas::matmul_a_bt(&us, &v))
            })
            .collect();
        (IrregularTensor::new(slices), v, w)
    }

    #[test]
    fn fits_planted_model_to_high_fit() {
        let mut rng = Pcg64::seed(171);
        let (data, _, _) = planted(&mut rng, 12, 10, 3);
        let cfg = Parafac2Config {
            rank: 3,
            max_iters: 200,
            tol: 1e-9,
            nonneg: false,
            seed: 5,
            workers: 1,
            ..Default::default()
        };
        let model = fit_parafac2(&data, &cfg).unwrap();
        assert!(model.stats.final_fit > 0.95, "fit {}", model.stats.final_fit);
        // internal fit estimate must agree with the exact one
        let exact = model.fit(&data);
        assert!(
            (model.stats.final_fit - exact).abs() < 1e-6,
            "{} vs {exact}",
            model.stats.final_fit
        );
    }

    #[test]
    fn recovers_planted_factors() {
        let mut rng = Pcg64::seed(172);
        let (data, v_true, w_true) = planted(&mut rng, 15, 8, 2);
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: 300,
            tol: 1e-10,
            nonneg: false,
            seed: 11,
            workers: 1,
            init: InitMethod::SvdWarm,
            ..Default::default()
        };
        let model = fit_parafac2(&data, &cfg).unwrap();
        let fms = crate::linalg::fms_joint(&[(&model.v, &v_true), (&model.w, &w_true)]);
        assert!(fms > 0.98, "joint FMS {fms}");
    }

    #[test]
    fn sse_monotonically_decreases() {
        let mut rng = Pcg64::seed(173);
        let (data, _, _) = planted(&mut rng, 8, 9, 3);
        let cfg = Parafac2Config {
            rank: 3,
            max_iters: 25,
            tol: 0.0, // run all iterations
            nonneg: true,
            workers: 1,
            ..Default::default()
        };
        let mut sses = Vec::new();
        let _ = fit_parafac2_traced(&data, &cfg, &mut |r| sses.push(r.sse)).unwrap();
        for win in sses.windows(2) {
            assert!(
                win[1] <= win[0] * (1.0 + 1e-7) + 1e-9,
                "SSE increased: {} -> {}",
                win[0],
                win[1]
            );
        }
    }

    #[test]
    fn backends_agree() {
        let mut rng = Pcg64::seed(174);
        let (data, _, _) = planted(&mut rng, 6, 7, 2);
        let mk = |backend| Parafac2Config {
            rank: 2,
            max_iters: 12,
            tol: 0.0,
            nonneg: true,
            seed: 3,
            workers: 1,
            backend,
            ..Default::default()
        };
        let a = fit_parafac2(&data, &mk(Backend::Spartan)).unwrap();
        let b = fit_parafac2(&data, &mk(Backend::Baseline)).unwrap();
        assert!(a.v.max_abs_diff(&b.v) < 1e-6, "V diverged");
        assert!(a.w.max_abs_diff(&b.w) < 1e-6, "W diverged");
        assert!((a.stats.final_sse - b.stats.final_sse).abs() < 1e-6 * (1.0 + a.stats.final_sse));
    }

    #[test]
    fn baseline_oom_is_reported() {
        let mut rng = Pcg64::seed(175);
        let (data, _, _) = planted(&mut rng, 6, 7, 2);
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: 3,
            backend: Backend::Baseline,
            mem_budget: Some(32),
            workers: 1,
            ..Default::default()
        };
        match fit_parafac2(&data, &cfg) {
            Err(FitError::OutOfMemory(_)) => {}
            other => panic!("expected OoM, got {other:?}"),
        }
    }

    #[test]
    fn invalid_config_rejected() {
        let mut rng = Pcg64::seed(176);
        let (data, _, _) = planted(&mut rng, 3, 5, 2);
        let cfg = Parafac2Config { rank: 0, ..Default::default() };
        assert!(matches!(fit_parafac2(&data, &cfg), Err(FitError::Config(_))));
        let cfg = Parafac2Config { rank: 99, ..Default::default() };
        assert!(matches!(fit_parafac2(&data, &cfg), Err(FitError::Config(_))));
    }

    #[test]
    fn nonneg_constraints_respected() {
        let mut rng = Pcg64::seed(177);
        let (data, _, _) = planted(&mut rng, 6, 8, 2);
        let cfg = Parafac2Config { rank: 2, max_iters: 10, nonneg: true, workers: 1, ..Default::default() };
        let model = fit_parafac2(&data, &cfg).unwrap();
        assert!(model.v.data().iter().all(|&x| x >= 0.0));
        assert!(model.w.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn fit_counts_one_yv_and_one_traversal_per_subject_per_iteration() {
        // End-to-end teeth for the pack-fusion: a Spartan fit of N
        // iterations on K subjects performs exactly N·K `Y_k·V` products
        // (all emitted during the pack) and N·K cold slice traversals
        // (mode 2 only), plus the final-report pass's K-standalone mode 3.
        let mut rng = Pcg64::seed(179);
        let (data, _, _) = planted(&mut rng, 9, 8, 2);
        let iters = 7usize;
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: iters,
            tol: 0.0,
            workers: 2,
            ..Default::default()
        };
        let model = fit_parafac2(&data, &cfg).unwrap();
        let k = data.k() as u64;
        assert_eq!(model.stats.yv_products, iters as u64 * k);
        assert_eq!(model.stats.traversals, (iters as u64 + 1) * k);
    }

    #[test]
    fn fit_counts_one_x_traversal_per_subject_per_iteration() {
        // End-to-end teeth for the compact-X arena: a Spartan fit of N
        // iterations on K subjects makes exactly K cold X passes for the
        // one-time arena pack, K per iteration (the C_k stage — the
        // repack rides it), and K for the final report pass. The
        // pre-arena structure cost 2K per iteration (target + repack both
        // re-streamed the CSR); metrics::flops pins that 2→1 drop against
        // the separate two-sweep structure.
        let mut rng = Pcg64::seed(180);
        let (data, _, _) = planted(&mut rng, 9, 8, 2);
        let iters = 6usize;
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: iters,
            tol: 0.0,
            workers: 2,
            ..Default::default()
        };
        let model = fit_parafac2(&data, &cfg).unwrap();
        let k = data.k() as u64;
        assert_eq!(model.stats.x_traversals, (iters as u64 + 2) * k);
        // and the resident footprint is accounted (arena + packed Y +
        // scratch must all be nonzero once a fit ran)
        assert!(model.stats.heap_bytes > 0);
    }

    #[test]
    fn parallel_and_serial_same_result() {
        let mut rng = Pcg64::seed(178);
        let (data, _, _) = planted(&mut rng, 9, 8, 2);
        let mk = |workers| Parafac2Config {
            rank: 2,
            max_iters: 8,
            tol: 0.0,
            workers,
            seed: 9,
            ..Default::default()
        };
        let a = fit_parafac2(&data, &mk(1)).unwrap();
        let b = fit_parafac2(&data, &mk(4)).unwrap();
        // deterministic chunk-ordered reductions ⇒ identical results
        assert_eq!(a.v.data(), b.v.data());
        assert_eq!(a.w.data(), b.w.data());
    }
}
