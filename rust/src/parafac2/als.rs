//! The PARAFAC2-ALS outer loop (paper Algorithm 2) with pluggable step-2
//! backend: SPARTan's packed kernels or the Tensor-Toolbox-style baseline.
//!
//! Per iteration (SPARTan backend):
//! 1. **Pack-fused sweep over the resident compact-X arena** — stream
//!    each subject's iteration-invariant compact values exactly **once**
//!    (`C_k = X̃_k·V` against a gathered `V`-support panel; the
//!    `Y_k = Q_kᵀX̃_k` repack rides that pass), recompute `{Q_k}`, repack
//!    `{Y_k}` **in place** into the persistent slice arena, and emit the
//!    mode-1 MTTKRP `M¹` while each freshly packed slice is still
//!    cache-hot (DPar2-style; [`procrustes_pack_mode1`]). All per-subject
//!    temporaries live in per-chunk [`SubjectScratch`] arenas, so
//!    steady-state iterations allocate nothing in this phase.
//! 2. **CP step** — the rest of one fused CP-ALS iteration
//!    ([`cp_iteration_from_m1`]): H from the pre-computed `M¹`, then the
//!    mode-2 sweep (the iteration's **only** cold traversal of the packed
//!    slices, caching `Z_k = Y_kᵀ H`), then the mode-3 epilogue — so
//!    `Y_k·V` runs exactly once per subject and the packed slices are
//!    streamed cold exactly once per iteration (both asserted in
//!    `metrics::flops`).
//!
//! All per-subject work runs on one persistent [`Pool`] created per fit,
//! chunked by one per-fit [`ChunkPlan`] balanced on per-subject nnz
//! (heavy-tailed cohorts can't strand a sweep behind one overloaded
//! chunk; boundaries depend only on the data, so trajectories stay
//! bitwise identical across worker counts).
//!
//! The SSE tracked for convergence uses the decomposition
//! `‖X_k − Q_k M_k‖² = ‖X_k‖² − ‖Y_k‖² + ‖Y_k − M_k‖²` (exact whenever
//! `Q_kᵀQ_k = I`, i.e. all `I_k ≥ R`; slices shorter than the rank make it
//! an upper-bound approximation, which is also what the reference Matlab
//! implementation tracks).
//!
//! The loop itself is **inverted into a [`FitSession`]**: construction
//! performs the one-time work (budget admission → arena pack →
//! initialization or warm-start), [`FitSession::step`] runs one ALS
//! iteration, and [`FitSession::finish`] materializes the model. The
//! batch entry points [`fit_parafac2`] / [`fit_parafac2_traced`] are thin
//! drivers over a borrowed-data session and preserve the historical
//! floating-point sequence bit for bit (the golden-trajectory gate in
//! `bench::als_runner` pins it). The service layer drives owned-data
//! sessions concurrently on one shared pool.

use super::baseline::{cp_iteration_baseline, BaselinePhases};
use super::cp_als::{cp_iteration_from_m1, CpFactors, CpOptions};
use super::init::{initialize, InitMethod};
use super::intermediate::PackedY;
use super::model::{FitStats, Parafac2Model};
use super::mttkrp::FusedScratch;
use super::procrustes::{
    procrustes_all_into, procrustes_pack_mode1, scratch_heap_bytes, subject_plan, SubjectScratch,
};
use crate::linalg::Mat;
use crate::sparse::{CompactX, IrregularTensor};
use crate::threadpool::{ChunkPlan, Pool};
use crate::util::membudget::{BudgetExceeded, MemBudget, SharedCharge};
use crate::util::timer::Stopwatch;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Which step-2 engine to use.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// SPARTan (paper Algorithm 3): packed slices, no tensor
    /// materialization, no Khatri-Rao products.
    #[default]
    Spartan,
    /// "Sparse PARAFAC2" baseline: explicit COO tensor + TTB-style MTTKRP.
    Baseline,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "spartan" => Some(Backend::Spartan),
            "baseline" | "sparse-parafac2" => Some(Backend::Baseline),
            _ => None,
        }
    }
}

/// Fitting configuration.
#[derive(Clone, Debug)]
pub struct Parafac2Config {
    /// Target rank R.
    pub rank: usize,
    /// Maximum outer iterations.
    pub max_iters: usize,
    /// Convergence: stop when |ΔSSE|/SSE < tol.
    pub tol: f64,
    /// Non-negativity on V and `{S_k}` (paper §3.2).
    pub nonneg: bool,
    /// V initialization.
    pub init: InitMethod,
    /// Worker threads (0 = all cores).
    pub workers: usize,
    /// RNG seed for initialization.
    pub seed: u64,
    /// Step-2 engine.
    pub backend: Backend,
    /// Memory budget for the baseline's intermediates (None = unlimited).
    pub mem_budget: Option<u64>,
}

impl Default for Parafac2Config {
    fn default() -> Self {
        Parafac2Config {
            rank: 10,
            max_iters: 100,
            tol: 1e-6,
            nonneg: true,
            init: InitMethod::Random,
            workers: 0,
            seed: 42,
            backend: Backend::Spartan,
            mem_budget: None,
        }
    }
}

/// Fitting failure modes.
#[derive(Debug)]
pub enum FitError {
    /// The baseline exhausted its memory budget (the paper's "OoM").
    OutOfMemory(BudgetExceeded),
    /// Invalid configuration.
    Config(String),
}

impl std::fmt::Display for FitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FitError::OutOfMemory(e) => write!(f, "out of memory: {e}"),
            FitError::Config(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for FitError {}

/// Per-iteration progress (also exposed to benches for time-per-iteration
/// tables).
#[derive(Clone, Debug)]
pub struct IterationRecord {
    pub iter: usize,
    pub sse: f64,
    pub fit: f64,
    /// Seconds in the pack-fused sweep (Procrustes + repack + the mode-1
    /// MTTKRP it emits; the baseline backend's plain pack).
    pub procrustes_secs: f64,
    /// Seconds in the rest of the CP step (modes 2–3 + solves).
    pub cp_secs: f64,
}

/// How a [`FitSession`] holds its input tensor.
///
/// Borrowed sessions (the batch `fit_parafac2*` drivers) leave ownership
/// with the caller; owned sessions (service jobs) carry the tensor in and
/// — unless [`SessionOptions::keep_data`] is set — **release it after
/// initialization**: the fit path reads only the resident compact-X arena
/// from there on (the arena caches `‖X_k‖²` too), so the original CSR
/// slices are dead weight for a fit-only job. The session's budget charge
/// shrinks accordingly, which is the ROADMAP's memory-diet fix made
/// assertable through [`MemBudget::peak`].
pub enum DataHandle<'d> {
    Borrowed(&'d IrregularTensor),
    Owned(IrregularTensor),
    /// CSR slices already released (fit-only owned session, post-init).
    Released,
}

impl<'d> DataHandle<'d> {
    fn get(&self) -> Option<&IrregularTensor> {
        match self {
            DataHandle::Borrowed(d) => Some(d),
            DataHandle::Owned(d) => Some(d),
            DataHandle::Released => None,
        }
    }
}

/// Initial factors taken from a previously fitted model (COPA-style
/// repeated fits of an updated cohort): the session starts ALS from these
/// instead of running [`initialize`], skipping the seeded RNG entirely.
#[derive(Clone, Debug)]
pub struct WarmStart {
    pub h: Mat,
    pub v: Mat,
    pub w: Mat,
}

impl WarmStart {
    /// Warm-start factors from a fitted model (e.g. the service's
    /// warm-model cache). The model's `{Q_k}` are not needed — the first
    /// Procrustes sweep recomputes them from H/V/W.
    pub fn from_model(m: &Parafac2Model) -> WarmStart {
        WarmStart { h: m.h.clone(), v: m.v.clone(), w: m.w.clone() }
    }
}

/// Loop state beyond the factors, captured at an iteration boundary —
/// together with a [`WarmStart`] of H/V/W this is everything a durable
/// checkpoint needs to continue a fit **bitwise identically** to one that
/// never stopped (the factors determine the remaining trajectory; the
/// fields here restore the convergence test, the history, and the
/// already-spent counters). Produced by [`FitSession::resume_state`] and
/// consumed by [`FitSession::restore`]; the on-disk encoding lives in
/// `service::checkpoint`, keeping the engine codec-free.
#[derive(Clone, Debug, Default)]
pub struct ResumeState {
    /// Completed ALS iterations at the boundary.
    pub iter: usize,
    /// IEEE-754 bits of the tracked `prev_sse` (feeds `sse_converged`;
    /// `f64::INFINITY` bits before the first iteration). Transported as
    /// bits because the value must survive serialization exactly.
    pub prev_sse_bits: u64,
    /// Whether the tol test had already fired.
    pub converged: bool,
    /// Per-iteration fit values so far.
    pub fit_history: Vec<f64>,
    /// Work counters accumulated before the boundary (a restored session
    /// adds them to its own arena tallies at finish).
    pub yv_products: u64,
    pub traversals: u64,
    pub x_traversals: u64,
    /// Wall-clock already spent (summed into the final stats).
    pub procrustes_secs: f64,
    pub cp_secs: f64,
    pub total_secs: f64,
    /// Recovery counters carried across the interruption.
    pub shard_reconnects: u64,
    pub shard_retries: u64,
}

/// Counters spent before a [`FitSession::restore`], added on top of the
/// live arena tallies when [`FitSession::finish`] publishes `FitStats`
/// (which otherwise *overwrites* the stats counters from the arenas).
#[derive(Clone, Copy, Debug, Default)]
struct CarriedCounters {
    yv_products: u64,
    traversals: u64,
    x_traversals: u64,
    total_secs: f64,
}

/// Per-session knobs beyond [`Parafac2Config`]. `Default` reproduces the
/// batch drivers exactly: private pool, budget from `cfg.mem_budget`, cold
/// init, data kept, no cancellation.
#[derive(Default)]
pub struct SessionOptions {
    /// Share an existing pool instead of spawning one per fit
    /// (`cfg.workers` is then ignored). The pool's job queue interleaves
    /// any number of concurrent sessions over one worker set.
    pub pool: Option<Pool>,
    /// Charge a shared budget instead of a per-fit one — admission across
    /// concurrent sessions is enforced against the same tracker.
    pub budget: Option<Arc<MemBudget>>,
    /// Start from these factors instead of [`initialize`].
    pub warm: Option<WarmStart>,
    /// Keep the original CSR slices resident even in an owned fit-only
    /// session (needed when the caller wants exact-SSE reporting against
    /// the data afterwards, e.g. [`Parafac2Model::fit`]).
    pub keep_data: bool,
    /// External cancel flag, checked at step entry and between the two
    /// sweeps of an iteration — cancellation takes effect within one ALS
    /// iteration and never perturbs the trajectory (a cancelled sweep is
    /// discarded; the factors still hold the last completed iterate).
    pub cancel: Option<Arc<AtomicBool>>,
}

/// What one [`FitSession::step`] call did.
#[derive(Debug)]
pub enum StepOutcome {
    /// One more ALS iteration completed.
    Iterated(IterationRecord),
    /// Converged (tol) or `max_iters` reached — nothing ran; the session
    /// is ready for [`FitSession::finish`].
    Done,
    /// The cancel flag was observed — nothing took effect; the factors
    /// hold the last completed iterate and [`FitSession::finish`] still
    /// produces a valid (partial) model.
    Cancelled,
}

/// A PARAFAC2 fit **inverted into a session object**: construction does
/// the one-time work (admission charge → arena pack → init), after which
/// the owner drives [`FitSession::step`] one ALS iteration at a time and
/// [`FitSession::finish`] materializes the model. The batch
/// [`fit_parafac2*`](fit_parafac2) entry points are thin drivers over
/// this, preserving their exact floating-point sequence — the
/// golden-trajectory gate pins that.
///
/// The session owns everything a fit touches — the resident [`CompactX`]
/// arena, the packed-`Y` arena, the per-chunk scratch, and the frozen
/// [`ChunkPlan`] — so any number of sessions can interleave on one shared
/// [`Pool`] without contending on anything but worker time. Memory is
/// accounted up front: construction charges the arena's *upper-bound
/// estimate* (plus the CSR bytes for owned data) against the budget via
/// [`SharedCharge`], fails with [`FitError::OutOfMemory`] **before
/// packing** when it does not fit, and shrinks the charge to the actual
/// footprint after the pack (and again after a fit-only session releases
/// its CSR slices).
pub struct FitSession<'d> {
    cfg: Parafac2Config,
    data: DataHandle<'d>,
    pool: Pool,
    budget: Arc<MemBudget>,
    /// Admission charge: CSR bytes (owned data only) + arena footprint.
    charge: SharedCharge,
    total_sw: Stopwatch,
    plan: ChunkPlan,
    cx: CompactX,
    x_norm_sq: f64,
    x_norm: f64,
    factors: CpFactors,
    opts: CpOptions,
    stats: FitStats,
    baseline_phases: BaselinePhases,
    prev_sse: f64,
    iters_done: usize,
    converged: bool,
    cancel: Arc<AtomicBool>,
    y: PackedY,
    scratch: FusedScratch,
    sweep_scratch: Vec<SubjectScratch>,
    carried: CarriedCounters,
}

impl<'d> FitSession<'d> {
    /// Borrowed-data session with per-fit pool and budget — the exact
    /// construction the batch drivers perform.
    pub fn new(data: &'d IrregularTensor, cfg: &Parafac2Config) -> Result<FitSession<'d>, FitError> {
        FitSession::with_options(DataHandle::Borrowed(data), cfg, SessionOptions::default())
    }

    /// Full-control constructor. `DataHandle::Owned` + default `keep_data`
    /// gives the fit-only memory diet: the CSR slices are dropped right
    /// after initialization and their budget charge released.
    pub fn with_options(
        data: DataHandle<'d>,
        cfg: &Parafac2Config,
        options: SessionOptions,
    ) -> Result<FitSession<'d>, FitError> {
        let tensor = data.get().expect("fresh DataHandle");
        if cfg.rank == 0 {
            return Err(FitError::Config("rank must be ≥ 1".into()));
        }
        if cfg.rank > tensor.j() {
            return Err(FitError::Config(format!(
                "rank {} exceeds variable count J={}",
                cfg.rank,
                tensor.j()
            )));
        }
        let pool = options.pool.unwrap_or_else(|| Pool::new(cfg.workers));
        let budget: Arc<MemBudget> = match options.budget {
            Some(b) => b,
            None => match cfg.mem_budget {
                Some(b) => MemBudget::limited(b),
                None => MemBudget::unlimited(),
            },
        };
        let total_sw = Stopwatch::start();

        // Persistent per-fit arenas and schedule: the resident compact-X
        // arena (values + local column ids, packed once — every subsequent
        // Procrustes sweep streams it exactly once per subject), the
        // packed-Y slice buffers, the per-chunk sweep scratch, the fused
        // sweep's Z_k cache, and the nnz-balanced chunk plan are built
        // once and reused (refilled in place) by every iteration.
        let plan = subject_plan(tensor);

        // Admission happens BEFORE the pack: charge the arena's upper
        // bound (plus the CSR bytes when the session owns them), so an
        // over-budget fit fails structurally with nothing allocated.
        let owned = matches!(data, DataHandle::Owned(_));
        let data_bytes = if owned { tensor.heap_bytes() } else { 0 };
        let estimate = CompactX::estimate_heap_bytes(tensor);
        let mut charge = SharedCharge::new(&budget, data_bytes + estimate)
            .map_err(FitError::OutOfMemory)?;

        let cx = CompactX::pack(tensor, &pool, &plan);
        // Estimate → actual (the estimate is an upper bound; `min` guards
        // the assert-only-shrinks contract against allocator slack).
        charge.shrink_to((data_bytes + cx.heap_bytes()).min(charge.bytes()));
        // ‖X‖² served from the arena's pack-time per-slice caches —
        // bitwise identical to `tensor.fro_norm_sq()`, and the last
        // fit-path read of the original CSR goes away with it.
        let x_norm_sq = cx.norm_sq();
        let x_norm = x_norm_sq.sqrt();

        let factors = match options.warm {
            Some(warm) => {
                let (r, j, k) = (cfg.rank, tensor.j(), tensor.k());
                if warm.h.shape() != (r, r) || warm.v.shape() != (j, r) || warm.w.shape() != (k, r)
                {
                    return Err(FitError::Config(format!(
                        "warm-start shapes {:?}/{:?}/{:?} do not match rank {r}, J={j}, K={k}",
                        warm.h.shape(),
                        warm.v.shape(),
                        warm.w.shape()
                    )));
                }
                CpFactors { h: warm.h, v: warm.v, w: warm.w }
            }
            None => {
                let init = initialize(tensor, cfg.rank, cfg.init, cfg.seed, &pool);
                CpFactors { h: init.h, v: init.v, w: init.w }
            }
        };
        let opts = CpOptions { nonneg: cfg.nonneg };

        let y = PackedY::empty(tensor.j());

        // Memory diet: a fit-only owned session has no further use for the
        // original CSR slices — everything downstream (both sweeps, the
        // final report pass) reads the arena. Drop them and give the bytes
        // back to the budget.
        let mut data = data;
        if owned && !options.keep_data {
            data = DataHandle::Released;
            charge.shrink_to(charge.bytes() - data_bytes);
        }

        let sweep_scratch = SubjectScratch::for_plan(&plan);
        Ok(FitSession {
            cfg: cfg.clone(),
            data,
            pool,
            budget,
            charge,
            total_sw,
            plan,
            cx,
            x_norm_sq,
            x_norm,
            factors,
            opts,
            stats: FitStats::default(),
            baseline_phases: BaselinePhases::default(),
            prev_sse: f64::INFINITY,
            iters_done: 0,
            converged: false,
            cancel: options.cancel.unwrap_or_else(|| Arc::new(AtomicBool::new(false))),
            y,
            scratch: FusedScratch::new(),
            sweep_scratch,
            carried: CarriedCounters::default(),
        })
    }

    /// Restore the loop state captured by [`FitSession::resume_state`] on
    /// a freshly constructed session (whose `SessionOptions::warm` carried
    /// the checkpoint's H/V/W). The next [`FitSession::step`] then runs
    /// iteration `rs.iter` exactly as the uninterrupted fit would have:
    /// the factors determine the sweep, `prev_sse` feeds the convergence
    /// test bit-for-bit, and the carried counters are added back at
    /// [`FitSession::finish`]. Callers revalidate the re-packed arena via
    /// [`FitSession::slice_norm_sq`] *before* trusting the restore.
    pub fn restore(&mut self, rs: ResumeState) {
        self.iters_done = rs.iter;
        self.prev_sse = f64::from_bits(rs.prev_sse_bits);
        self.converged = rs.converged;
        self.stats.fit_history = rs.fit_history;
        self.stats.procrustes_secs = rs.procrustes_secs;
        self.stats.cp_secs = rs.cp_secs;
        self.stats.shard_reconnects = rs.shard_reconnects;
        self.stats.shard_retries = rs.shard_retries;
        self.stats.resumed_from_iter = rs.iter as u64;
        self.carried = CarriedCounters {
            yv_products: rs.yv_products,
            traversals: rs.traversals,
            x_traversals: rs.x_traversals,
            total_secs: rs.total_secs,
        };
    }

    /// Snapshot the loop state at the current iteration boundary — the
    /// non-factor half of a checkpoint (the factor half is
    /// [`FitSession::factors`]). Counters include anything carried from an
    /// earlier restore, so checkpoint-of-a-resumed-fit composes.
    pub fn resume_state(&self) -> ResumeState {
        ResumeState {
            iter: self.iters_done,
            prev_sse_bits: self.prev_sse.to_bits(),
            converged: self.converged,
            fit_history: self.stats.fit_history.clone(),
            yv_products: self.carried.yv_products + self.y.yv_products(),
            traversals: self.carried.traversals + self.y.traversals(),
            x_traversals: self.carried.x_traversals + self.cx.x_traversals(),
            procrustes_secs: self.stats.procrustes_secs,
            cp_secs: self.stats.cp_secs,
            total_secs: self.carried.total_secs + self.total_sw.elapsed_secs(),
            shard_reconnects: self.stats.shard_reconnects,
            shard_retries: self.stats.shard_retries,
        }
    }

    /// The current factor iterate `(H, V, W)` — at an iteration boundary
    /// this is everything the remaining trajectory depends on.
    pub fn factors(&self) -> (&Mat, &Mat, &Mat) {
        (&self.factors.h, &self.factors.v, &self.factors.w)
    }

    /// Per-slice `‖X_k‖²` in subject order, read from the packed arena's
    /// pack-time caches. A resume compares these bits against the
    /// checkpoint's — the same data-identity contract the shard `reattach`
    /// verb enforces — so silently diverging data is rejected, never
    /// refit.
    pub fn slice_norm_sq(&self) -> Vec<f64> {
        self.cx.slices.iter().map(|s| s.norm_sq()).collect()
    }

    /// Run **one** ALS iteration. Returns [`StepOutcome::Done`] once
    /// converged or `max_iters` is reached (idempotently), and
    /// [`StepOutcome::Cancelled`] when the cancel flag is up — at step
    /// entry, or at the checkpoint between the Procrustes sweep and the CP
    /// step (the sweep's outputs are then discarded; re-stepping after
    /// clearing the flag reproduces them from the unchanged factors).
    pub fn step(&mut self) -> Result<StepOutcome, FitError> {
        if self.converged || self.iters_done >= self.cfg.max_iters {
            return Ok(StepOutcome::Done);
        }
        if self.cancel.load(Ordering::Relaxed) {
            return Ok(StepOutcome::Cancelled);
        }
        let iter = self.iters_done;

        // --- step 1: Procrustes + packing (into the arena); the SPARTan
        // backend also emits M¹ while each slice is cache-hot ------------
        let sw = Stopwatch::start();
        let fused = match self.cfg.backend {
            Backend::Spartan => Some(procrustes_pack_mode1(
                &self.cx,
                &self.factors.v,
                &self.factors.h,
                &self.factors.w,
                &self.pool,
                &self.plan,
                &mut self.y,
                &mut self.sweep_scratch,
            )),
            Backend::Baseline => {
                let _ = procrustes_all_into(
                    &self.cx,
                    &self.factors.v,
                    &self.factors.h,
                    &self.factors.w,
                    &self.pool,
                    &self.plan,
                    false,
                    &mut self.y,
                    &mut self.sweep_scratch,
                );
                None
            }
        };
        let procrustes_secs = sw.elapsed_secs();

        // Cancellation checkpoint between sweeps: the sweep only refilled
        // the session-private Y/M¹ from the (untouched) factors, so
        // discarding it leaves the trajectory exactly at the last
        // completed iterate. Its timing is discarded with it.
        if self.cancel.load(Ordering::Relaxed) {
            return Ok(StepOutcome::Cancelled);
        }
        self.stats.procrustes_secs += procrustes_secs;

        // --- step 2: the rest of one CP-ALS iteration on Y ---------------
        let sw = Stopwatch::start();
        let cp_stats = match fused {
            Some(sweep) => cp_iteration_from_m1(
                &self.y,
                sweep.m1,
                sweep.yv_products,
                &mut self.factors,
                self.opts,
                &self.pool,
                &self.plan,
                &mut self.scratch,
            ),
            None => cp_iteration_baseline(
                &self.y,
                &mut self.factors,
                self.opts,
                &self.budget,
                &mut self.baseline_phases,
            )
            .map_err(FitError::OutOfMemory)?,
        };
        let cp_secs = sw.elapsed_secs();
        self.stats.cp_secs += cp_secs;

        if iter == 0 {
            crate::debug!(
                "arena: compact X {} B, packed Y {} B, sweep scratch {} B, fused scratch {} B",
                self.cx.heap_bytes(),
                self.y.heap_bytes(),
                scratch_heap_bytes(&self.sweep_scratch),
                self.scratch.heap_bytes()
            );
        }

        let sse = sse_from_parts(self.x_norm_sq, self.y.norm_sq(), cp_stats.y_residual_sq);
        let fit = fit_from_sse(sse, self.x_norm);
        self.stats.fit_history.push(fit);
        self.iters_done = iter + 1;
        crate::debug!("iter {iter}: sse={sse:.6e} fit={fit:.6}");

        // --- convergence --------------------------------------------------
        if sse_converged(self.prev_sse, sse, self.cfg.tol) {
            self.converged = true;
        }
        self.prev_sse = sse;

        Ok(StepOutcome::Iterated(IterationRecord { iter, sse, fit, procrustes_secs, cp_secs }))
    }

    /// Final pass: materialize Q_k for the fitted factors (kept out of the
    /// loop so the loop's footprint stays at the packed-Y size), and
    /// recompute the SSE against the refreshed Q_k so the reported fit is
    /// exactly the returned model's (the refresh strictly improves on the
    /// last tracked SSE). Reuses the same arena. Valid after any number of
    /// steps — including zero, or a cancellation — so a cancelled job
    /// still yields its last completed iterate (e.g. for the warm cache).
    pub fn finish(mut self) -> Parafac2Model {
        let qs = procrustes_all_into(
            &self.cx,
            &self.factors.v,
            &self.factors.h,
            &self.factors.w,
            &self.pool,
            &self.plan,
            true,
            &mut self.y,
            &mut self.sweep_scratch,
        );
        let m3 =
            super::mttkrp::mttkrp_mode3(&self.y, &self.factors.h, &self.factors.v, &self.pool, &self.plan);
        let final_res = super::cp_als::residual_stats(&m3, &self.factors, self.y.norm_sq());
        let final_sse = sse_from_parts(self.x_norm_sq, self.y.norm_sq(), final_res.y_residual_sq);
        let mut stats = self.stats;
        stats.yv_products = self.carried.yv_products + self.y.yv_products();
        stats.traversals = self.carried.traversals + self.y.traversals();
        stats.x_traversals = self.carried.x_traversals + self.cx.x_traversals();
        stats.heap_bytes = self.cx.heap_bytes()
            + self.y.heap_bytes()
            + scratch_heap_bytes(&self.sweep_scratch)
            + self.scratch.heap_bytes();

        stats.iterations = self.iters_done;
        stats.final_sse = final_sse;
        stats.final_fit = fit_from_sse(final_sse, self.x_norm);
        stats.kernel_backend = crate::linalg::kernels::active_backend().name().to_string();
        stats.total_secs = self.carried.total_secs + self.total_sw.elapsed_secs();
        stats.secs_per_iter = if self.iters_done > 0 {
            (stats.procrustes_secs + stats.cp_secs) / self.iters_done as f64
        } else {
            0.0
        };

        Parafac2Model {
            rank: self.cfg.rank,
            h: self.factors.h,
            v: self.factors.v,
            w: self.factors.w,
            q: qs.expect("keep_q requested"),
            stats,
        }
    }

    /// ALS iterations completed so far.
    pub fn iterations(&self) -> usize {
        self.iters_done
    }

    /// Whether the tol-based convergence test has fired.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// The session's cancel flag (the one passed in, or a private one).
    /// Setting it stops the fit within one ALS iteration.
    pub fn cancel_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.cancel)
    }

    /// Progress so far (fit history, phase timings). Counters and final
    /// figures are filled by [`FitSession::finish`].
    pub fn stats(&self) -> &FitStats {
        &self.stats
    }

    /// Bytes this session currently holds against its budget.
    pub fn charged_bytes(&self) -> u64 {
        self.charge.bytes()
    }

    /// The budget this session charges (shared or per-fit).
    pub fn budget(&self) -> &Arc<MemBudget> {
        &self.budget
    }

    /// Whether the original CSR slices are still resident.
    pub fn holds_data(&self) -> bool {
        self.data.get().is_some()
    }
}

// ---------------------------------------------------------------------------
// The per-iteration scalar seam
//
// The sharded coordinator (`service::shard`) re-evaluates exactly these
// expressions from merged partials; sharing the functions (not copies of
// the formulas) is what makes "bitwise identical to a local fit" a
// property of the code rather than of reviewer vigilance.

/// SSE of the current iterate from the tracked decomposition
/// `‖X‖² − ‖Y‖² + ‖Y − M‖²` (module docs) — evaluated in exactly this
/// operation order by both the local step and the sharded merge.
pub(crate) fn sse_from_parts(x_norm_sq: f64, y_norm_sq: f64, y_residual_sq: f64) -> f64 {
    (x_norm_sq - y_norm_sq + y_residual_sq).max(0.0)
}

/// Fit = `1 − √SSE / ‖X‖`.
pub(crate) fn fit_from_sse(sse: f64, x_norm: f64) -> f64 {
    1.0 - sse.sqrt() / x_norm
}

/// The relative-ΔSSE convergence test (`|ΔSSE|/SSE < tol`), total over the
/// first iteration's infinite `prev_sse`.
pub(crate) fn sse_converged(prev_sse: f64, sse: f64, tol: f64) -> bool {
    if !prev_sse.is_finite() {
        return false;
    }
    let denom = prev_sse.max(f64::MIN_POSITIVE);
    (prev_sse - sse).abs() / denom < tol
}

/// Fit a PARAFAC2 model.
pub fn fit_parafac2(data: &IrregularTensor, cfg: &Parafac2Config) -> Result<Parafac2Model, FitError> {
    let mut records = Vec::new();
    fit_parafac2_traced(data, cfg, &mut |r| records.push(r))
}

/// Fit with a per-iteration callback (bench instrumentation). Thin driver
/// over [`FitSession`]: construct, step to completion, finish.
pub fn fit_parafac2_traced(
    data: &IrregularTensor,
    cfg: &Parafac2Config,
    on_iter: &mut dyn FnMut(IterationRecord),
) -> Result<Parafac2Model, FitError> {
    let mut session = FitSession::new(data, cfg)?;
    loop {
        match session.step()? {
            StepOutcome::Iterated(rec) => on_iter(rec),
            // no cancel flag is wired here, so Cancelled cannot occur —
            // handled all the same to keep the driver total
            StepOutcome::Done | StepOutcome::Cancelled => break,
        }
    }
    Ok(session.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, random_orthonormal, Mat};
    use crate::sparse::Csr;
    use crate::util::rng::Pcg64;

    /// Generate data exactly following a planted PARAFAC2 model.
    fn planted(rng: &mut Pcg64, k: usize, j: usize, r: usize) -> (IrregularTensor, Mat, Mat) {
        let h = Mat::rand_normal(r, r, rng);
        let v = Mat::rand_uniform(j, r, rng);
        let w = Mat::from_fn(k, r, |_, _| rng.uniform(0.5, 2.0));
        let slices: Vec<Csr> = (0..k)
            .map(|kk| {
                let ik = r + rng.range(3, 9);
                let q = random_orthonormal(ik, r, rng);
                let u = blas::matmul(&q, &h);
                let mut us = u;
                for i in 0..us.rows() {
                    for (c, x) in us.row_mut(i).iter_mut().enumerate() {
                        *x *= w[(kk, c)];
                    }
                }
                Csr::from_dense(&blas::matmul_a_bt(&us, &v))
            })
            .collect();
        (IrregularTensor::new(slices), v, w)
    }

    #[test]
    fn fits_planted_model_to_high_fit() {
        let mut rng = Pcg64::seed(171);
        let (data, _, _) = planted(&mut rng, 12, 10, 3);
        let cfg = Parafac2Config {
            rank: 3,
            max_iters: 200,
            tol: 1e-9,
            nonneg: false,
            seed: 5,
            workers: 1,
            ..Default::default()
        };
        let model = fit_parafac2(&data, &cfg).unwrap();
        assert!(model.stats.final_fit > 0.95, "fit {}", model.stats.final_fit);
        // internal fit estimate must agree with the exact one
        let exact = model.fit(&data);
        assert!(
            (model.stats.final_fit - exact).abs() < 1e-6,
            "{} vs {exact}",
            model.stats.final_fit
        );
    }

    #[test]
    fn recovers_planted_factors() {
        let mut rng = Pcg64::seed(172);
        let (data, v_true, w_true) = planted(&mut rng, 15, 8, 2);
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: 300,
            tol: 1e-10,
            nonneg: false,
            seed: 11,
            workers: 1,
            init: InitMethod::SvdWarm,
            ..Default::default()
        };
        let model = fit_parafac2(&data, &cfg).unwrap();
        let fms = crate::linalg::fms_joint(&[(&model.v, &v_true), (&model.w, &w_true)]);
        assert!(fms > 0.98, "joint FMS {fms}");
    }

    #[test]
    fn sse_monotonically_decreases() {
        let mut rng = Pcg64::seed(173);
        let (data, _, _) = planted(&mut rng, 8, 9, 3);
        let cfg = Parafac2Config {
            rank: 3,
            max_iters: 25,
            tol: 0.0, // run all iterations
            nonneg: true,
            workers: 1,
            ..Default::default()
        };
        let mut sses = Vec::new();
        let _ = fit_parafac2_traced(&data, &cfg, &mut |r| sses.push(r.sse)).unwrap();
        for win in sses.windows(2) {
            assert!(
                win[1] <= win[0] * (1.0 + 1e-7) + 1e-9,
                "SSE increased: {} -> {}",
                win[0],
                win[1]
            );
        }
    }

    #[test]
    fn backends_agree() {
        let mut rng = Pcg64::seed(174);
        let (data, _, _) = planted(&mut rng, 6, 7, 2);
        let mk = |backend| Parafac2Config {
            rank: 2,
            max_iters: 12,
            tol: 0.0,
            nonneg: true,
            seed: 3,
            workers: 1,
            backend,
            ..Default::default()
        };
        let a = fit_parafac2(&data, &mk(Backend::Spartan)).unwrap();
        let b = fit_parafac2(&data, &mk(Backend::Baseline)).unwrap();
        assert!(a.v.max_abs_diff(&b.v) < 1e-6, "V diverged");
        assert!(a.w.max_abs_diff(&b.w) < 1e-6, "W diverged");
        assert!((a.stats.final_sse - b.stats.final_sse).abs() < 1e-6 * (1.0 + a.stats.final_sse));
    }

    #[test]
    fn baseline_oom_is_reported() {
        let mut rng = Pcg64::seed(175);
        let (data, _, _) = planted(&mut rng, 6, 7, 2);
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: 3,
            backend: Backend::Baseline,
            mem_budget: Some(32),
            workers: 1,
            ..Default::default()
        };
        match fit_parafac2(&data, &cfg) {
            Err(FitError::OutOfMemory(_)) => {}
            other => panic!("expected OoM, got {other:?}"),
        }
    }

    #[test]
    fn invalid_config_rejected() {
        let mut rng = Pcg64::seed(176);
        let (data, _, _) = planted(&mut rng, 3, 5, 2);
        let cfg = Parafac2Config { rank: 0, ..Default::default() };
        assert!(matches!(fit_parafac2(&data, &cfg), Err(FitError::Config(_))));
        let cfg = Parafac2Config { rank: 99, ..Default::default() };
        assert!(matches!(fit_parafac2(&data, &cfg), Err(FitError::Config(_))));
    }

    #[test]
    fn nonneg_constraints_respected() {
        let mut rng = Pcg64::seed(177);
        let (data, _, _) = planted(&mut rng, 6, 8, 2);
        let cfg = Parafac2Config { rank: 2, max_iters: 10, nonneg: true, workers: 1, ..Default::default() };
        let model = fit_parafac2(&data, &cfg).unwrap();
        assert!(model.v.data().iter().all(|&x| x >= 0.0));
        assert!(model.w.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn fit_counts_one_yv_and_one_traversal_per_subject_per_iteration() {
        // End-to-end teeth for the pack-fusion: a Spartan fit of N
        // iterations on K subjects performs exactly N·K `Y_k·V` products
        // (all emitted during the pack) and N·K cold slice traversals
        // (mode 2 only), plus the final-report pass's K-standalone mode 3.
        let mut rng = Pcg64::seed(179);
        let (data, _, _) = planted(&mut rng, 9, 8, 2);
        let iters = 7usize;
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: iters,
            tol: 0.0,
            workers: 2,
            ..Default::default()
        };
        let model = fit_parafac2(&data, &cfg).unwrap();
        let k = data.k() as u64;
        assert_eq!(model.stats.yv_products, iters as u64 * k);
        assert_eq!(model.stats.traversals, (iters as u64 + 1) * k);
    }

    #[test]
    fn fit_counts_one_x_traversal_per_subject_per_iteration() {
        // End-to-end teeth for the compact-X arena: a Spartan fit of N
        // iterations on K subjects makes exactly K cold X passes for the
        // one-time arena pack, K per iteration (the C_k stage — the
        // repack rides it), and K for the final report pass. The
        // pre-arena structure cost 2K per iteration (target + repack both
        // re-streamed the CSR); metrics::flops pins that 2→1 drop against
        // the separate two-sweep structure.
        let mut rng = Pcg64::seed(180);
        let (data, _, _) = planted(&mut rng, 9, 8, 2);
        let iters = 6usize;
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: iters,
            tol: 0.0,
            workers: 2,
            ..Default::default()
        };
        let model = fit_parafac2(&data, &cfg).unwrap();
        let k = data.k() as u64;
        assert_eq!(model.stats.x_traversals, (iters as u64 + 2) * k);
        // and the resident footprint is accounted (arena + packed Y +
        // scratch must all be nonzero once a fit ran)
        assert!(model.stats.heap_bytes > 0);
    }

    #[test]
    fn interleaved_sessions_on_shared_pool_bitwise_match_batch() {
        // Two sessions alternate steps on ONE shared pool — the service's
        // multiplexing shape — and must each reproduce their standalone
        // batch fit bit for bit.
        let mut rng = Pcg64::seed(181);
        let (d1, _, _) = planted(&mut rng, 10, 9, 3);
        let (d2, _, _) = planted(&mut rng, 7, 11, 2);
        let cfg1 = Parafac2Config {
            rank: 3,
            max_iters: 6,
            tol: 0.0,
            workers: 3,
            ..Default::default()
        };
        let cfg2 = Parafac2Config { rank: 2, seed: 17, ..cfg1.clone() };
        let b1 = fit_parafac2(&d1, &cfg1).unwrap();
        let b2 = fit_parafac2(&d2, &cfg2).unwrap();

        let pool = Pool::new(3);
        let opts = |p: &Pool| SessionOptions { pool: Some(p.clone()), ..Default::default() };
        let mut s1 = FitSession::with_options(DataHandle::Borrowed(&d1), &cfg1, opts(&pool)).unwrap();
        let mut s2 = FitSession::with_options(DataHandle::Borrowed(&d2), &cfg2, opts(&pool)).unwrap();
        let (mut done1, mut done2) = (false, false);
        while !(done1 && done2) {
            if !done1 {
                done1 = matches!(s1.step().unwrap(), StepOutcome::Done);
            }
            if !done2 {
                done2 = matches!(s2.step().unwrap(), StepOutcome::Done);
            }
        }
        let (m1, m2) = (s1.finish(), s2.finish());
        assert_eq!(m1.h.data(), b1.h.data());
        assert_eq!(m1.v.data(), b1.v.data());
        assert_eq!(m1.w.data(), b1.w.data());
        assert_eq!(m2.h.data(), b2.h.data());
        assert_eq!(m2.v.data(), b2.v.data());
        assert_eq!(m2.w.data(), b2.w.data());
        assert_eq!(m1.stats.iterations, b1.stats.iterations);
    }

    #[test]
    fn cancellation_stops_and_resume_stays_on_trajectory() {
        let mut rng = Pcg64::seed(182);
        let (data, _, _) = planted(&mut rng, 8, 9, 2);
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: 5,
            tol: 0.0,
            workers: 1,
            ..Default::default()
        };
        let mut plain = Vec::new();
        let batch = fit_parafac2_traced(&data, &cfg, &mut |r| plain.push(r.sse)).unwrap();

        let cancel = Arc::new(AtomicBool::new(false));
        let mut s = FitSession::with_options(
            DataHandle::Borrowed(&data),
            &cfg,
            SessionOptions { cancel: Some(Arc::clone(&cancel)), ..Default::default() },
        )
        .unwrap();
        let mut sses = Vec::new();
        match s.step().unwrap() {
            StepOutcome::Iterated(r) => sses.push(r.sse),
            other => panic!("expected an iteration, got {other:?}"),
        }
        // flag up ⇒ the very next step refuses to iterate
        cancel.store(true, Ordering::Relaxed);
        assert!(matches!(s.step().unwrap(), StepOutcome::Cancelled));
        assert_eq!(s.iterations(), 1, "cancel must not consume an iteration");
        // flag down ⇒ the fit resumes exactly where it left off
        cancel.store(false, Ordering::Relaxed);
        loop {
            match s.step().unwrap() {
                StepOutcome::Iterated(r) => sses.push(r.sse),
                StepOutcome::Done => break,
                StepOutcome::Cancelled => panic!("flag is down"),
            }
        }
        assert_eq!(sses.len(), plain.len());
        for (i, (a, b)) in sses.iter().zip(&plain).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "iter {i} diverged after cancel/resume");
        }
        let model = s.finish();
        assert_eq!(model.v.data(), batch.v.data());
        // a cancelled-for-good session still yields its partial iterate
        let cancel2 = Arc::new(AtomicBool::new(false));
        let mut s2 = FitSession::with_options(
            DataHandle::Borrowed(&data),
            &cfg,
            SessionOptions { cancel: Some(Arc::clone(&cancel2)), ..Default::default() },
        )
        .unwrap();
        let _ = s2.step().unwrap();
        cancel2.store(true, Ordering::Relaxed);
        assert!(matches!(s2.step().unwrap(), StepOutcome::Cancelled));
        let partial = s2.finish();
        assert_eq!(partial.stats.iterations, 1);
        assert_eq!(partial.q.len(), data.k());
    }

    #[test]
    fn warm_start_matches_continued_batch_fit() {
        // fit 3 iters, warm-start 3 more ⇒ bitwise the same endpoint as
        // one uninterrupted 6-iteration fit (the final Q-pass never
        // touches H/V/W, so a model's factors ARE the loop state).
        let mut rng = Pcg64::seed(183);
        let (data, _, _) = planted(&mut rng, 9, 8, 2);
        let mk = |iters| Parafac2Config {
            rank: 2,
            max_iters: iters,
            tol: 0.0,
            workers: 2,
            ..Default::default()
        };
        let first = fit_parafac2(&data, &mk(3)).unwrap();
        let full = fit_parafac2(&data, &mk(6)).unwrap();
        let mut s = FitSession::with_options(
            DataHandle::Borrowed(&data),
            &mk(3),
            SessionOptions { warm: Some(WarmStart::from_model(&first)), ..Default::default() },
        )
        .unwrap();
        while let StepOutcome::Iterated(_) = s.step().unwrap() {}
        let resumed = s.finish();
        assert_eq!(resumed.h.data(), full.h.data());
        assert_eq!(resumed.v.data(), full.v.data());
        assert_eq!(resumed.w.data(), full.w.data());
        for (a, b) in resumed.q.iter().zip(&full.q) {
            assert_eq!(a.data(), b.data());
        }

        // shape mismatches are structured Config errors
        let bad = WarmStart { h: Mat::zeros(3, 3), v: first.v.clone(), w: first.w.clone() };
        let err = FitSession::with_options(
            DataHandle::Borrowed(&data),
            &mk(3),
            SessionOptions { warm: Some(bad), ..Default::default() },
        );
        assert!(matches!(err, Err(FitError::Config(_))));
    }

    #[test]
    fn restore_reproduces_uninterrupted_fit_bitwise() {
        // Checkpoint at iteration 3 of 6 (factors + resume_state), rebuild
        // a fresh session from the snapshot, and finish: the trajectory,
        // yv_products, and traversals must match the uninterrupted fit
        // exactly — the only counter signature of the resume is one extra
        // K of x_traversals (the restore's arena re-pack).
        let mut rng = Pcg64::seed(189);
        let k = 9;
        let (data, _, _) = planted(&mut rng, k, 8, 2);
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: 6,
            tol: 0.0,
            workers: 2,
            ..Default::default()
        };

        let mut full = FitSession::new(&data, &cfg).unwrap();
        while let StepOutcome::Iterated(_) = full.step().unwrap() {}
        let full = full.finish();

        let mut first = FitSession::new(&data, &cfg).unwrap();
        for _ in 0..3 {
            assert!(matches!(first.step().unwrap(), StepOutcome::Iterated(_)));
        }
        let rs = first.resume_state();
        assert_eq!(rs.iter, 3);
        let (h, v, w) = first.factors();
        let warm = WarmStart { h: h.clone(), v: v.clone(), w: w.clone() };
        let norms = first.slice_norm_sq();
        drop(first);

        let mut resumed = FitSession::with_options(
            DataHandle::Borrowed(&data),
            &cfg,
            SessionOptions { warm: Some(warm), ..Default::default() },
        )
        .unwrap();
        // the data-identity gate a real resume enforces before restore
        for (a, b) in resumed.slice_norm_sq().iter().zip(&norms) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        resumed.restore(rs);
        assert_eq!(resumed.iterations(), 3);
        while let StepOutcome::Iterated(_) = resumed.step().unwrap() {}
        let resumed = resumed.finish();

        assert_eq!(resumed.h.data(), full.h.data());
        assert_eq!(resumed.v.data(), full.v.data());
        assert_eq!(resumed.w.data(), full.w.data());
        for (a, b) in resumed.q.iter().zip(&full.q) {
            assert_eq!(a.data(), b.data());
        }
        assert_eq!(resumed.stats.fit_history.len(), full.stats.fit_history.len());
        for (a, b) in resumed.stats.fit_history.iter().zip(&full.stats.fit_history) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(resumed.stats.final_sse.to_bits(), full.stats.final_sse.to_bits());
        assert_eq!(resumed.stats.iterations, full.stats.iterations);
        assert_eq!(resumed.stats.resumed_from_iter, 3);
        assert_eq!(full.stats.resumed_from_iter, 0);
        assert_eq!(resumed.stats.yv_products, full.stats.yv_products);
        assert_eq!(resumed.stats.traversals, full.stats.traversals);
        assert_eq!(resumed.stats.x_traversals, full.stats.x_traversals + k as u64);
    }

    #[test]
    fn session_admission_rejects_before_packing() {
        let mut rng = Pcg64::seed(184);
        let (data, _, _) = planted(&mut rng, 8, 9, 2);
        let cfg = Parafac2Config { rank: 2, workers: 1, ..Default::default() };
        let budget = MemBudget::limited(64); // far below any arena estimate
        let err = FitSession::with_options(
            DataHandle::Borrowed(&data),
            &cfg,
            SessionOptions { budget: Some(Arc::clone(&budget)), ..Default::default() },
        );
        assert!(matches!(err, Err(FitError::OutOfMemory(_))));
        // the rejected charge rolled back — the budget stays serviceable
        assert_eq!(budget.used(), 0);
        let ok = FitSession::with_options(
            DataHandle::Borrowed(&data),
            &cfg,
            SessionOptions {
                budget: Some(MemBudget::limited(64 << 20)),
                ..Default::default()
            },
        );
        assert!(ok.is_ok());
    }

    #[test]
    fn fit_only_owned_session_drops_csr_and_budget_proves_it() {
        let mut rng = Pcg64::seed(185);
        let (data, _, _) = planted(&mut rng, 10, 9, 2);
        let csr_bytes = data.heap_bytes();
        assert!(csr_bytes > 0);
        let cfg = Parafac2Config {
            rank: 2,
            max_iters: 5,
            tol: 0.0,
            workers: 1,
            ..Default::default()
        };
        let batch = fit_parafac2(&data, &cfg).unwrap();

        // fit-only: CSR released right after init, charge shrunk
        let diet = MemBudget::unlimited();
        let mut s = FitSession::with_options(
            DataHandle::Owned(data.clone()),
            &cfg,
            SessionOptions { budget: Some(Arc::clone(&diet)), ..Default::default() },
        )
        .unwrap();
        assert!(!s.holds_data());
        assert_eq!(diet.used(), s.charged_bytes());
        // the peak proves the CSR bytes were charged during construction…
        assert!(
            diet.peak() >= diet.used() + csr_bytes,
            "peak {} should cover arena {} + CSR {csr_bytes}",
            diet.peak(),
            diet.used()
        );

        // …and keep_data holds exactly those bytes longer
        let keep = MemBudget::unlimited();
        let s_keep = FitSession::with_options(
            DataHandle::Owned(data.clone()),
            &cfg,
            SessionOptions {
                budget: Some(Arc::clone(&keep)),
                keep_data: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(s_keep.holds_data());
        assert_eq!(s_keep.charged_bytes(), s.charged_bytes() + csr_bytes);

        // the diet changes nothing about the math
        while let StepOutcome::Iterated(_) = s.step().unwrap() {}
        let model = s.finish();
        assert_eq!(model.v.data(), batch.v.data());
        assert_eq!(model.w.data(), batch.w.data());
        drop(s_keep);
        assert_eq!(keep.used(), 0, "dropping the session releases its charge");
        assert_eq!(diet.used(), 0);
    }

    #[test]
    fn parallel_and_serial_same_result() {
        let mut rng = Pcg64::seed(178);
        let (data, _, _) = planted(&mut rng, 9, 8, 2);
        let mk = |workers| Parafac2Config {
            rank: 2,
            max_iters: 8,
            tol: 0.0,
            workers,
            seed: 9,
            ..Default::default()
        };
        let a = fit_parafac2(&data, &mk(1)).unwrap();
        let b = fit_parafac2(&data, &mk(4)).unwrap();
        // deterministic chunk-ordered reductions ⇒ identical results
        assert_eq!(a.v.data(), b.v.data());
        assert_eq!(a.w.data(), b.w.data());
    }
}
