//! The single CP-ALS iteration executed on the intermediate tensor `Y`
//! inside each PARAFAC2-ALS sweep (paper Algorithm 2, line 10).
//!
//! Kiers et al. showed one CP-ALS iteration per outer sweep suffices to
//! decrease the objective. The iteration updates, in order:
//!
//! 1. `H ← M¹ (WᵀW ∗ VᵀV)⁺`, columns normalized,
//! 2. `V ← M² (WᵀW ∗ HᵀH)⁺` (optionally NNLS), columns normalized,
//! 3. `W ← M³ (VᵀV ∗ HᵀH)⁺` (optionally NNLS) — W keeps the scale
//!    (`S_k = diag(W(k,:))`).
//!
//! The residual `‖Y − ⟦H,V,W⟧‖²` falls out for free after the mode-3
//! update via the classic identity `⟨Y, rec⟩ = ⟨M³, W⟩`, giving the
//! PARAFAC2 SSE as `‖X‖² − ‖Y‖² + ‖Y − rec‖²` without touching the data.
//!
//! The iteration is **fused** twice over (see [`super::mttkrp`] and
//! [`super::procrustes::procrustes_pack_mode1`]): the ALS driver computes
//! `M¹` during the Procrustes pack itself and hands it to
//! [`cp_iteration_from_m1`], mode 2 caches `Z_k = Y_kᵀ H` per subject,
//! and mode 3 becomes a cheap epilogue over that cache — so each ALS
//! iteration performs exactly **one** cold traversal of the packed slices
//! (mode 2) and `Y_k·V` is computed exactly once per subject.

use super::intermediate::PackedY;
use super::mttkrp;
use crate::linalg::{blas, nnls, solve, Mat};
use crate::threadpool::{ChunkPlan, Pool};

/// The CP factor triple of the intermediate tensor.
#[derive(Clone, Debug)]
pub struct CpFactors {
    /// R×R (replaces CP's U for mode 1 of Y).
    pub h: Mat,
    /// J×R, shared variable loadings — the phenotype definitions.
    pub v: Mat,
    /// K×R, subject weights — row k is `diag(S_k)`.
    pub w: Mat,
}

/// Options controlling the iteration.
#[derive(Clone, Copy, Debug, Default)]
pub struct CpOptions {
    /// Impose non-negativity on V and W (hence `{S_k}`), per paper §3.2.
    pub nonneg: bool,
}

/// Result statistics of one CP iteration.
#[derive(Clone, Copy, Debug)]
pub struct CpIterStats {
    /// `‖Y − ⟦H,V,W⟧‖²_F` after the update.
    pub y_residual_sq: f64,
    /// `⟨Y, rec⟩` (kept for diagnostics).
    pub inner: f64,
    /// `‖rec‖²`.
    pub rec_norm_sq: f64,
    /// Number of `Y_k·V` products performed (the hottest kernel). The
    /// fused sweep does exactly one per subject — K in total — which
    /// `metrics::flops` asserts.
    pub yv_products: u64,
}

/// One CP-ALS iteration on the packed intermediate tensor (SPARTan path),
/// allocating its own scratch. The ALS loop uses
/// [`cp_iteration_from_m1`] (with the pack-fused `M¹`) and a persistent
/// scratch to reuse the `Z_k` buffers across iterations.
pub fn cp_iteration(
    y: &PackedY,
    f: &mut CpFactors,
    opts: CpOptions,
    pool: &Pool,
    plan: &ChunkPlan,
) -> CpIterStats {
    let mut scratch = mttkrp::FusedScratch::new();
    cp_iteration_with_scratch(y, f, opts, pool, plan, &mut scratch)
}

/// One CP-ALS iteration computing its own mode-1 MTTKRP (standalone
/// traversal). Bitwise identical to [`cp_iteration_from_m1`] fed with the
/// pack-fused `M¹` on the same plan.
pub fn cp_iteration_with_scratch(
    y: &PackedY,
    f: &mut CpFactors,
    opts: CpOptions,
    pool: &Pool,
    plan: &ChunkPlan,
    scratch: &mut mttkrp::FusedScratch,
) -> CpIterStats {
    let (m1, yv_products) = mttkrp::mttkrp_mode1_counted(y, &f.v, &f.w, pool, plan);
    cp_iteration_from_m1(y, m1, yv_products, f, opts, pool, plan, scratch)
}

/// One fused CP-ALS iteration given a precomputed mode-1 MTTKRP `m1`
/// (normally emitted by the pack-fused Procrustes sweep,
/// [`super::procrustes::procrustes_pack_mode1`], with the same `V`/`W`
/// still held in `f`): the H update consumes `m1`, mode 2 makes the
/// iteration's **single** cold traversal of the packed slices (caching
/// `Z_k = Y_kᵀ H`), and mode 3 is an `O(c_k·R)` epilogue fed from the
/// cache — `Y_k·V` is computed exactly once per subject, all of it during
/// the pack. The update order (H, then V, then W) and the residual
/// identity `⟨Y, rec⟩ = ⟨M³, W⟩` (M³ with the final H and V) are
/// unchanged from the unfused iteration.
#[allow(clippy::too_many_arguments)]
pub fn cp_iteration_from_m1(
    y: &PackedY,
    m1: Mat,
    yv_products: u64,
    f: &mut CpFactors,
    opts: CpOptions,
    pool: &Pool,
    plan: &ChunkPlan,
    scratch: &mut mttkrp::FusedScratch,
) -> CpIterStats {
    // --- mode 1: H (m1 was computed against the current f.v / f.w) ------
    let g1 = blas::hadamard(&blas::gram(&f.w), &blas::gram(&f.v));
    f.h = solve::solve_gram_system(&m1, &g1);
    normalize_cols_safe(&mut f.h);

    // --- mode 2: V (sweep caches Z_k = Y_kᵀ H for mode 3) ----------------
    let m2 = mttkrp::mttkrp_mode2_cached(y, &f.h, &f.w, pool, plan, scratch);
    let g2 = blas::hadamard(&blas::gram(&f.w), &blas::gram(&f.h));
    f.v = solve_mode(&m2, &g2, opts.nonneg);
    normalize_cols_safe(&mut f.v);

    // --- mode 3: W (carries the scale) — epilogue over cached Z_k --------
    let m3 = mttkrp::mttkrp_mode3_from_cache(y, &f.v, scratch, pool, plan);
    let g3 = blas::hadamard(&blas::gram(&f.v), &blas::gram(&f.h));
    f.w = solve_mode(&m3, &g3, opts.nonneg);

    // --- residual via the MTTKRP identity --------------------------------
    // ⟨Y, rec⟩ = ⟨M³, W⟩ (M³ computed with the FINAL H, V; W final too).
    let mut stats = residual_stats(&m3, f, y.norm_sq());
    stats.yv_products = yv_products;
    stats
}

/// Normalize columns to unit norm, leaving exact-zero columns alone
/// (a collapsed component must not become NaN; the solver may revive it).
pub(crate) fn normalize_cols_safe(m: &mut Mat) {
    m.normalize_cols();
}

/// Shared factor solve: `M · G⁺`, optionally non-negative (row-wise FNNLS).
pub(crate) fn solve_mode(m: &Mat, g: &Mat, nonneg: bool) -> Mat {
    if nonneg {
        nnls::nnls_gram_system(m, g)
    } else {
        solve::solve_gram_system(m, g)
    }
}

/// Residual statistics shared by the SPARTan and baseline iterations:
/// given the final `M³`, factors, and `‖Y‖²`.
pub(crate) fn residual_stats(m3: &Mat, f: &CpFactors, y_norm_sq: f64) -> CpIterStats {
    let inner: f64 = m3.data().iter().zip(f.w.data()).map(|(a, b)| a * b).sum();
    let g_all = blas::hadamard(
        &blas::hadamard(&blas::gram(&f.h), &blas::gram(&f.v)),
        &blas::gram(&f.w),
    );
    let rec_norm_sq: f64 = g_all.data().iter().sum();
    let y_residual_sq = (y_norm_sq - 2.0 * inner + rec_norm_sq).max(0.0);
    CpIterStats { y_residual_sq, inner, rec_norm_sq, yv_products: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parafac2::intermediate::PackedSlice;
    use crate::sparse::Csr;
    use crate::util::rng::Pcg64;

    fn random_y(rng: &mut Pcg64, k: usize, j: usize, r: usize) -> PackedY {
        let slices = (0..k)
            .map(|_| {
                let rows = r + rng.range(2, 6);
                let mut trips = vec![(0usize, rng.range(0, j), 1.0)];
                for i in 0..rows {
                    for jj in 0..j {
                        if rng.chance(0.3) {
                            trips.push((i, jj, rng.uniform(0.1, 1.5)));
                        }
                    }
                }
                let xk = Csr::from_triplets(rows, j, trips);
                let qk = crate::linalg::random_orthonormal(rows, r, rng);
                PackedSlice::pack(&xk, &qk)
            })
            .collect();
        PackedY { slices, j_dim: j }
    }

    fn residual_explicit(y: &PackedY, f: &CpFactors) -> f64 {
        // ‖Y − ⟦H,V,W⟧‖² by dense materialization
        let mut sse = 0.0;
        for (kk, s) in y.slices.iter().enumerate() {
            let yk = s.to_dense(y.j_dim);
            // rec_k = H diag(W(k,:)) Vᵀ
            let hw = Mat::from_fn(f.h.rows(), f.h.cols(), |i, c| f.h[(i, c)] * f.w[(kk, c)]);
            let rec = blas::matmul_a_bt(&hw, &f.v);
            sse += yk.fro_dist(&rec).powi(2);
        }
        sse
    }

    #[test]
    fn residual_identity_matches_explicit() {
        let mut rng = Pcg64::seed(131);
        let (k, j, r) = (5, 8, 3);
        let y = random_y(&mut rng, k, j, r);
        let mut f = CpFactors {
            h: Mat::rand_normal(r, r, &mut rng),
            v: Mat::rand_normal(j, r, &mut rng),
            w: Mat::rand_normal(k, r, &mut rng),
        };
        let stats =
            cp_iteration(&y, &mut f, CpOptions::default(), &Pool::serial(), &ChunkPlan::fixed(k));
        let explicit = residual_explicit(&y, &f);
        assert!(
            (stats.y_residual_sq - explicit).abs() < 1e-8 * (1.0 + explicit),
            "{} vs {explicit}",
            stats.y_residual_sq
        );
    }

    #[test]
    fn iteration_monotonically_decreases_residual() {
        let mut rng = Pcg64::seed(132);
        let (k, j, r) = (6, 10, 3);
        let y = random_y(&mut rng, k, j, r);
        let mut f = CpFactors {
            h: Mat::rand_normal(r, r, &mut rng),
            v: Mat::rand_normal(j, r, &mut rng),
            w: Mat::rand_uniform(k, r, &mut rng),
        };
        let plan = ChunkPlan::fixed(k);
        let mut last = f64::INFINITY;
        for it in 0..8 {
            let stats = cp_iteration(&y, &mut f, CpOptions::default(), &Pool::serial(), &plan);
            assert!(
                stats.y_residual_sq <= last * (1.0 + 1e-9) + 1e-12,
                "iter {it}: {} > {last}",
                stats.y_residual_sq
            );
            last = stats.y_residual_sq;
        }
    }

    #[test]
    fn nonneg_keeps_v_w_nonnegative_and_decreases() {
        let mut rng = Pcg64::seed(133);
        let (k, j, r) = (5, 9, 3);
        let y = random_y(&mut rng, k, j, r);
        let mut f = CpFactors {
            h: Mat::rand_normal(r, r, &mut rng),
            v: Mat::rand_uniform(j, r, &mut rng),
            w: Mat::rand_uniform(k, r, &mut rng),
        };
        let opts = CpOptions { nonneg: true };
        let plan = ChunkPlan::fixed(k);
        let mut last = f64::INFINITY;
        for _ in 0..6 {
            let stats = cp_iteration(&y, &mut f, opts, &Pool::serial(), &plan);
            assert!(f.v.data().iter().all(|&x| x >= 0.0));
            assert!(f.w.data().iter().all(|&x| x >= 0.0));
            assert!(stats.y_residual_sq <= last * (1.0 + 1e-9) + 1e-12);
            last = stats.y_residual_sq;
        }
    }

    #[test]
    fn scratch_reuse_across_iterations_is_bitwise_stable() {
        // Reusing one FusedScratch across iterations (the ALS loop's
        // arena pattern) must give bitwise the same trajectory as a fresh
        // scratch per iteration, serial or parallel.
        let mut rng = Pcg64::seed(135);
        let (k, j, r) = (9, 11, 3);
        let y = random_y(&mut rng, k, j, r);
        let f0 = CpFactors {
            h: Mat::rand_normal(r, r, &mut rng),
            v: Mat::rand_normal(j, r, &mut rng),
            w: Mat::rand_uniform(k, r, &mut rng),
        };
        let plan = ChunkPlan::fixed(k);
        for pool in [Pool::serial(), Pool::new(4)] {
            let mut fa = f0.clone();
            let mut fb = f0.clone();
            let mut shared = super::super::mttkrp::FusedScratch::new();
            for _ in 0..5 {
                let sa = cp_iteration_with_scratch(
                    &y,
                    &mut fa,
                    CpOptions::default(),
                    &pool,
                    &plan,
                    &mut shared,
                );
                let sb = cp_iteration(&y, &mut fb, CpOptions::default(), &pool, &plan);
                assert_eq!(fa.h.data(), fb.h.data());
                assert_eq!(fa.v.data(), fb.v.data());
                assert_eq!(fa.w.data(), fb.w.data());
                assert_eq!(sa.y_residual_sq.to_bits(), sb.y_residual_sq.to_bits());
                assert_eq!(sa.yv_products, k as u64);
            }
        }
    }

    #[test]
    fn iteration_from_precomputed_m1_is_bitwise_identical() {
        // The driver's pack-fused path hands cp_iteration_from_m1 an M¹
        // computed during the pack; feeding the standalone mode-1 result
        // through the same entry point must reproduce the self-computing
        // iteration bit for bit, on fixed and balanced plans. K exceeds
        // SUBJECT_CHUNK so both plans are genuinely multi-chunk and cut
        // at different boundaries (smaller K would make them the same
        // single chunk and the plan loop vacuous).
        let mut rng = Pcg64::seed(136);
        let (k, j, r) = (crate::threadpool::partition::SUBJECT_CHUNK + 6, 12, 3);
        let y = random_y(&mut rng, k, j, r);
        let weights: Vec<u64> =
            y.slices.iter().map(|s| (s.c_k() * s.rank()) as u64).collect();
        let f0 = CpFactors {
            h: Mat::rand_normal(r, r, &mut rng),
            v: Mat::rand_normal(j, r, &mut rng),
            w: Mat::rand_uniform(k, r, &mut rng),
        };
        let balanced = ChunkPlan::balanced(&weights);
        assert!(balanced.n_chunks() > 1, "plan degenerate: {:?}", balanced.ranges());
        for plan in [ChunkPlan::fixed(k), balanced] {
            for pool in [Pool::serial(), Pool::new(3)] {
                let mut fa = f0.clone();
                let mut fb = f0.clone();
                let mut scr_a = super::super::mttkrp::FusedScratch::new();
                let mut scr_b = super::super::mttkrp::FusedScratch::new();
                for _ in 0..4 {
                    let (m1, n) =
                        super::super::mttkrp::mttkrp_mode1_counted(&y, &fa.v, &fa.w, &pool, &plan);
                    let sa = cp_iteration_from_m1(
                        &y,
                        m1,
                        n,
                        &mut fa,
                        CpOptions::default(),
                        &pool,
                        &plan,
                        &mut scr_a,
                    );
                    let sb = cp_iteration_with_scratch(
                        &y,
                        &mut fb,
                        CpOptions::default(),
                        &pool,
                        &plan,
                        &mut scr_b,
                    );
                    assert_eq!(fa.h.data(), fb.h.data());
                    assert_eq!(fa.v.data(), fb.v.data());
                    assert_eq!(fa.w.data(), fb.w.data());
                    assert_eq!(sa.y_residual_sq.to_bits(), sb.y_residual_sq.to_bits());
                    assert_eq!(sa.yv_products, sb.yv_products);
                }
            }
        }
    }

    #[test]
    fn normalized_factor_columns() {
        let mut rng = Pcg64::seed(134);
        let (k, j, r) = (4, 7, 2);
        let y = random_y(&mut rng, k, j, r);
        let mut f = CpFactors {
            h: Mat::rand_normal(r, r, &mut rng),
            v: Mat::rand_normal(j, r, &mut rng),
            w: Mat::rand_uniform(k, r, &mut rng),
        };
        cp_iteration(&y, &mut f, CpOptions::default(), &Pool::serial(), &ChunkPlan::fixed(k));
        for norms in [f.h.col_norms(), f.v.col_norms()] {
            for n in norms {
                assert!(n == 0.0 || (n - 1.0).abs() < 1e-10, "col norm {n}");
            }
        }
    }
}
