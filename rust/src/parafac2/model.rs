//! The fitted PARAFAC2 model `X_k ≈ U_k S_k Vᵀ` with `U_k = Q_k H`.

use crate::linalg::{blas, Mat};
use crate::sparse::IrregularTensor;

/// A fitted PARAFAC2 decomposition.
#[derive(Clone, Debug)]
pub struct Parafac2Model {
    /// Target rank R.
    pub rank: usize,
    /// R×R common cross-product factor (`U_k = Q_k H`).
    pub h: Mat,
    /// J×R shared variable loadings (phenotype definitions).
    pub v: Mat,
    /// K×R subject weights; `S_k = diag(W(k,:))`.
    pub w: Mat,
    /// Per-subject orthonormal bases `Q_k` (I_k×R).
    pub q: Vec<Mat>,
    /// Fitting statistics.
    pub stats: FitStats,
}

/// Statistics recorded by the ALS driver.
#[derive(Clone, Debug, Default)]
pub struct FitStats {
    /// Outer ALS iterations executed.
    pub iterations: usize,
    /// Final sum of squared errors Σ_k‖X_k − U_k S_k Vᵀ‖².
    pub final_sse: f64,
    /// Final fit = 1 − √(SSE)/‖X‖_F (1 = perfect).
    pub final_fit: f64,
    /// Fit after each iteration.
    pub fit_history: Vec<f64>,
    /// Wall-clock seconds in total and per phase.
    pub total_secs: f64,
    pub procrustes_secs: f64,
    pub cp_secs: f64,
    /// Mean seconds per outer iteration.
    pub secs_per_iter: f64,
    /// `Y_k·V` products performed over the whole fit (the hottest kernel;
    /// the fused sweep does exactly K per iteration — benches publish this
    /// next to wall time so perf claims are machine-checkable).
    pub yv_products: u64,
    /// Cold read traversals of the packed slices over the whole fit (the
    /// pack-fused SPARTan sweep does exactly K per iteration — down from
    /// 2K pre-fusion; see `metrics::flops`).
    pub traversals: u64,
    /// Cold streaming passes over the subjects' **X data** over the whole
    /// fit, tallied by the resident compact-X arena: K for the one-time
    /// pack, then exactly K per iteration (the `C_k = X̃_k·V` stage; the
    /// repack rides it), plus K for the final report pass — down from 2K
    /// per iteration in the pre-arena CSR-streaming structure (see
    /// `metrics::flops`).
    pub x_traversals: u64,
    /// Steady-state resident footprint of the fit's data-plane arenas:
    /// the compact-X arena + the packed-Y arena + the per-chunk sweep
    /// scratch + the fused Z-cache. The arena trades this residency for
    /// halved X memory traffic, so benches publish it next to the
    /// counters.
    pub heap_bytes: u64,
    /// Successful mid-fit shard re-attaches: a lost worker connection was
    /// re-established, the worker re-packed its subject range via the
    /// `reattach` verb, and the interrupted iteration was replayed from
    /// the frozen factor snapshot (bitwise identical to an uninterrupted
    /// fit). Always 0 for local fits.
    pub shard_reconnects: u64,
    /// Reconnect attempts made while recovering lost shards (every
    /// connect+hello+reattach try counts, including the ones that failed).
    /// `shard_retries ≥ shard_reconnects`; always 0 for local fits.
    pub shard_retries: u64,
    /// The iteration boundary this fit was resumed from (durable
    /// checkpoint/resume): 0 for a fit that started cold or warm in this
    /// process; `i > 0` means iterations `0..i` were restored from a
    /// checkpoint and only `i..iterations` executed here. The recovered
    /// trajectory is bitwise identical to an uninterrupted fit; the only
    /// counter signature of a resume is one extra `K` of `x_traversals`
    /// (the re-pack of the arena on restore).
    pub resumed_from_iter: u64,
    /// The kernel backend the fit ran on (`linalg::kernels::
    /// KernelBackend::name()`: `scalar`/`blocked`/`avx2`/`avx512`/`neon`)
    /// — records which lane family produced the trajectory, so a result
    /// from the reordered `avx512` family can never be mistaken for a
    /// bitwise one. Empty on models that predate backend recording.
    pub kernel_backend: String,
}

impl Parafac2Model {
    /// Number of subjects.
    pub fn k(&self) -> usize {
        self.w.rows()
    }

    /// Number of variables.
    pub fn j(&self) -> usize {
        self.v.rows()
    }

    /// `U_k = Q_k H` — the temporal signature matrix of subject k
    /// (I_k × R; paper §5.3: each column is the evolution of one
    /// phenotype's expression over the subject's observations).
    pub fn u_k(&self, k: usize) -> Mat {
        blas::matmul(&self.q[k], &self.h)
    }

    /// `diag(S_k)` — the subject's importance weights per component.
    pub fn s_k(&self, k: usize) -> &[f64] {
        self.w.row(k)
    }

    /// Reconstruct slice k: `U_k S_k Vᵀ` (dense; small-scale use).
    pub fn reconstruct_slice(&self, k: usize) -> Mat {
        let uk = self.u_k(k);
        // scale columns by S_k then multiply by Vᵀ
        let mut us = uk;
        let sk = self.w.row(k).to_vec();
        for i in 0..us.rows() {
            for (c, x) in us.row_mut(i).iter_mut().enumerate() {
                *x *= sk[c];
            }
        }
        blas::matmul_a_bt(&us, &self.v)
    }

    /// Exact SSE against the data (O(Σ nnz_k + Σ I_k·J·R) — verification
    /// and small-scale reporting; the ALS loop itself uses the cheap
    /// residual identity).
    pub fn sse(&self, data: &IrregularTensor) -> f64 {
        let mut total = 0.0;
        for k in 0..data.k() {
            let rec = self.reconstruct_slice(k);
            let xk = data.slice(k);
            // ‖X_k − rec‖² = ‖rec‖² − 2⟨X_k, rec⟩ + ‖X_k‖² streamed over nnz
            let mut cross = 0.0;
            for i in 0..xk.rows() {
                for (j, v) in xk.row_iter(i) {
                    cross += v * rec[(i, j as usize)];
                }
            }
            total += rec.fro_norm().powi(2) - 2.0 * cross + xk.fro_norm_sq();
        }
        total.max(0.0)
    }

    /// Fit = 1 − √SSE/‖X‖ against the data (exact, see [`Self::sse`]).
    pub fn fit(&self, data: &IrregularTensor) -> f64 {
        1.0 - (self.sse(data) / data.fro_norm_sq()).sqrt()
    }

    /// The model constraint Φ = HᵀH that makes `U_kᵀU_k` invariant over k.
    pub fn phi(&self) -> Mat {
        blas::gram(&self.h)
    }

    /// Verify the PARAFAC2 invariant `U_kᵀU_k = Φ ∀k` (max deviation).
    pub fn cross_product_invariance_defect(&self) -> f64 {
        let phi = self.phi();
        let mut worst: f64 = 0.0;
        for k in 0..self.q.len() {
            // U_kᵀU_k = Hᵀ Q_kᵀ Q_k H; only exact when Q_k has orthonormal
            // columns (I_k ≥ R slices).
            if self.q[k].rows() < self.rank {
                continue;
            }
            let uk = self.u_k(k);
            let g = blas::gram(&uk);
            worst = worst.max(g.max_abs_diff(&phi));
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::random_orthonormal;
    use crate::sparse::Csr;
    use crate::util::rng::Pcg64;

    fn toy_model(rng: &mut Pcg64, k: usize, j: usize, r: usize, iks: &[usize]) -> Parafac2Model {
        Parafac2Model {
            rank: r,
            h: Mat::rand_normal(r, r, rng),
            v: Mat::rand_uniform(j, r, rng),
            w: Mat::rand_uniform(k, r, rng),
            q: iks.iter().map(|&ik| random_orthonormal(ik, r, rng)).collect(),
            stats: FitStats::default(),
        }
    }

    #[test]
    fn uk_shape_and_invariance() {
        let mut rng = Pcg64::seed(151);
        let m = toy_model(&mut rng, 3, 6, 2, &[5, 7, 4]);
        assert_eq!(m.u_k(1).shape(), (7, 2));
        assert!(m.cross_product_invariance_defect() < 1e-9);
    }

    #[test]
    fn perfect_model_has_fit_one() {
        let mut rng = Pcg64::seed(152);
        let m = toy_model(&mut rng, 3, 6, 2, &[5, 7, 4]);
        // generate data exactly from the model
        let slices: Vec<Csr> = (0..3).map(|k| Csr::from_dense(&m.reconstruct_slice(k))).collect();
        let data = IrregularTensor::new_unchecked(slices);
        assert!(m.sse(&data) < 1e-16 * data.fro_norm_sq().max(1.0) + 1e-12);
        assert!(m.fit(&data) > 1.0 - 1e-7);
    }

    #[test]
    fn sse_detects_perturbation() {
        let mut rng = Pcg64::seed(153);
        let m = toy_model(&mut rng, 2, 5, 2, &[4, 6]);
        let mut slices: Vec<Mat> = (0..2).map(|k| m.reconstruct_slice(k)).collect();
        slices[0][(0, 0)] += 3.0; // inject error
        let data =
            IrregularTensor::new_unchecked(slices.iter().map(Csr::from_dense).collect());
        assert!((m.sse(&data) - 9.0).abs() < 1e-8);
    }
}
