//! Factor initialization for PARAFAC2-ALS.
//!
//! Following the classical algorithm (Kiers et al.; paper Algorithm 2,
//! line 1): `H` starts at the identity, `{S_k}` at identity (i.e. W all
//! ones), and `V` either random or "SVD-warm" — the dominant R-dimensional
//! column space of the stacked data, computed matrix-free by block power
//! iteration on `G = Σ_k X_kᵀ X_k` (never formed: each multiply streams
//! the CSR slices twice, so the cost is O(nnz·R) per power step).

use crate::linalg::{qr, Mat};
use crate::sparse::IrregularTensor;
use crate::threadpool::{partition::SUBJECT_CHUNK, Pool};
use crate::util::rng::Pcg64;

/// Initialization strategy for V.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum InitMethod {
    /// i.i.d. uniform [0,1) entries (safe default; also the right choice
    /// with non-negativity constraints).
    #[default]
    Random,
    /// Block power iteration toward the top-R eigenvectors of Σ X_kᵀX_k.
    SvdWarm,
}

impl InitMethod {
    pub fn parse(s: &str) -> Option<InitMethod> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Some(InitMethod::Random),
            "svd" | "svd-warm" | "svdwarm" => Some(InitMethod::SvdWarm),
            _ => None,
        }
    }
}

/// Initial factors (H = I, W = 1, V per `method`).
pub struct InitialFactors {
    pub h: Mat,
    pub v: Mat,
    pub w: Mat,
}

pub fn initialize(
    data: &IrregularTensor,
    rank: usize,
    method: InitMethod,
    seed: u64,
    pool: &Pool,
) -> InitialFactors {
    let mut rng = Pcg64::new(seed, 0xF0);
    let v = match method {
        InitMethod::Random => Mat::rand_uniform(data.j(), rank, &mut rng),
        InitMethod::SvdWarm => svd_warm_v(data, rank, &mut rng, pool),
    };
    InitialFactors {
        h: Mat::eye(rank),
        v,
        w: Mat::from_fn(data.k(), rank, |_, _| 1.0),
    }
}

/// Matrix-free block power iteration: returns an orthonormal J×R basis
/// aligned with the top eigenvectors of `Σ_k X_kᵀ X_k`.
pub fn svd_warm_v(data: &IrregularTensor, rank: usize, rng: &mut Pcg64, pool: &Pool) -> Mat {
    let j = data.j();
    let r = rank.min(j);
    let mut z = qr::random_orthonormal(j, r, rng);
    let steps = 4;
    for _ in 0..steps {
        let gz = apply_gram(data, &z, pool); // Σ X_kᵀ (X_k Z)
        let (q, _) = qr::qr_thin(&gz);
        z = q;
    }
    if r < rank {
        // degenerate J < R: pad with zero columns
        let mut padded = Mat::zeros(j, rank);
        for i in 0..j {
            padded.row_mut(i)[..r].copy_from_slice(z.row(i));
        }
        z = padded;
    }
    z
}

/// `Σ_k X_kᵀ (X_k Z)` streamed over the slices on the pool.
fn apply_gram(data: &IrregularTensor, z: &Mat, pool: &Pool) -> Mat {
    let k = data.k();
    let chunk = SUBJECT_CHUNK;
    pool.par_fold(
        k,
        chunk,
        |range| {
            let mut acc = Mat::zeros(z.rows(), z.cols());
            for kk in range {
                let xk = data.slice(kk);
                let xz = xk.matmul_dense(z);
                let xtxz = xk.t_matmul_dense(&xz);
                acc.axpy(1.0, &xtxz);
            }
            acc
        },
        |mut a, b| {
            a.axpy(1.0, &b);
            a
        },
    )
    .unwrap_or_else(|| Mat::zeros(z.rows(), z.cols()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, qr::orthonormality_defect};
    use crate::sparse::Csr;

    fn planted_data(rng: &mut Pcg64, k: usize, j: usize, r: usize) -> IrregularTensor {
        // Slices whose row space concentrates on a planted r-dim subspace.
        let basis = qr::random_orthonormal(j, r, rng);
        let slices: Vec<Csr> = (0..k)
            .map(|_| {
                let rows = 6;
                let coef = Mat::rand_normal(rows, r, rng);
                let dense = blas::matmul_a_bt(&coef, &basis);
                // keep dense->sparse conversion exact (no sparsification
                // so the subspace stays planted)
                Csr::from_dense(&dense)
            })
            .collect();
        IrregularTensor::new(slices)
    }

    #[test]
    fn svd_warm_recovers_planted_subspace() {
        let mut rng = Pcg64::seed(161);
        let (k, j, r) = (10, 20, 3);
        let data = planted_data(&mut rng, k, j, r);
        let v = svd_warm_v(&data, r, &mut rng, &Pool::serial());
        assert!(orthonormality_defect(&v) < 1e-9);
        // Every data row must lie (nearly) in span(V): residual after
        // projection ≈ 0.
        for kk in 0..k {
            let xd = data.slice_dense(kk);
            let proj = blas::matmul(&blas::matmul(&xd, &v), &v.transpose());
            assert!(xd.fro_dist(&proj) < 1e-8 * (1.0 + xd.fro_norm()));
        }
    }

    #[test]
    fn initialize_shapes_and_defaults() {
        let mut rng = Pcg64::seed(162);
        let data = planted_data(&mut rng, 4, 10, 2);
        let init = initialize(&data, 3, InitMethod::Random, 7, &Pool::serial());
        assert_eq!(init.h.shape(), (3, 3));
        assert_eq!(init.v.shape(), (10, 3));
        assert_eq!(init.w.shape(), (4, 3));
        assert!(init.w.data().iter().all(|&x| x == 1.0));
        // H = I
        assert!(init.h.max_abs_diff(&Mat::eye(3)) < 1e-15);
        // deterministic per seed
        let init2 = initialize(&data, 3, InitMethod::Random, 7, &Pool::serial());
        assert_eq!(init.v.data(), init2.v.data());
    }

    #[test]
    fn parse_methods() {
        assert_eq!(InitMethod::parse("random"), Some(InitMethod::Random));
        assert_eq!(InitMethod::parse("svd-warm"), Some(InitMethod::SvdWarm));
        assert_eq!(InitMethod::parse("bogus"), None);
    }
}
