//! The comparison baseline: "Sparse PARAFAC2" — the standard fitting
//! algorithm [Kiers et al.] adjusted for sparse tensors as in Chew et
//! al. [12], with the CP-ALS iteration running on an **explicitly
//! materialized** sparse intermediate tensor via Tensor-Toolbox-style
//! MTTKRP (paper §5.1 "Implementation details").
//!
//! Per outer iteration the baseline:
//! 1. runs the same Procrustes step as SPARTan (the paper parallelizes
//!    both equally — the methods differ in step 2),
//! 2. **constructs** the COO sparse tensor `Y ∈ R^{R×J×K}` from the
//!    `{Y_k}` slices — `R·Σc_k` entries at 20 bytes each, charged against
//!    the memory budget (this is where the paper's 1 TB server ran OoM),
//! 3. runs one CP-ALS iteration with [`crate::sparse::CooTensor3::mttkrp`]
//!    per mode (each re-sorts the nonzeros — TTB's matricization cost —
//!    and materializes TTB's nnz-length per-column temporary).
//!
//! Note on the fused SPARTan sweep: the baseline deliberately does **not**
//! share its intermediates — it models the comparison method as published.
//! It consumes the same packed `{Y_k}` (produced by the same in-place
//! Procrustes arena), and since the arena repack is bitwise identical to a
//! fresh pack, this path's numbers are byte-compatible with the
//! pre-fusion implementation.

use super::cp_als::{normalize_cols_safe, residual_stats, solve_mode, CpFactors, CpIterStats, CpOptions};
use super::intermediate::PackedY;
use crate::linalg::blas;
use crate::sparse::CooTensor3;
use crate::util::membudget::{BudgetExceeded, MemBudget};
use crate::util::timer::Stopwatch;

/// Phase timing of one baseline CP iteration (for the bench breakdown).
#[derive(Clone, Copy, Debug, Default)]
pub struct BaselinePhases {
    pub construct_secs: f64,
    pub mttkrp_secs: f64,
    pub solve_secs: f64,
}

/// Materialize the COO tensor `Y` from the packed slices (the step SPARTan
/// skips entirely). Charges `budget` for the full COO storage.
pub fn materialize_coo(y: &PackedY, budget: &MemBudget) -> Result<CooTensor3, BudgetExceeded> {
    let r = y.slices.first().map(|s| s.rank()).unwrap_or(0);
    let mut coo = CooTensor3::new([r, y.j_dim, y.k()]);
    coo.reserve(y.nnz(), budget)?;
    for (kk, slice) in y.slices.iter().enumerate() {
        slice.note_traversal(); // the COO build streams every packed slice
        for (c, &j) in slice.support.iter().enumerate() {
            let yrow = slice.yt.row(c); // Y_k(:, j)ᵀ
            for (i, &v) in yrow.iter().enumerate() {
                if v != 0.0 {
                    coo.push(i as u32, j, kk as u32, v);
                }
            }
        }
    }
    Ok(coo)
}

/// One CP-ALS iteration on the explicit COO tensor (baseline path).
/// Mirrors [`super::cp_als::cp_iteration`] but with TTB-style MTTKRPs;
/// returns `Err` when the memory budget is exhausted — the paper's "OoM".
pub fn cp_iteration_baseline(
    y: &PackedY,
    f: &mut CpFactors,
    opts: CpOptions,
    budget: &MemBudget,
    phases: &mut BaselinePhases,
) -> Result<CpIterStats, BudgetExceeded> {
    // Snapshot so every charge made below (COO storage, MTTKRP outputs and
    // temporaries) is released on exit, including early-error paths. The
    // baseline runs single-threaded w.r.t. the budget, so this is exact.
    let used_at_entry = budget.used();
    let sw = Stopwatch::start();
    let coo = materialize_coo(y, budget);
    let mut coo = match coo {
        Ok(c) => c,
        Err(e) => {
            budget.release(budget.used() - used_at_entry);
            return Err(e);
        }
    };
    phases.construct_secs += sw.elapsed_secs();

    let result = (|| {
        // --- mode 1: H ----------------------------------------------------
        let sw = Stopwatch::start();
        let m1 = coo.mttkrp(0, [&f.h, &f.v, &f.w], budget)?;
        phases.mttkrp_secs += sw.elapsed_secs();
        let sw = Stopwatch::start();
        let g1 = blas::hadamard(&blas::gram(&f.w), &blas::gram(&f.v));
        f.h = solve_mode(&m1, &g1, false);
        normalize_cols_safe(&mut f.h);
        phases.solve_secs += sw.elapsed_secs();
        budget.release((m1.rows() * m1.cols() * 8) as u64);

        // --- mode 2: V ----------------------------------------------------
        let sw = Stopwatch::start();
        let m2 = coo.mttkrp(1, [&f.h, &f.v, &f.w], budget)?;
        phases.mttkrp_secs += sw.elapsed_secs();
        let sw = Stopwatch::start();
        let g2 = blas::hadamard(&blas::gram(&f.w), &blas::gram(&f.h));
        f.v = solve_mode(&m2, &g2, opts.nonneg);
        normalize_cols_safe(&mut f.v);
        phases.solve_secs += sw.elapsed_secs();
        budget.release((m2.rows() * m2.cols() * 8) as u64);

        // --- mode 3: W ------------------------------------------------------
        let sw = Stopwatch::start();
        let m3 = coo.mttkrp(2, [&f.h, &f.v, &f.w], budget)?;
        phases.mttkrp_secs += sw.elapsed_secs();
        let sw = Stopwatch::start();
        let g3 = blas::hadamard(&blas::gram(&f.v), &blas::gram(&f.h));
        f.w = solve_mode(&m3, &g3, opts.nonneg);
        let stats = residual_stats(&m3, f, y.norm_sq());
        phases.solve_secs += sw.elapsed_secs();
        budget.release((m3.rows() * m3.cols() * 8) as u64);
        Ok(stats)
    })();

    drop(coo);
    budget.release(budget.used() - used_at_entry);
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::parafac2::cp_als::cp_iteration;
    use crate::parafac2::intermediate::PackedSlice;
    use crate::sparse::Csr;
    use crate::threadpool::Pool;
    use crate::util::rng::Pcg64;

    fn random_y(rng: &mut Pcg64, k: usize, j: usize, r: usize) -> PackedY {
        let slices = (0..k)
            .map(|_| {
                let rows = r + rng.range(2, 6);
                let mut trips = vec![(0usize, rng.range(0, j), 1.0)];
                for i in 0..rows {
                    for jj in 0..j {
                        if rng.chance(0.25) {
                            trips.push((i, jj, rng.uniform(0.1, 1.5)));
                        }
                    }
                }
                let xk = Csr::from_triplets(rows, j, trips);
                let qk = crate::linalg::random_orthonormal(rows, r, rng);
                PackedSlice::pack(&xk, &qk)
            })
            .collect();
        PackedY { slices, j_dim: j }
    }

    #[test]
    fn baseline_matches_spartan_iteration_exactly() {
        // Same Y, same starting factors ⇒ identical updated factors and
        // residual (both compute the same math, differently).
        let mut rng = Pcg64::seed(141);
        for &(k, j, r) in &[(4usize, 7usize, 2usize), (8, 10, 3)] {
            let y = random_y(&mut rng, k, j, r);
            let f0 = CpFactors {
                h: Mat::rand_normal(r, r, &mut rng),
                v: Mat::rand_normal(j, r, &mut rng),
                w: Mat::rand_uniform(k, r, &mut rng),
            };
            for nonneg in [false, true] {
                let opts = CpOptions { nonneg };
                let mut fa = f0.clone();
                let mut fb = f0.clone();
                let sa = cp_iteration(
                    &y,
                    &mut fa,
                    opts,
                    &Pool::serial(),
                    &crate::threadpool::ChunkPlan::fixed(k),
                );
                let budget = MemBudget::unlimited();
                let mut phases = BaselinePhases::default();
                let sb =
                    cp_iteration_baseline(&y, &mut fb, opts, &budget, &mut phases).unwrap();
                assert!(fa.h.max_abs_diff(&fb.h) < 1e-8, "H nonneg={nonneg}");
                assert!(fa.v.max_abs_diff(&fb.v) < 1e-8, "V nonneg={nonneg}");
                assert!(fa.w.max_abs_diff(&fb.w) < 1e-8, "W nonneg={nonneg}");
                assert!(
                    (sa.y_residual_sq - sb.y_residual_sq).abs()
                        < 1e-8 * (1.0 + sa.y_residual_sq)
                );
            }
        }
    }

    #[test]
    fn budget_exhaustion_reports_oom() {
        let mut rng = Pcg64::seed(142);
        let y = random_y(&mut rng, 6, 9, 3);
        let mut f = CpFactors {
            h: Mat::rand_normal(3, 3, &mut rng),
            v: Mat::rand_normal(9, 3, &mut rng),
            w: Mat::rand_uniform(6, 3, &mut rng),
        };
        let budget = MemBudget::limited(64); // absurdly small
        let mut phases = BaselinePhases::default();
        let err = cp_iteration_baseline(&y, &mut f, CpOptions::default(), &budget, &mut phases);
        assert!(err.is_err());
        // budget rolls back so a subsequent unlimited-ish run still works
        assert_eq!(budget.used(), 0);
    }

    #[test]
    fn budget_released_after_success() {
        let mut rng = Pcg64::seed(143);
        let y = random_y(&mut rng, 4, 6, 2);
        let mut f = CpFactors {
            h: Mat::rand_normal(2, 2, &mut rng),
            v: Mat::rand_normal(6, 2, &mut rng),
            w: Mat::rand_uniform(4, 2, &mut rng),
        };
        let budget = MemBudget::limited(10 << 20);
        let mut phases = BaselinePhases::default();
        cp_iteration_baseline(&y, &mut f, CpOptions::default(), &budget, &mut phases).unwrap();
        assert_eq!(budget.used(), 0, "all charges released");
        assert!(budget.peak() > 0, "peak recorded");
    }
}
