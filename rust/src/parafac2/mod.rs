//! PARAFAC2 fitting: the model, the classical ALS algorithm, SPARTan's
//! specialized MTTKRP kernels (the paper's contribution), and the
//! Tensor-Toolbox-style baseline it is evaluated against.

pub mod als;
pub mod baseline;
pub mod cp_als;
pub mod init;
pub mod intermediate;
pub mod model;
pub mod mttkrp;
pub mod procrustes;
pub mod restarts;

pub use als::{
    fit_parafac2, Backend, DataHandle, FitError, FitSession, IterationRecord, Parafac2Config,
    ResumeState, SessionOptions, StepOutcome, WarmStart,
};
pub use model::{FitStats, Parafac2Model};
pub use restarts::fit_parafac2_restarts;
