//! Multi-restart fitting.
//!
//! ALS converges to a local optimum of a non-convex objective; the
//! standard remedy (and what practitioners do with the reference Matlab
//! implementation) is several fits from independent random
//! initializations, keeping the best final SSE. Restarts reuse the same
//! config with per-restart derived seeds, so a run is reproducible from
//! the base seed.

use super::als::{fit_parafac2, FitError, Parafac2Config};
use super::model::Parafac2Model;
use crate::sparse::IrregularTensor;

/// Summary of one restart.
#[derive(Clone, Debug)]
pub struct RestartRecord {
    pub seed: u64,
    pub final_fit: f64,
    pub final_sse: f64,
    pub iterations: usize,
    pub secs: f64,
}

/// Outcome of a multi-restart fit.
pub struct RestartOutcome {
    /// The best model (highest fit / lowest SSE).
    pub best: Parafac2Model,
    /// Index into `records` of the winner.
    pub best_index: usize,
    /// Per-restart summaries, in execution order.
    pub records: Vec<RestartRecord>,
}

/// Run `n_restarts` independent fits (seeds `base_seed + i`), keep the
/// best. `n_restarts = 1` is exactly [`fit_parafac2`].
pub fn fit_parafac2_restarts(
    data: &IrregularTensor,
    cfg: &Parafac2Config,
    n_restarts: usize,
) -> Result<RestartOutcome, FitError> {
    assert!(n_restarts >= 1, "need at least one restart");
    let mut best: Option<(usize, Parafac2Model)> = None;
    let mut records = Vec::with_capacity(n_restarts);
    for i in 0..n_restarts {
        let mut c = cfg.clone();
        c.seed = cfg.seed.wrapping_add(i as u64);
        let model = fit_parafac2(data, &c)?;
        crate::info!(
            "restart {i} (seed {}): fit {:.5} after {} iters",
            c.seed,
            model.stats.final_fit,
            model.stats.iterations
        );
        records.push(RestartRecord {
            seed: c.seed,
            final_fit: model.stats.final_fit,
            final_sse: model.stats.final_sse,
            iterations: model.stats.iterations,
            secs: model.stats.total_secs,
        });
        let better = best
            .as_ref()
            .map_or(true, |(_, b)| model.stats.final_sse < b.stats.final_sse);
        if better {
            best = Some((i, model));
        }
    }
    let (best_index, best) = best.expect("n_restarts >= 1");
    Ok(RestartOutcome { best, best_index, records })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::synthetic::{generate, SyntheticSpec};

    fn data() -> IrregularTensor {
        generate(&SyntheticSpec {
            k: 40,
            j: 20,
            max_i_k: 8,
            target_nnz: 5_000,
            rank: 3,
            noise: 0.05,
            seed: 4,
        })
        .tensor
    }

    #[test]
    fn best_of_restarts_is_no_worse_than_any() {
        let d = data();
        let cfg = Parafac2Config { rank: 3, max_iters: 15, workers: 1, ..Default::default() };
        let out = fit_parafac2_restarts(&d, &cfg, 3).unwrap();
        assert_eq!(out.records.len(), 3);
        for r in &out.records {
            assert!(out.best.stats.final_sse <= r.final_sse + 1e-12);
        }
        assert_eq!(
            out.records[out.best_index].final_sse,
            out.best.stats.final_sse
        );
    }

    #[test]
    fn single_restart_equals_plain_fit() {
        let d = data();
        let cfg = Parafac2Config { rank: 2, max_iters: 10, workers: 1, seed: 9, ..Default::default() };
        let out = fit_parafac2_restarts(&d, &cfg, 1).unwrap();
        let plain = fit_parafac2(&d, &cfg).unwrap();
        assert_eq!(out.best.stats.final_sse, plain.stats.final_sse);
        assert_eq!(out.best.v.data(), plain.v.data());
    }

    #[test]
    fn restart_seeds_differ() {
        let d = data();
        let cfg = Parafac2Config { rank: 2, max_iters: 5, workers: 1, ..Default::default() };
        let out = fit_parafac2_restarts(&d, &cfg, 3).unwrap();
        assert_eq!(out.records[0].seed + 1, out.records[1].seed);
        // different inits ⇒ (almost surely) different trajectories
        assert_ne!(out.records[0].final_sse, out.records[1].final_sse);
    }
}
