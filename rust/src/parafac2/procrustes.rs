//! Step 1 of PARAFAC2-ALS: the per-subject Orthogonal Procrustes update
//! (paper Algorithm 2, lines 3–6), fused with the construction of the
//! packed intermediate slices `Y_k = Q_kᵀ X_k` (lines 7–9) — and, in the
//! ALS hot path, fused further with the **mode-1 MTTKRP** so the packed
//! slice is consumed the moment it is produced
//! ([`procrustes_pack_mode1`]).
//!
//! The textbook step is: SVD of `H S_k Vᵀ X_kᵀ = P_k Σ_k Z_kᵀ`, then
//! `Q_k ← Z_k P_kᵀ`. That is exactly the orthonormal polar factor of
//! `B_k = X_k V S_k Hᵀ`, which we compute via the R×R eigen route
//! ([`crate::linalg::svd::polar_orthonormal`]) — O(nnz_k·R + I_k·R²)
//! per subject instead of an SVD of an R×I_k matrix.
//!
//! This step is embarrassingly parallel over the K subjects, and SPARTan
//! (like the paper) runs it chunked on the worker pool over the caller's
//! frozen [`ChunkPlan`] (nnz-balanced in the ALS driver, so a heavy-tailed
//! cohort cannot strand the whole sweep behind one overloaded chunk).
//!
//! Both per-subject hot products run on the register-blocked micro-kernels
//! behind the `linalg::kernels` dispatch point: the `C_k = X_k V` stage of
//! [`procrustes_target`] via `Csr::matmul_dense`, and the pack-fused
//! mode-1 read via `PackedSlice::yk_times_v_fused`. Both are in the
//! kernel layer's order-preserving family (bitwise identical to the scalar
//! references), so this module's fused-vs-separate bitwise guarantees are
//! untouched by kernel selection.

use super::intermediate::{PackedSlice, PackedY};
use crate::linalg::{blas, Mat};
use crate::sparse::IrregularTensor;
use crate::threadpool::{ChunkPlan, Pool};

/// Compute `B_k = X_k V S_k Hᵀ` for one subject.
///
/// Two-stage to exploit the column sparsity of `X_k`: first
/// `C_k = X_k · V` (touches only support rows of V, cost `nnz_k · R`),
/// then `B_k = C_k · (S_k Hᵀ)` (cost `I_k · R²`).
pub fn procrustes_target(
    xk: &crate::sparse::Csr,
    v: &Mat,
    h: &Mat,
    s_k: &[f64],
) -> Mat {
    let ck = xk.matmul_dense(v); // I_k × R
    // D = S_k Hᵀ: row r of Hᵀ is column r of H scaled by s_k[r]
    let r = h.rows();
    let d = Mat::from_fn(r, r, |i, j| s_k[i] * h[(j, i)]);
    blas::matmul(&ck, &d)
}

/// Per-subject Procrustes + pack. Returns the packed `Y_k` slice, and the
/// orthonormal `Q_k` if `keep_q` (memory: keeping every `Q_k` costs
/// `Σ I_k · R` floats, so the ALS loop only materializes them on the final
/// iteration).
pub fn procrustes_and_pack(
    xk: &crate::sparse::Csr,
    v: &Mat,
    h: &Mat,
    s_k: &[f64],
    keep_q: bool,
) -> (PackedSlice, Option<Mat>) {
    let b = procrustes_target(xk, v, h, s_k);
    // One-sided Jacobi polar (§Perf step 2): for tall targets (I_k ≥ R)
    // rank-deficient directions are completed so Q_kᵀQ_k = I holds exactly
    // (matching the SVD formulation's arbitrary orthonormal completion,
    // same objective); short slices (I_k < R) get orthonormal rows.
    let qk = crate::linalg::svd::procrustes_polar_jacobi(&b);
    let packed = PackedSlice::pack(xk, &qk);
    (packed, if keep_q { Some(qk) } else { None })
}

/// Run step 1 for all subjects on the pool, writing the packed slices
/// **in place** into `y` (the slice arena): the support/`local_cols`/`yt`
/// buffers of an already-filled arena are reused, so steady-state
/// iterations perform zero per-subject allocations in this phase.
/// Returns all `Q_k` when `keep_q`.
#[allow(clippy::too_many_arguments)]
pub fn procrustes_all_into(
    data: &IrregularTensor,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    keep_q: bool,
    y: &mut PackedY,
) -> Option<Vec<Mat>> {
    let k = data.k();
    y.j_dim = data.j();
    y.resize_slots(k);
    let per_chunk: Vec<Vec<Mat>> = pool.par_plan_chunks_mut(&mut y.slices, plan, |start, sub| {
        let mut qs = Vec::with_capacity(if keep_q { sub.len() } else { 0 });
        for (i, slot) in sub.iter_mut().enumerate() {
            let xk = data.slice(start + i);
            let b = procrustes_target(xk, v, h, w.row(start + i));
            let qk = crate::linalg::svd::procrustes_polar_jacobi(&b);
            slot.repack_from(xk, &qk);
            if keep_q {
                qs.push(qk);
            }
        }
        qs
    });
    if keep_q {
        let mut qs = Vec::with_capacity(k);
        for chunk_qs in per_chunk {
            qs.extend(chunk_qs);
        }
        Some(qs)
    } else {
        None
    }
}

/// Result of the pack-fused Procrustes → mode-1 sweep.
pub struct FusedPackSweep {
    /// `M¹ = Σ_k rowhad(Y_k V, W(k,:))` — the mode-1 MTTKRP, accumulated
    /// chunk-ordered while each `Y_k` was still cache-resident from its
    /// pack. Bitwise identical to
    /// [`super::mttkrp::mttkrp_mode1`]`(y, v, w, pool, plan)` on the same
    /// plan.
    pub m1: Mat,
    /// `Y_k·V` products performed — exactly one per subject.
    pub yv_products: u64,
}

/// Step 1 **fused with the mode-1 MTTKRP** (DPar2-style): per subject,
/// compute `Q_k`, repack `Y_k` into its arena slot, and immediately emit
/// `P_k = Y_k V` + the `W(k,:)` row-Hadamard while the freshly packed
/// rows are hot in cache — so the CP step that follows never has to
/// stream the packed slices for mode 1 again, cutting cold packed-slice
/// traversals from 2 to 1 per ALS iteration (mode 2 is the only remaining
/// sweep; asserted in `metrics::flops`).
///
/// Mode 1 needs `V` and `W` *as of the start of the iteration* — exactly
/// the factors this Procrustes step consumes — which is what makes the
/// fusion legal without changing any update's inputs. Per-chunk `M¹`
/// partials merge in the plan's chunk order: bitwise identical to the
/// standalone pack + [`super::mttkrp::mttkrp_mode1`] on the same plan,
/// and bitwise deterministic across worker counts.
pub fn procrustes_pack_mode1(
    data: &IrregularTensor,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    y: &mut PackedY,
) -> FusedPackSweep {
    let r = v.cols();
    assert_eq!(w.cols(), r, "W/V rank mismatch");
    y.j_dim = data.j();
    y.resize_slots(data.k());
    let partials: Vec<(Mat, u64)> = pool.par_plan_chunks_mut(&mut y.slices, plan, |start, sub| {
        let mut acc = Mat::zeros(r, r);
        let mut yv_products = 0u64;
        for (i, slot) in sub.iter_mut().enumerate() {
            let kk = start + i;
            let xk = data.slice(kk);
            let b = procrustes_target(xk, v, h, w.row(kk));
            let qk = crate::linalg::svd::procrustes_polar_jacobi(&b);
            slot.repack_from(xk, &qk);
            // The fusion: consume the slice now, while `yt` is cache-hot
            // from the pack above. Same kernel, same FP order as the
            // standalone mode-1 sweep.
            let mut temp = slot.yk_times_v_fused(v);
            yv_products += 1;
            blas::rowhad_inplace(&mut temp, w.row(kk));
            acc.axpy(1.0, &temp);
        }
        (acc, yv_products)
    });
    // Seed the merge with the first chunk's partial — the exact fold
    // structure `mttkrp_mode1` uses — so even the signs of exact zeros
    // come out bitwise identical to the standalone sweep.
    let mut parts = partials.into_iter();
    let (mut m1, mut yv_products) = match parts.next() {
        Some(first) => first,
        None => (Mat::zeros(r, r), 0),
    };
    for (part, n) in parts {
        m1.axpy(1.0, &part);
        yv_products += n;
    }
    FusedPackSweep { m1, yv_products }
}

/// Run step 1 for all subjects on the pool into a fresh [`PackedY`],
/// chunked by an nnz-balanced plan derived from `data`. (Convenience
/// wrapper over [`procrustes_all_into`]; the ALS loop holds a persistent
/// arena and plan instead.)
pub fn procrustes_all(
    data: &IrregularTensor,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    keep_q: bool,
) -> (PackedY, Option<Vec<Mat>>) {
    let mut y = PackedY::empty(data.j());
    let plan = subject_plan(data);
    let qs = procrustes_all_into(data, v, h, w, pool, &plan, keep_q, &mut y);
    (y, qs)
}

/// The per-fit chunk plan: contiguous subject chunks balanced by
/// per-subject `nnz(X_k)` (the dominant per-subject cost of both the
/// Procrustes pack, `O(nnz_k·R)`, and the CP sweeps, `O(c_k·R²)` with
/// `c_k ≤ nnz_k`). Boundaries depend only on the data — see
/// [`ChunkPlan::balanced`] for the determinism contract.
pub fn subject_plan(data: &IrregularTensor) -> ChunkPlan {
    let weights: Vec<u64> = (0..data.k()).map(|k| data.slice(k).nnz() as u64).collect();
    ChunkPlan::balanced(&weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthonormality_defect;
    use crate::linalg::svd::svd_thin;
    use crate::parafac2::mttkrp;
    use crate::sparse::Csr;
    use crate::util::rng::Pcg64;

    fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trips = vec![(0, 0, 1.0)]; // guarantee nonzero
        for i in 0..rows {
            for j in 0..cols {
                if rng.chance(density) {
                    trips.push((i, j, rng.uniform(0.1, 2.0)));
                }
            }
        }
        Csr::from_triplets(rows, cols, trips)
    }

    #[test]
    fn qk_is_orthonormal_and_optimal() {
        let mut rng = Pcg64::seed(111);
        let r = 4;
        let xk = random_sparse(&mut rng, 15, 12, 0.2);
        let v = Mat::rand_normal(12, r, &mut rng);
        let h = Mat::rand_normal(r, r, &mut rng);
        let s_k: Vec<f64> = (0..r).map(|_| rng.uniform(0.5, 2.0)).collect();
        let (_, q) = procrustes_and_pack(&xk, &v, &h, &s_k, true);
        let q = q.unwrap();
        assert!(orthonormality_defect(&q) < 1e-8);

        // Optimality: Q_k minimizes ‖X_k − Q H S_k Vᵀ‖² over orthonormal Q.
        let target = {
            // H S_k Vᵀ  (R × J)
            let hs = Mat::from_fn(r, r, |i, j| h[(i, j)] * s_k[j]);
            blas::matmul_a_bt(&hs, &v)
        };
        let xd = xk.to_dense();
        let obj = |q: &Mat| blas::matmul(q, &target).fro_dist(&xd);
        let opt = obj(&q);
        for _ in 0..10 {
            let cand = crate::linalg::random_orthonormal(15, r, &mut rng);
            assert!(obj(&cand) >= opt - 1e-8);
        }
    }

    #[test]
    fn matches_svd_formulation() {
        // Q_k from the paper's SVD of H S_k Vᵀ X_kᵀ = P Σ Zᵀ, Q_k = Z Pᵀ.
        let mut rng = Pcg64::seed(112);
        let r = 3;
        let xk = random_sparse(&mut rng, 10, 8, 0.3);
        let v = Mat::rand_normal(8, r, &mut rng);
        let h = Mat::rand_normal(r, r, &mut rng);
        let s_k: Vec<f64> = (0..r).map(|_| rng.uniform(0.5, 2.0)).collect();

        let (_, q_polar) = procrustes_and_pack(&xk, &v, &h, &s_k, true);
        let q_polar = q_polar.unwrap();

        let hs = Mat::from_fn(r, r, |i, j| h[(i, j)] * s_k[j]);
        let hsvt = blas::matmul_a_bt(&hs, &v); // R × J
        let f = blas::matmul_a_bt(&hsvt, &xk.to_dense()); // R × I_k
        let (p, _s, z) = svd_thin(&f);
        let q_svd = blas::matmul_a_bt(&z, &p); // Z Pᵀ: I_k × R
        assert!(q_polar.max_abs_diff(&q_svd) < 1e-7);
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Pcg64::seed(113);
        let r = 3;
        let slices: Vec<Csr> = (0..7)
            .map(|_| {
                let rows = 6 + rng.range(0, 5);
                random_sparse(&mut rng, rows, 9, 0.25)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let v = Mat::rand_normal(9, r, &mut rng);
        let h = Mat::rand_normal(r, r, &mut rng);
        let w = Mat::rand_uniform(7, r, &mut rng);

        let (y_ser, q_ser) = procrustes_all(&data, &v, &h, &w, &Pool::serial(), true);
        let (y_par, q_par) = procrustes_all(&data, &v, &h, &w, &Pool::new(4), true);
        assert_eq!(y_ser.k(), y_par.k());
        for k in 0..data.k() {
            assert!(y_ser.slices[k].yt.max_abs_diff(&y_par.slices[k].yt) < 1e-14);
            assert!(q_ser.as_ref().unwrap()[k].max_abs_diff(&q_par.as_ref().unwrap()[k]) < 1e-14);
        }
    }

    #[test]
    fn arena_repack_matches_fresh_pack_bitwise() {
        let mut rng = Pcg64::seed(115);
        let r = 3;
        let slices: Vec<Csr> = (0..5)
            .map(|_| {
                let rows = 5 + rng.range(0, 4);
                random_sparse(&mut rng, rows, 8, 0.3)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let mut y = crate::parafac2::intermediate::PackedY::empty(data.j());
        let pool = Pool::new(3);
        let plan = subject_plan(&data);
        for round in 0..4 {
            let v = Mat::rand_normal(8, r, &mut rng);
            let h = Mat::rand_normal(r, r, &mut rng);
            let w = Mat::rand_uniform(5, r, &mut rng);
            let _ = procrustes_all_into(&data, &v, &h, &w, &pool, &plan, false, &mut y);
            let (fresh, _) = procrustes_all(&data, &v, &h, &w, &Pool::serial(), false);
            for k in 0..data.k() {
                assert_eq!(
                    y.slices[k].yt.data(),
                    fresh.slices[k].yt.data(),
                    "round {round} subject {k}"
                );
            }
        }
    }

    #[test]
    fn pack_fused_mode1_matches_separate_bitwise() {
        // THE tentpole regression guard: the pack-fused sweep must be
        // bitwise indistinguishable from "repack, then standalone mode-1
        // MTTKRP" — same arena contents, same M¹ bits — on the same plan,
        // for fixed and balanced (heavy-tailed ⇒ uneven) boundaries, on
        // serial and parallel pools, across arena-reusing rounds.
        let mut rng = Pcg64::seed(116);
        let r = 3;
        let k = 70; // crosses the SUBJECT_CHUNK boundary
        let slices: Vec<Csr> = (0..k)
            .map(|kk| {
                // heavy tail: subject 0 holds ~half the cohort's nnz
                let (rows, dens) = if kk == 0 { (30, 0.9) } else { (4 + rng.range(0, 4), 0.08) };
                random_sparse(&mut rng, rows, 40, dens)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let balanced = subject_plan(&data);
        assert!(balanced.n_chunks() > 1);
        for plan in [ChunkPlan::fixed(k), balanced] {
            for workers in [1usize, 4] {
                let pool = Pool::new(workers);
                let mut y_fused = PackedY::empty(data.j());
                let mut y_sep = PackedY::empty(data.j());
                let mut rng2 = Pcg64::seed(991);
                for round in 0..3 {
                    let v = Mat::rand_normal(40, r, &mut rng2);
                    let h = Mat::rand_normal(r, r, &mut rng2);
                    let w = Mat::rand_uniform(k, r, &mut rng2);
                    let sweep =
                        procrustes_pack_mode1(&data, &v, &h, &w, &pool, &plan, &mut y_fused);
                    let _ =
                        procrustes_all_into(&data, &v, &h, &w, &pool, &plan, false, &mut y_sep);
                    let m1 = mttkrp::mttkrp_mode1(&y_sep, &v, &w, &pool, &plan);
                    assert_eq!(
                        sweep.m1.data(),
                        m1.data(),
                        "round {round}, {workers} workers"
                    );
                    assert_eq!(sweep.yv_products, k as u64);
                    for kk in 0..k {
                        assert_eq!(
                            y_fused.slices[kk].yt.data(),
                            y_sep.slices[kk].yt.data(),
                            "round {round} subject {kk}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn subject_plan_balances_heavy_cohort() {
        let mut rng = Pcg64::seed(117);
        // subject 0 carries well over half the nnz of the cohort
        let slices: Vec<Csr> = (0..80)
            .map(|kk| {
                let (rows, dens) = if kk == 0 { (60, 0.95) } else { (12, 0.02) };
                random_sparse(&mut rng, rows, 120, dens)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let plan = subject_plan(&data);
        assert!(plan.covers(80));
        // the heavy subject's chunk closes right after it
        let heavy = plan.ranges().iter().find(|r| r.contains(&0)).unwrap();
        assert_eq!(heavy.clone(), 0..1, "heavy chunk {heavy:?}");
        assert_ne!(plan, ChunkPlan::fixed(80));
    }

    #[test]
    fn short_slice_ik_below_rank() {
        // I_k < R must not panic and must give orthonormal *rows*.
        let mut rng = Pcg64::seed(114);
        let r = 5;
        let xk = random_sparse(&mut rng, 3, 10, 0.5);
        let v = Mat::rand_normal(10, r, &mut rng);
        let h = Mat::rand_normal(r, r, &mut rng);
        let s_k = vec![1.0; r];
        let (_, q) = procrustes_and_pack(&xk, &v, &h, &s_k, true);
        let q = q.unwrap();
        let qqt = blas::matmul_a_bt(&q, &q);
        assert!(qqt.max_abs_diff(&Mat::eye(3)) < 1e-7);
    }
}
