//! Step 1 of PARAFAC2-ALS: the per-subject Orthogonal Procrustes update
//! (paper Algorithm 2, lines 3–6), fused with the construction of the
//! packed intermediate slices `Y_k = Q_kᵀ X_k` (lines 7–9) — and, in the
//! ALS hot path, fused further with the **mode-1 MTTKRP** so the packed
//! slice is consumed the moment it is produced
//! ([`procrustes_pack_mode1`]).
//!
//! The textbook step is: SVD of `H S_k Vᵀ X_kᵀ = P_k Σ_k Z_kᵀ`, then
//! `Q_k ← Z_k P_kᵀ`. That is exactly the orthonormal polar factor of
//! `B_k = X_k V S_k Hᵀ`, which we compute via one-sided Jacobi
//! ([`crate::linalg::svd::procrustes_polar_jacobi_into`]) —
//! O(nnz_k·R + I_k·R²) per subject instead of an SVD of an R×I_k matrix.
//!
//! ## Single traversal over the resident compact-X arena
//!
//! The hot sweeps read the [`CompactX`] arena, not the original CSR: the
//! target stage gathers the support rows of `V` into a contiguous panel
//! and streams the subject's compact values **once** per iteration
//! (`C_k = X̃_k·V`, the iteration's only cold X pass), and the repack
//! `Y_k = Q_kᵀX̃_k` rides that pass, re-reading the same cache-resident
//! values instead of re-streaming CSR — the data-side twin of the PR 2
//! pack→mode-1 fusion, with the 2→1 drop pinned by the arena's
//! `x_traversals` tally (`metrics::flops` asserts it against the
//! two-sweep reference structure, [`procrustes_then_repack_separate`]).
//! Every per-subject temporary (the gathered panel, `C_k`, `B_k`,
//! `D = S_k Hᵀ`, `Q_k`, the polar factor's internals, the fused `Y_k·V`
//! output) lives in a per-chunk [`SubjectScratch`], so steady-state
//! iterations allocate nothing in this phase (asserted end-to-end by the
//! `arena_memory` integration test).
//!
//! This step is embarrassingly parallel over the K subjects, and SPARTan
//! (like the paper) runs it chunked on the worker pool over the caller's
//! frozen [`ChunkPlan`] (nnz-balanced in the ALS driver, so a heavy-tailed
//! cohort cannot strand the whole sweep behind one overloaded chunk);
//! scratch arenas are per *chunk*, so results are bitwise identical across
//! worker counts.
//!
//! Both per-subject hot products run on the register-blocked micro-kernels
//! behind the `linalg::kernels` dispatch point: the `C_k = X̃_k·V` stage
//! via `sparse_row_axpy` against the gathered panel (the identical
//! per-entry floating-point sequence `Csr::matmul_dense` produces — the
//! arena changes *where* the operands live, never the arithmetic), and
//! the pack-fused mode-1 read via `PackedSlice::yk_times_v_fused_into`.
//! The `*_csr` variants keep the pre-arena CSR-streaming structure
//! callable for the `ablations --filter xfuse` A/B and the bitwise
//! cross-checks below.

use super::intermediate::{PackedSlice, PackedY};
use crate::linalg::{blas, svd, Mat};
use crate::sparse::{CompactSlice, CompactX, IrregularTensor};
use crate::threadpool::{ChunkPlan, Pool};

/// Per-chunk scratch arena for the Procrustes sweeps: every per-subject
/// temporary, sized to the chunk's high-water shapes during the first
/// iteration and reused (zero-reset) forever after. One instance per plan
/// chunk ([`SubjectScratch::for_plan`]); chunk→scratch assignment depends
/// only on the chunk id, so scratch can never perturb determinism.
#[derive(Debug)]
pub struct SubjectScratch {
    /// Gathered `V` support panel (`c_k × R`).
    vc: Mat,
    /// `C_k = X̃_k·V` (`I_k × R`).
    ck: Mat,
    /// Procrustes target `B_k = C_k·(S_k Hᵀ)` (`I_k × R`).
    b: Mat,
    /// `D = S_k Hᵀ` (`R × R`) — hoisted out of the per-subject loop.
    d: Mat,
    /// Polar factor `Q_k` (`I_k × R`).
    q: Mat,
    /// Fused mode-1 output `rowhad(Y_k V, W(k,:))` staging (`R × R`).
    temp: Mat,
    /// The polar factor's internal buffers.
    polar: svd::PolarScratch,
}

impl Default for SubjectScratch {
    fn default() -> Self {
        SubjectScratch::new()
    }
}

impl SubjectScratch {
    pub fn new() -> SubjectScratch {
        SubjectScratch {
            vc: Mat::zeros(0, 0),
            ck: Mat::zeros(0, 0),
            b: Mat::zeros(0, 0),
            d: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            temp: Mat::zeros(0, 0),
            polar: svd::PolarScratch::new(),
        }
    }

    /// One scratch arena per chunk of `plan` (the fit allocates this once
    /// next to the packed-Y arena).
    pub fn for_plan(plan: &ChunkPlan) -> Vec<SubjectScratch> {
        (0..plan.n_chunks()).map(|_| SubjectScratch::new()).collect()
    }

    /// Current heap footprint (memory accounting; grows to the chunk's
    /// high-water shapes during iteration 1, then stays put).
    pub fn heap_bytes(&self) -> u64 {
        self.vc.heap_bytes()
            + self.ck.heap_bytes()
            + self.b.heap_bytes()
            + self.d.heap_bytes()
            + self.q.heap_bytes()
            + self.temp.heap_bytes()
            + self.polar.heap_bytes()
    }
}

/// Total heap footprint of a per-chunk scratch set.
pub fn scratch_heap_bytes(scratch: &[SubjectScratch]) -> u64 {
    scratch.iter().map(|s| s.heap_bytes()).sum()
}

/// Compute `B_k = X̃_k V S_k Hᵀ` for one subject into `s.b`, entirely from
/// the resident arena + scratch: `D = S_k Hᵀ` into `s.d`, the gathered
/// support panel into `s.vc`, the cold `C_k = X̃_k·V` pass into `s.ck`
/// (the subject's **one** tallied X traversal this sweep), then the
/// `I_k × R²` epilogue. Bitwise identical to the CSR-streaming
/// [`procrustes_target`].
fn target_into(cxk: &CompactSlice, v: &Mat, h: &Mat, s_k: &[f64], s: &mut SubjectScratch) {
    let r = h.rows();
    // D = S_k Hᵀ: row r of Hᵀ is column r of H scaled by s_k[r] — same
    // values in the same row-major write order as the historical
    // `Mat::from_fn`, now in reused scratch (every element written, so no
    // zero-fill pass).
    s.d.reset_for_overwrite(r, r);
    for i in 0..r {
        for j in 0..r {
            s.d[(i, j)] = s_k[i] * h[(j, i)];
        }
    }
    cxk.gather_v_into(v, &mut s.vc);
    cxk.times_v_into(&s.vc, &mut s.ck); // the cold X pass (tallied)
    s.b.reset_to_zeros(cxk.rows(), r);
    blas::gemm_acc(&mut s.b, &s.ck, &s.d, 1.0);
}

/// Compute `B_k = X_k V S_k Hᵀ` for one subject from the original CSR
/// (pre-arena structure; kept for the coordinator-independent callers,
/// tests, and the `xfuse` ablation's streaming arm).
///
/// Two-stage to exploit the column sparsity of `X_k`: first
/// `C_k = X_k · V` (touches only support rows of V, cost `nnz_k · R`),
/// then `B_k = C_k · (S_k Hᵀ)` (cost `I_k · R²`).
pub fn procrustes_target(
    xk: &crate::sparse::Csr,
    v: &Mat,
    h: &Mat,
    s_k: &[f64],
) -> Mat {
    let ck = xk.matmul_dense(v); // I_k × R
    // D = S_k Hᵀ: row r of Hᵀ is column r of H scaled by s_k[r]
    let r = h.rows();
    let d = Mat::from_fn(r, r, |i, j| s_k[i] * h[(j, i)]);
    blas::matmul(&ck, &d)
}

/// Per-subject Procrustes + pack from the original CSR. Returns the packed
/// `Y_k` slice, and the orthonormal `Q_k` if `keep_q`.
pub fn procrustes_and_pack(
    xk: &crate::sparse::Csr,
    v: &Mat,
    h: &Mat,
    s_k: &[f64],
    keep_q: bool,
) -> (PackedSlice, Option<Mat>) {
    let b = procrustes_target(xk, v, h, s_k);
    // One-sided Jacobi polar (§Perf step 2): for tall targets (I_k ≥ R)
    // rank-deficient directions are completed so Q_kᵀQ_k = I holds exactly
    // (matching the SVD formulation's arbitrary orthonormal completion,
    // same objective); short slices (I_k < R) get orthonormal rows.
    let qk = crate::linalg::svd::procrustes_polar_jacobi(&b);
    let packed = PackedSlice::pack(xk, &qk);
    (packed, if keep_q { Some(qk) } else { None })
}

/// Per-subject Procrustes + pack from the **resident arena** (the
/// coordinator's native-fallback path): same bits as
/// [`procrustes_and_pack`], one cold X pass instead of two, zero
/// steady-state allocations beyond the returned slice.
pub fn procrustes_and_pack_compact(
    cxk: &CompactSlice,
    v: &Mat,
    h: &Mat,
    s_k: &[f64],
    keep_q: bool,
    s: &mut SubjectScratch,
) -> (PackedSlice, Option<Mat>) {
    target_into(cxk, v, h, s_k, s);
    svd::procrustes_polar_jacobi_into(&s.b, &mut s.polar, &mut s.q);
    let mut slot = PackedSlice::empty();
    cxk.repack_y_fused(&s.q, &mut slot); // rides the C_k pass
    (slot, if keep_q { Some(s.q.clone()) } else { None })
}

/// Run step 1 for all subjects on the pool, writing the packed slices
/// **in place** into `y` (the slice arena) from the resident compact-X
/// arena: per subject, one cold pass over the compact values (`C_k`) with
/// the repack riding it. Returns all `Q_k` when `keep_q` (memory: keeping
/// every `Q_k` costs `Σ I_k · R` floats, so the ALS loop only materializes
/// them on the final iteration).
#[allow(clippy::too_many_arguments)]
pub fn procrustes_all_into(
    cx: &CompactX,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    keep_q: bool,
    y: &mut PackedY,
    scratch: &mut [SubjectScratch],
) -> Option<Vec<Mat>> {
    let k = cx.k();
    y.j_dim = cx.j();
    y.resize_slots(k);
    let per_chunk: Vec<Vec<Mat>> =
        pool.par_plan_zip_mut(&mut y.slices, scratch, plan, |start, sub, s| {
            let mut qs = Vec::with_capacity(if keep_q { sub.len() } else { 0 });
            for (i, slot) in sub.iter_mut().enumerate() {
                let cxk = &cx.slices[start + i];
                target_into(cxk, v, h, w.row(start + i), s);
                svd::procrustes_polar_jacobi_into(&s.b, &mut s.polar, &mut s.q);
                cxk.repack_y_fused(&s.q, slot);
                if keep_q {
                    qs.push(s.q.clone());
                }
            }
            qs
        });
    if keep_q {
        let mut qs = Vec::with_capacity(k);
        for chunk_qs in per_chunk {
            qs.extend(chunk_qs);
        }
        Some(qs)
    } else {
        None
    }
}

/// Pre-arena CSR-streaming form of [`procrustes_all_into`] (streams each
/// original `X_k` twice per subject — target + repack). Kept callable for
/// the `xfuse` ablation's streaming arm and the bitwise cross-checks; the
/// ALS driver uses the arena form.
#[allow(clippy::too_many_arguments)]
pub fn procrustes_all_into_csr(
    data: &IrregularTensor,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    keep_q: bool,
    y: &mut PackedY,
) -> Option<Vec<Mat>> {
    let k = data.k();
    y.j_dim = data.j();
    y.resize_slots(k);
    let per_chunk: Vec<Vec<Mat>> = pool.par_plan_chunks_mut(&mut y.slices, plan, |start, sub| {
        let mut qs = Vec::with_capacity(if keep_q { sub.len() } else { 0 });
        for (i, slot) in sub.iter_mut().enumerate() {
            let xk = data.slice(start + i);
            let b = procrustes_target(xk, v, h, w.row(start + i));
            let qk = crate::linalg::svd::procrustes_polar_jacobi(&b);
            slot.repack_from(xk, &qk);
            if keep_q {
                qs.push(qk);
            }
        }
        qs
    });
    if keep_q {
        let mut qs = Vec::with_capacity(k);
        for chunk_qs in per_chunk {
            qs.extend(chunk_qs);
        }
        Some(qs)
    } else {
        None
    }
}

/// Result of the pack-fused Procrustes → mode-1 sweep.
pub struct FusedPackSweep {
    /// `M¹ = Σ_k rowhad(Y_k V, W(k,:))` — the mode-1 MTTKRP, accumulated
    /// chunk-ordered while each `Y_k` was still cache-resident from its
    /// pack. Bitwise identical to
    /// [`super::mttkrp::mttkrp_mode1`]`(y, v, w, pool, plan)` on the same
    /// plan.
    pub m1: Mat,
    /// `Y_k·V` products performed — exactly one per subject.
    pub yv_products: u64,
}

/// Step 1 **fused with the mode-1 MTTKRP** (DPar2-style) over the
/// resident arena: per subject, one cold pass over the compact X values
/// (`C_k`), `Q_k`, the repack riding that pass, and `P_k = Y_k V` + the
/// `W(k,:)` row-Hadamard emitted while the freshly packed rows are hot —
/// so a full ALS iteration makes exactly **one** cold pass over each
/// subject's X data *and* one cold traversal of its packed Y slice
/// (mode 2), both asserted in `metrics::flops`.
///
/// Mode 1 needs `V` and `W` *as of the start of the iteration* — exactly
/// the factors this Procrustes step consumes — which is what makes the
/// fusion legal without changing any update's inputs. Per-chunk `M¹`
/// partials merge in the plan's chunk order: bitwise identical to the
/// standalone pack + [`super::mttkrp::mttkrp_mode1`] on the same plan,
/// bitwise identical to the CSR-streaming
/// [`procrustes_pack_mode1_csr`], and bitwise deterministic across worker
/// counts.
#[allow(clippy::too_many_arguments)]
pub fn procrustes_pack_mode1(
    cx: &CompactX,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    y: &mut PackedY,
    scratch: &mut [SubjectScratch],
) -> FusedPackSweep {
    let r = v.cols();
    let partials = procrustes_pack_mode1_partials(cx, v, h, w, pool, plan, y, scratch);
    merge_fused_partials(partials, r)
}

/// The per-chunk half of [`procrustes_pack_mode1`]: run the fused sweep
/// and return the **unmerged** per-chunk `(M¹ partial, yv_products)` in
/// plan chunk order. The sharded coordinator ships these partials over
/// the wire and replays [`merge_fused_partials`] over the *global* chunk
/// sequence — the same flat seeded-from-first fold a single process runs —
/// which is what keeps a sharded fit bitwise identical to a local one.
#[allow(clippy::too_many_arguments)]
pub(crate) fn procrustes_pack_mode1_partials(
    cx: &CompactX,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    y: &mut PackedY,
    scratch: &mut [SubjectScratch],
) -> Vec<(Mat, u64)> {
    let r = v.cols();
    assert_eq!(w.cols(), r, "W/V rank mismatch");
    y.j_dim = cx.j();
    y.resize_slots(cx.k());
    pool.par_plan_zip_mut(&mut y.slices, scratch, plan, |start, sub, s| {
        let mut acc = Mat::zeros(r, r);
        let mut yv_products = 0u64;
        for (i, slot) in sub.iter_mut().enumerate() {
            let kk = start + i;
            let cxk = &cx.slices[kk];
            target_into(cxk, v, h, w.row(kk), s);
            svd::procrustes_polar_jacobi_into(&s.b, &mut s.polar, &mut s.q);
            cxk.repack_y_fused(&s.q, slot);
            // The fusion: consume the slice now, while `yt` is
            // cache-hot from the pack above. Same kernel, same FP
            // order as the standalone mode-1 sweep.
            slot.yk_times_v_fused_into(v, &mut s.temp);
            yv_products += 1;
            blas::rowhad_inplace(&mut s.temp, w.row(kk));
            acc.axpy(1.0, &s.temp);
        }
        (acc, yv_products)
    })
}

/// Pre-arena CSR-streaming form of [`procrustes_pack_mode1`]: identical
/// arithmetic (bitwise — pinned by `pack_fused_mode1_matches_csr_bitwise`)
/// but every subject re-streams its original CSR slice twice (target +
/// repack). The `xfuse` ablation's A/B arm: the wall-clock delta between
/// this and the arena sweep is the PR's claim, measured.
pub fn procrustes_pack_mode1_csr(
    data: &IrregularTensor,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    y: &mut PackedY,
) -> FusedPackSweep {
    let r = v.cols();
    assert_eq!(w.cols(), r, "W/V rank mismatch");
    y.j_dim = data.j();
    y.resize_slots(data.k());
    let partials: Vec<(Mat, u64)> = pool.par_plan_chunks_mut(&mut y.slices, plan, |start, sub| {
        let mut acc = Mat::zeros(r, r);
        let mut yv_products = 0u64;
        for (i, slot) in sub.iter_mut().enumerate() {
            let kk = start + i;
            let xk = data.slice(kk);
            let b = procrustes_target(xk, v, h, w.row(kk));
            let qk = crate::linalg::svd::procrustes_polar_jacobi(&b);
            slot.repack_from(xk, &qk);
            let mut temp = slot.yk_times_v_fused(v);
            yv_products += 1;
            blas::rowhad_inplace(&mut temp, w.row(kk));
            acc.axpy(1.0, &temp);
        }
        (acc, yv_products)
    });
    merge_fused_partials(partials, r)
}

/// Seed the merge with the first chunk's partial — the exact fold
/// structure `mttkrp_mode1` uses — so even the signs of exact zeros come
/// out bitwise identical to the standalone sweep. `pub(crate)` because the
/// sharded coordinator replays this exact fold over the wire-shipped
/// per-chunk partials, concatenated in global chunk order.
pub(crate) fn merge_fused_partials(partials: Vec<(Mat, u64)>, r: usize) -> FusedPackSweep {
    let mut parts = partials.into_iter();
    let (mut m1, mut yv_products) = match parts.next() {
        Some(first) => first,
        None => (Mat::zeros(r, r), 0),
    };
    for (part, n) in parts {
        m1.axpy(1.0, &part);
        yv_products += n;
    }
    FusedPackSweep { m1, yv_products }
}

/// The **unfused two-sweep reference structure** for the X-traversal
/// claim: sweep 1 computes every target and `Q_k` (one cold `C_k` pass
/// per subject), sweep 2 repacks every `Y_k` in a separate pass over the
/// arena (a second cold re-stream per subject, tallied via
/// [`CompactSlice::repack_y`]) — 2 cold X passes per subject per
/// iteration where the fused sweeps do 1. Bitwise identical outputs;
/// `metrics::flops` pins the 2→1 counter drop against this, and the
/// `xfuse` ablation times it.
pub fn procrustes_then_repack_separate(
    cx: &CompactX,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    y: &mut PackedY,
) {
    // Sweep 1 — targets + polar factors for every subject (chunk-ordered).
    let per_chunk: Vec<Vec<Mat>> = pool.par_plan_results(plan, |range| {
        let mut s = SubjectScratch::new();
        let mut qs = Vec::with_capacity(range.len());
        for kk in range {
            target_into(&cx.slices[kk], v, h, w.row(kk), &mut s);
            svd::procrustes_polar_jacobi_into(&s.b, &mut s.polar, &mut s.q);
            qs.push(s.q.clone());
        }
        qs
    });
    let mut qs = Vec::with_capacity(cx.k());
    for chunk_qs in per_chunk {
        qs.extend(chunk_qs);
    }
    // Sweep 2 — repack every slice in a second pass over the arena: by
    // now subject k's values are long out of cache (the whole cohort's
    // targets ran in between), so this is the honest cold re-stream the
    // fused structure eliminates.
    y.j_dim = cx.j();
    y.resize_slots(cx.k());
    pool.par_plan_chunks_mut(&mut y.slices, plan, |start, sub| {
        for (i, slot) in sub.iter_mut().enumerate() {
            cx.slices[start + i].repack_y(&qs[start + i], slot);
        }
    });
}

/// Run step 1 for all subjects into a fresh [`PackedY`], building a
/// one-shot arena + scratch internally. (Convenience wrapper over
/// [`procrustes_all_into`]; the ALS loop holds the persistent arena,
/// scratch, and plan instead.)
pub fn procrustes_all(
    data: &IrregularTensor,
    v: &Mat,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    keep_q: bool,
) -> (PackedY, Option<Vec<Mat>>) {
    let mut y = PackedY::empty(data.j());
    let plan = subject_plan(data);
    let cx = CompactX::pack(data, pool, &plan);
    let mut scratch = SubjectScratch::for_plan(&plan);
    let qs = procrustes_all_into(&cx, v, h, w, pool, &plan, keep_q, &mut y, &mut scratch);
    (y, qs)
}

/// The per-fit chunk plan: contiguous subject chunks balanced by
/// per-subject `nnz(X_k)` (the dominant per-subject cost of both the
/// Procrustes pack, `O(nnz_k·R)`, and the CP sweeps, `O(c_k·R²)` with
/// `c_k ≤ nnz_k`). Boundaries depend only on the data — see
/// [`ChunkPlan::balanced`] for the determinism contract.
pub fn subject_plan(data: &IrregularTensor) -> ChunkPlan {
    let weights: Vec<u64> = (0..data.k()).map(|k| data.slice(k).nnz() as u64).collect();
    ChunkPlan::balanced(&weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthonormality_defect;
    use crate::linalg::svd::svd_thin;
    use crate::parafac2::mttkrp;
    use crate::sparse::Csr;
    use crate::util::rng::Pcg64;

    fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trips = vec![(0, 0, 1.0)]; // guarantee nonzero
        for i in 0..rows {
            for j in 0..cols {
                if rng.chance(density) {
                    trips.push((i, j, rng.uniform(0.1, 2.0)));
                }
            }
        }
        Csr::from_triplets(rows, cols, trips)
    }

    fn bits_eq(a: &Mat, b: &Mat) -> bool {
        a.shape() == b.shape()
            && a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn qk_is_orthonormal_and_optimal() {
        let mut rng = Pcg64::seed(111);
        let r = 4;
        let xk = random_sparse(&mut rng, 15, 12, 0.2);
        let v = Mat::rand_normal(12, r, &mut rng);
        let h = Mat::rand_normal(r, r, &mut rng);
        let s_k: Vec<f64> = (0..r).map(|_| rng.uniform(0.5, 2.0)).collect();
        let (_, q) = procrustes_and_pack(&xk, &v, &h, &s_k, true);
        let q = q.unwrap();
        assert!(orthonormality_defect(&q) < 1e-8);

        // Optimality: Q_k minimizes ‖X_k − Q H S_k Vᵀ‖² over orthonormal Q.
        let target = {
            // H S_k Vᵀ  (R × J)
            let hs = Mat::from_fn(r, r, |i, j| h[(i, j)] * s_k[j]);
            blas::matmul_a_bt(&hs, &v)
        };
        let xd = xk.to_dense();
        let obj = |q: &Mat| blas::matmul(q, &target).fro_dist(&xd);
        let opt = obj(&q);
        for _ in 0..10 {
            let cand = crate::linalg::random_orthonormal(15, r, &mut rng);
            assert!(obj(&cand) >= opt - 1e-8);
        }
    }

    #[test]
    fn matches_svd_formulation() {
        // Q_k from the paper's SVD of H S_k Vᵀ X_kᵀ = P Σ Zᵀ, Q_k = Z Pᵀ.
        let mut rng = Pcg64::seed(112);
        let r = 3;
        let xk = random_sparse(&mut rng, 10, 8, 0.3);
        let v = Mat::rand_normal(8, r, &mut rng);
        let h = Mat::rand_normal(r, r, &mut rng);
        let s_k: Vec<f64> = (0..r).map(|_| rng.uniform(0.5, 2.0)).collect();

        let (_, q_polar) = procrustes_and_pack(&xk, &v, &h, &s_k, true);
        let q_polar = q_polar.unwrap();

        let hs = Mat::from_fn(r, r, |i, j| h[(i, j)] * s_k[j]);
        let hsvt = blas::matmul_a_bt(&hs, &v); // R × J
        let f = blas::matmul_a_bt(&hsvt, &xk.to_dense()); // R × I_k
        let (p, _s, z) = svd_thin(&f);
        let q_svd = blas::matmul_a_bt(&z, &p); // Z Pᵀ: I_k × R
        assert!(q_polar.max_abs_diff(&q_svd) < 1e-7);
    }

    #[test]
    fn compact_and_pack_matches_csr_and_pack_bitwise() {
        // The arena-backed per-subject path (coordinator fallback) against
        // the original CSR path: identical Y_k and Q_k bits, across
        // scratch-reusing calls with heterogeneous shapes.
        let mut rng = Pcg64::seed(119);
        let r = 4;
        let mut s = SubjectScratch::new();
        for round in 0..4 {
            let rows = 4 + rng.range(0, 12);
            let xk = random_sparse(&mut rng, rows, 11, 0.3);
            let cx = CompactSlice::pack(&xk);
            let v = Mat::rand_normal(11, r, &mut rng);
            let h = Mat::rand_normal(r, r, &mut rng);
            let s_k: Vec<f64> = (0..r).map(|_| rng.uniform(0.5, 2.0)).collect();
            let (p_csr, q_csr) = procrustes_and_pack(&xk, &v, &h, &s_k, true);
            let (p_cx, q_cx) = procrustes_and_pack_compact(&cx, &v, &h, &s_k, true, &mut s);
            assert!(bits_eq(&p_cx.yt, &p_csr.yt), "round {round}");
            assert!(bits_eq(&q_cx.unwrap(), &q_csr.unwrap()), "round {round}");
            assert_eq!(p_cx.support, p_csr.support, "round {round}");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Pcg64::seed(113);
        let r = 3;
        let slices: Vec<Csr> = (0..7)
            .map(|_| {
                let rows = 6 + rng.range(0, 5);
                random_sparse(&mut rng, rows, 9, 0.25)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let v = Mat::rand_normal(9, r, &mut rng);
        let h = Mat::rand_normal(r, r, &mut rng);
        let w = Mat::rand_uniform(7, r, &mut rng);

        let (y_ser, q_ser) = procrustes_all(&data, &v, &h, &w, &Pool::serial(), true);
        let (y_par, q_par) = procrustes_all(&data, &v, &h, &w, &Pool::new(4), true);
        assert_eq!(y_ser.k(), y_par.k());
        for k in 0..data.k() {
            assert!(y_ser.slices[k].yt.max_abs_diff(&y_par.slices[k].yt) < 1e-14);
            assert!(q_ser.as_ref().unwrap()[k].max_abs_diff(&q_par.as_ref().unwrap()[k]) < 1e-14);
        }
    }

    #[test]
    fn arena_repack_matches_fresh_pack_bitwise() {
        let mut rng = Pcg64::seed(115);
        let r = 3;
        let slices: Vec<Csr> = (0..5)
            .map(|_| {
                let rows = 5 + rng.range(0, 4);
                random_sparse(&mut rng, rows, 8, 0.3)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let mut y = crate::parafac2::intermediate::PackedY::empty(data.j());
        let pool = Pool::new(3);
        let plan = subject_plan(&data);
        let cx = CompactX::pack(&data, &pool, &plan);
        let mut scratch = SubjectScratch::for_plan(&plan);
        for round in 0..4 {
            let v = Mat::rand_normal(8, r, &mut rng);
            let h = Mat::rand_normal(r, r, &mut rng);
            let w = Mat::rand_uniform(5, r, &mut rng);
            let _ =
                procrustes_all_into(&cx, &v, &h, &w, &pool, &plan, false, &mut y, &mut scratch);
            let (fresh, _) = procrustes_all(&data, &v, &h, &w, &Pool::serial(), false);
            for k in 0..data.k() {
                assert_eq!(
                    y.slices[k].yt.data(),
                    fresh.slices[k].yt.data(),
                    "round {round} subject {k}"
                );
            }
        }
    }

    #[test]
    fn pack_fused_mode1_matches_separate_bitwise() {
        // THE tentpole regression guard: the pack-fused sweep must be
        // bitwise indistinguishable from "repack, then standalone mode-1
        // MTTKRP" — same arena contents, same M¹ bits — on the same plan,
        // for fixed and balanced (heavy-tailed ⇒ uneven) boundaries, on
        // serial and parallel pools, across arena-reusing rounds; and the
        // two-sweep separate-X reference must agree bitwise too.
        let mut rng = Pcg64::seed(116);
        let r = 3;
        let k = 70; // crosses the SUBJECT_CHUNK boundary
        let slices: Vec<Csr> = (0..k)
            .map(|kk| {
                // heavy tail: subject 0 holds ~half the cohort's nnz
                let (rows, dens) = if kk == 0 { (30, 0.9) } else { (4 + rng.range(0, 4), 0.08) };
                random_sparse(&mut rng, rows, 40, dens)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let balanced = subject_plan(&data);
        assert!(balanced.n_chunks() > 1);
        for plan in [ChunkPlan::fixed(k), balanced] {
            for workers in [1usize, 4] {
                let pool = Pool::new(workers);
                let cx = CompactX::pack(&data, &pool, &plan);
                let mut fused_scratch = SubjectScratch::for_plan(&plan);
                let mut sep_scratch = SubjectScratch::for_plan(&plan);
                let mut y_fused = PackedY::empty(data.j());
                let mut y_sep = PackedY::empty(data.j());
                let mut y_two = PackedY::empty(data.j());
                let mut rng2 = Pcg64::seed(991);
                for round in 0..3 {
                    let v = Mat::rand_normal(40, r, &mut rng2);
                    let h = Mat::rand_normal(r, r, &mut rng2);
                    let w = Mat::rand_uniform(k, r, &mut rng2);
                    let sweep = procrustes_pack_mode1(
                        &cx, &v, &h, &w, &pool, &plan, &mut y_fused, &mut fused_scratch,
                    );
                    let _ = procrustes_all_into(
                        &cx, &v, &h, &w, &pool, &plan, false, &mut y_sep, &mut sep_scratch,
                    );
                    let m1 = mttkrp::mttkrp_mode1(&y_sep, &v, &w, &pool, &plan);
                    assert_eq!(
                        sweep.m1.data(),
                        m1.data(),
                        "round {round}, {workers} workers"
                    );
                    assert_eq!(sweep.yv_products, k as u64);
                    procrustes_then_repack_separate(&cx, &v, &h, &w, &pool, &plan, &mut y_two);
                    for kk in 0..k {
                        assert_eq!(
                            y_fused.slices[kk].yt.data(),
                            y_sep.slices[kk].yt.data(),
                            "round {round} subject {kk}"
                        );
                        assert_eq!(
                            y_fused.slices[kk].yt.data(),
                            y_two.slices[kk].yt.data(),
                            "two-sweep reference, round {round} subject {kk}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn pack_fused_mode1_matches_csr_bitwise() {
        // The arena sweep against the pre-arena CSR-streaming sweep (the
        // xfuse ablation's two arms): identical M¹ and arena contents,
        // bit for bit — the arena changes where operands live, never the
        // arithmetic.
        let mut rng = Pcg64::seed(118);
        let r = 5;
        let k = 40;
        let slices: Vec<Csr> = (0..k)
            .map(|_| {
                let rows = 3 + rng.range(0, 9);
                random_sparse(&mut rng, rows, 25, 0.15)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let plan = subject_plan(&data);
        let pool = Pool::new(3);
        let cx = CompactX::pack(&data, &pool, &plan);
        let mut scratch = SubjectScratch::for_plan(&plan);
        let mut y_arena = PackedY::empty(data.j());
        let mut y_csr = PackedY::empty(data.j());
        let mut rng2 = Pcg64::seed(313);
        for round in 0..3 {
            let v = Mat::rand_normal(25, r, &mut rng2);
            let h = Mat::rand_normal(r, r, &mut rng2);
            let w = Mat::rand_uniform(k, r, &mut rng2);
            let a = procrustes_pack_mode1(
                &cx, &v, &h, &w, &pool, &plan, &mut y_arena, &mut scratch,
            );
            let b = procrustes_pack_mode1_csr(&data, &v, &h, &w, &pool, &plan, &mut y_csr);
            assert!(bits_eq(&a.m1, &b.m1), "round {round}");
            assert_eq!(a.yv_products, b.yv_products);
            for kk in 0..k {
                assert!(
                    bits_eq(&y_arena.slices[kk].yt, &y_csr.slices[kk].yt),
                    "round {round} subject {kk}"
                );
            }
        }
    }

    #[test]
    fn subject_plan_balances_heavy_cohort() {
        let mut rng = Pcg64::seed(117);
        // subject 0 carries well over half the nnz of the cohort
        let slices: Vec<Csr> = (0..80)
            .map(|kk| {
                let (rows, dens) = if kk == 0 { (60, 0.95) } else { (12, 0.02) };
                random_sparse(&mut rng, rows, 120, dens)
            })
            .collect();
        let data = IrregularTensor::new(slices);
        let plan = subject_plan(&data);
        assert!(plan.covers(80));
        // the heavy subject's chunk closes right after it
        let heavy = plan.ranges().iter().find(|r| r.contains(&0)).unwrap();
        assert_eq!(heavy.clone(), 0..1, "heavy chunk {heavy:?}");
        assert_ne!(plan, ChunkPlan::fixed(80));
    }

    #[test]
    fn short_slice_ik_below_rank() {
        // I_k < R must not panic and must give orthonormal *rows*.
        let mut rng = Pcg64::seed(114);
        let r = 5;
        let xk = random_sparse(&mut rng, 3, 10, 0.5);
        let v = Mat::rand_normal(10, r, &mut rng);
        let h = Mat::rand_normal(r, r, &mut rng);
        let s_k = vec![1.0; r];
        let (_, q) = procrustes_and_pack(&xk, &v, &h, &s_k, true);
        let q = q.unwrap();
        let qqt = blas::matmul_a_bt(&q, &q);
        assert!(qqt.max_abs_diff(&Mat::eye(3)) < 1e-7);
    }
}
