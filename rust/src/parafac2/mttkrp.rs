//! SPARTan's specialized MTTKRP — the paper's core contribution
//! (Algorithm 3, Figures 2–4).
//!
//! All three modes operate directly on the packed frontal slices
//! `{Y_k}` — the tensor `Y` is never materialized, no Khatri-Rao product
//! is ever formed, and each mode is parallelized over the K subjects:
//!
//! * **mode 1** (Eq. 10):  `M¹ = Σ_k rowhad(Y_k V, W(k,:))`
//! * **mode 2** (Eq. 13):  `M²(j,:) += (Y_k(:,j)ᵀ H) ∗ W(k,:)` for each
//!   nonzero column j of `Y_k`
//! * **mode 3** (Eq. 16):  `M³(k,:) = dot(H, Y_k V)` (column-wise inner
//!   products of two R×R matrices)
//!
//! Everything uses only the support rows of `V` ("we use only the rows of
//! V factor matrix corresponding to the non-zero columns of Y_k",
//! Fig. 2), so per-subject cost is `O(R·(R + c_k))` independent of J.

use super::intermediate::PackedY;
use crate::linalg::{blas, Mat};
use crate::threadpool::{partition::SUBJECT_CHUNK, Pool};

/// Mode-1 MTTKRP: `M¹ = Y_(1) (W ⊙ V) ∈ R^{R×R}`.
///
/// Per subject: `temp = Y_k V_c` (R×R), then Hadamard each row of `temp`
/// with `W(k,:)` and accumulate. Partial sums are merged in chunk order
/// (deterministic).
pub fn mttkrp_mode1(y: &PackedY, v: &Mat, w: &Mat, pool: &Pool) -> Mat {
    let k = y.k();
    let r = w.cols();
    assert_eq!(v.rows(), y.j_dim, "V rows must equal J");
    assert_eq!(w.rows(), k, "W rows must equal K");
    let chunk = SUBJECT_CHUNK;
    pool.par_fold(
        k,
        chunk,
        |range| {
            let mut acc = Mat::zeros(r, r);
            for kk in range {
                let slice = &y.slices[kk];
                let mut temp = slice.yk_times_v(v); // R×R, support rows only
                let wk = w.row(kk);
                blas::rowhad_inplace(&mut temp, wk); // temp(r,:) *= W(k,:)
                acc.axpy(1.0, &temp);
            }
            acc
        },
        |mut a, b| {
            a.axpy(1.0, &b);
            a
        },
    )
    .unwrap_or_else(|| Mat::zeros(r, r))
}

/// Mode-2 MTTKRP: `M² = Y_(2) (W ⊙ H) ∈ R^{J×R}`.
///
/// Per subject, only the `c_k` nonzero columns of `Y_k` produce nonzero
/// rows of the partial result; each is `(Y_k(:,j)ᵀ H) ∗ W(k,:)` scattered
/// to row j. Each chunk accumulates into a transient dense J×R buffer and
/// hands back only the *touched rows* (the union of its subjects' column
/// supports), so held memory stays proportional to `nnz(Y)` and the merge
/// — done in chunk order — is deterministic across worker counts.
pub fn mttkrp_mode2(y: &PackedY, h: &Mat, w: &Mat, pool: &Pool) -> Mat {
    let k = y.k();
    let r = w.cols();
    let j_dim = y.j_dim;
    assert_eq!(h.rows(), r, "H must be R×R");
    assert_eq!(w.rows(), k, "W rows must equal K");
    let chunk = SUBJECT_CHUNK;
    // Per chunk: (touched column ids, their accumulated rows, row-major r).
    let partials = pool.par_chunk_results(k, chunk, |range| {
        let mut acc = Mat::zeros(j_dim, r);
        let mut touched = vec![false; j_dim];
        let mut row_buf = vec![0.0f64; r];
        for kk in range {
            let slice = &y.slices[kk];
            let wk = w.row(kk);
            for (c, &j) in slice.support.iter().enumerate() {
                // row = (Y_k(:, j)ᵀ · H) ∗ W(k,:)
                let yrow = slice.yt.row(c); // = Y_k(:, j)ᵀ, length R
                row_buf.fill(0.0);
                for (i, &yv) in yrow.iter().enumerate() {
                    if yv == 0.0 {
                        continue;
                    }
                    let hrow = h.row(i);
                    for (b, &hv) in row_buf.iter_mut().zip(hrow) {
                        *b += yv * hv;
                    }
                }
                touched[j as usize] = true;
                let arow = acc.row_mut(j as usize);
                for ((a, &b), &wv) in arow.iter_mut().zip(&row_buf).zip(wk) {
                    *a += b * wv;
                }
            }
        }
        // compact: only touched rows survive the chunk
        let ids: Vec<u32> = (0..j_dim as u32).filter(|&j| touched[j as usize]).collect();
        let mut vals = Vec::with_capacity(ids.len() * r);
        for &j in &ids {
            vals.extend_from_slice(acc.row(j as usize));
        }
        (ids, vals)
    });
    let mut m = Mat::zeros(j_dim, r);
    for (ids, vals) in partials {
        for (t, &j) in ids.iter().enumerate() {
            let mrow = m.row_mut(j as usize);
            for (mv, &pv) in mrow.iter_mut().zip(&vals[t * r..(t + 1) * r]) {
                *mv += pv;
            }
        }
    }
    m
}

/// Mode-3 MTTKRP: `M³ = Y_(3) (V ⊙ H) ∈ R^{K×R}`.
///
/// Row k of the result is computed independently as the column-wise inner
/// products of `H` and `Y_k V` (both R×R): "it is efficient to delay any
/// computations on H until the R-by-R product of Y_k V is formed"
/// (paper Fig. 4).
pub fn mttkrp_mode3(y: &PackedY, h: &Mat, v: &Mat, pool: &Pool) -> Mat {
    let k = y.k();
    let r = h.cols();
    assert_eq!(v.rows(), y.j_dim, "V rows must equal J");
    let chunk = SUBJECT_CHUNK;
    let rows = pool.par_chunk_results(k, chunk, |range| {
        let mut out = Mat::zeros(range.len(), r);
        for (local, kk) in range.enumerate() {
            let slice = &y.slices[kk];
            let p = slice.yk_times_v(v); // R×R
            let orow = out.row_mut(local);
            for i in 0..r {
                let hrow = h.row(i);
                let prow = p.row(i);
                for ((o, &hv), &pv) in orow.iter_mut().zip(hrow).zip(prow) {
                    *o += hv * pv; // Σ_i H(i,r)·P(i,r) accumulated per column r
                }
            }
        }
        out
    });
    let mut m = Mat::zeros(k, r);
    let mut at = 0usize;
    for block in rows {
        for i in 0..block.rows() {
            m.row_mut(at).copy_from_slice(block.row(i));
            at += 1;
        }
    }
    m
}

/// Reference MTTKRP by explicit matricization + Khatri-Rao materialization
/// (Eqs. 7/11/14 verbatim). Exponential memory in J·K — tests only.
pub mod reference {
    use super::*;

    /// Dense frontal slices of Y from the packed representation.
    fn dense_slices(y: &PackedY) -> Vec<Mat> {
        y.slices.iter().map(|s| s.to_dense(y.j_dim)).collect()
    }

    pub fn mttkrp_dense(y: &PackedY, mode: usize, h: &Mat, v: &Mat, w: &Mat) -> Mat {
        let slices = dense_slices(y);
        let k = slices.len();
        let r = h.cols();
        let j = y.j_dim;
        match mode {
            0 => {
                // Y_(1) (W ⊙ V): Y_(1) = [Y_1 | Y_2 | ... ] (R × KJ)
                let krp = blas::khatri_rao(w, v); // KJ × R
                let mut m = Mat::zeros(r, r);
                for (kk, yk) in slices.iter().enumerate() {
                    let tkv = krp.block(kk * j, (kk + 1) * j, 0, r);
                    m.axpy(1.0, &blas::matmul(yk, &tkv));
                }
                m
            }
            1 => {
                // Y_(2) (W ⊙ H): Y_(2) = [Y_1ᵀ | Y_2ᵀ | ...] (J × RK)
                let krp = blas::khatri_rao(w, h); // KR × R
                let mut m = Mat::zeros(j, r);
                for (kk, yk) in slices.iter().enumerate() {
                    let tkh = krp.block(kk * r, (kk + 1) * r, 0, r);
                    m.axpy(1.0, &blas::matmul(&yk.transpose(), &tkh));
                }
                m
            }
            2 => {
                // M³(k, r) = H(:,r)ᵀ Y_k V(:,r)  (Eq. 15)
                let mut m = Mat::zeros(k, r);
                for (kk, yk) in slices.iter().enumerate() {
                    let p = blas::matmul(yk, v); // R × R
                    for c in 0..r {
                        let mut s = 0.0;
                        for i in 0..r {
                            s += h[(i, c)] * p[(i, c)];
                        }
                        m[(kk, c)] = s;
                    }
                }
                m
            }
            _ => panic!("mode must be 0..3"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parafac2::intermediate::PackedSlice;
    use crate::sparse::Csr;
    use crate::util::rng::Pcg64;

    fn random_packed(rng: &mut Pcg64, k: usize, j: usize, r: usize) -> PackedY {
        let slices = (0..k)
            .map(|_| {
                let rows = rng.range(r.max(2), r.max(2) + 6);
                let mut trips = vec![(0usize, rng.range(0, j), 1.0)];
                for i in 0..rows {
                    for jj in 0..j {
                        if rng.chance(0.15) {
                            trips.push((i, jj, rng.normal()));
                        }
                    }
                }
                let xk = Csr::from_triplets(rows, j, trips);
                let qk = crate::linalg::random_orthonormal(rows, r, rng);
                PackedSlice::pack(&xk, &qk)
            })
            .collect();
        PackedY { slices, j_dim: j }
    }

    #[test]
    fn all_modes_match_reference() {
        let mut rng = Pcg64::seed(121);
        for &(k, j, r) in &[(1usize, 5usize, 2usize), (6, 10, 3), (12, 7, 4)] {
            let y = random_packed(&mut rng, k, j, r);
            let h = Mat::rand_normal(r, r, &mut rng);
            let v = Mat::rand_normal(j, r, &mut rng);
            let w = Mat::rand_normal(k, r, &mut rng);
            let pool = Pool::new(3);

            let m1 = mttkrp_mode1(&y, &v, &w, &pool);
            let m2 = mttkrp_mode2(&y, &h, &w, &pool);
            let m3 = mttkrp_mode3(&y, &h, &v, &pool);

            let r1 = reference::mttkrp_dense(&y, 0, &h, &v, &w);
            let r2 = reference::mttkrp_dense(&y, 1, &h, &v, &w);
            let r3 = reference::mttkrp_dense(&y, 2, &h, &v, &w);

            assert!(m1.max_abs_diff(&r1) < 1e-9, "mode1 ({k},{j},{r})");
            assert!(m2.max_abs_diff(&r2) < 1e-9, "mode2 ({k},{j},{r})");
            assert!(m3.max_abs_diff(&r3) < 1e-9, "mode3 ({k},{j},{r})");
        }
    }

    #[test]
    fn serial_equals_parallel_bitwise() {
        let mut rng = Pcg64::seed(122);
        let y = random_packed(&mut rng, 9, 8, 3);
        let h = Mat::rand_normal(3, 3, &mut rng);
        let v = Mat::rand_normal(8, 3, &mut rng);
        let w = Mat::rand_normal(9, 3, &mut rng);
        let ser = Pool::serial();
        let par = Pool::new(4);
        // chunk-ordered reduction ⇒ identical floating point results
        assert_eq!(
            mttkrp_mode1(&y, &v, &w, &ser).data(),
            mttkrp_mode1(&y, &v, &w, &par).data()
        );
        assert_eq!(
            mttkrp_mode3(&y, &h, &v, &ser).data(),
            mttkrp_mode3(&y, &h, &v, &par).data()
        );
    }

    #[test]
    fn mode2_rows_outside_support_are_zero() {
        let mut rng = Pcg64::seed(123);
        let r = 3;
        let j = 20;
        // single slice touching only columns {4, 9}
        let xk = Csr::from_triplets(5, j, vec![(0, 4, 1.0), (3, 9, 2.0), (4, 4, -1.0)]);
        let qk = crate::linalg::random_orthonormal(5, r, &mut rng);
        let y = PackedY { slices: vec![PackedSlice::pack(&xk, &qk)], j_dim: j };
        let h = Mat::rand_normal(r, r, &mut rng);
        let w = Mat::rand_normal(1, r, &mut rng);
        let m2 = mttkrp_mode2(&y, &h, &w, &Pool::serial());
        for jj in 0..j {
            let nz = m2.row(jj).iter().any(|&x| x != 0.0);
            assert_eq!(nz, jj == 4 || jj == 9, "row {jj}");
        }
    }

    #[test]
    fn zero_rank_edge() {
        // smallest sane case R=1
        let mut rng = Pcg64::seed(124);
        let y = random_packed(&mut rng, 3, 4, 1);
        let h = Mat::rand_normal(1, 1, &mut rng);
        let v = Mat::rand_normal(4, 1, &mut rng);
        let w = Mat::rand_normal(3, 1, &mut rng);
        let pool = Pool::serial();
        let m1 = mttkrp_mode1(&y, &v, &w, &pool);
        let want = reference::mttkrp_dense(&y, 0, &h, &v, &w);
        assert!(m1.max_abs_diff(&want) < 1e-10);
    }
}
