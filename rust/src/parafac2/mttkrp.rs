//! SPARTan's specialized MTTKRP — the paper's core contribution
//! (Algorithm 3, Figures 2–4) — restructured as a **fused per-subject
//! sweep** so each CP iteration traverses the packed slices the minimum
//! number of times.
//!
//! All three modes operate directly on the packed frontal slices
//! `{Y_k}` — the tensor `Y` is never materialized, no Khatri-Rao product
//! is ever formed, and each mode is parallelized over the K subjects:
//!
//! * **mode 1** (Eq. 10):  `M¹ = Σ_k rowhad(Y_k V, W(k,:))`
//! * **mode 2** (Eq. 13):  `M²(j,:) += (Y_k(:,j)ᵀ H) ∗ W(k,:)` for each
//!   nonzero column j of `Y_k`
//! * **mode 3** (Eq. 16):  `M³(k,:) = dot(H, Y_k V)` — algebraically
//!   equal to `Σ_{j ∈ supp_k} Z_k(j,:) ∗ V(j,:)` with `Z_k = Y_kᵀ H`,
//!   which is the form used here (see below)
//!
//! ## The fused sweep
//!
//! A CP iteration updates `H` (needs mode 1 with the *old* `V`), then `V`
//! (needs mode 2 with the *new* `H`), then `W` (needs mode 3 with the
//! *new* `H` **and** `V`). Because mode 3 must see the post-update `V`,
//! its `Y_k V` product cannot share mode 1's `P_k = Y_k V_old` without
//! breaking the residual identity `⟨Y, rec⟩ = ⟨M³, W⟩` the convergence
//! tracking relies on. Instead the sweep reuses the **mode-2**
//! intermediate: the rows `(Y_k(:,j)ᵀ H)` that mode 2 scatters are
//! exactly the rows of `Z_k = Y_kᵀ H`, and
//! `M³(k,:) = Σ_{j ∈ supp_k} Z_k(j,:) ∗ V(j,:)`. Caching `Z_k` per
//! subject (in [`FusedScratch`], `nnz(Y)`-proportional, buffers reused
//! across iterations) turns mode 3 into an `O(c_k·R)` epilogue with **no
//! traversal of `Y` at all**.
//!
//! Mode 1, in turn, is fused into the **Procrustes pack** itself
//! (DPar2-style, see [`super::procrustes::procrustes_pack_mode1`]): the
//! `P_k = Y_k V` product is emitted while `Y_k` is still cache-resident
//! from being packed, so the ALS iteration performs exactly **one** cold
//! traversal of the packed slices per subject — the mode-2 sweep — and
//! the hottest kernel `Y_k·V` runs **exactly once per subject**. Both
//! invariants are counted per slice and asserted in `metrics::flops`
//! ([`super::intermediate::PackedY::yv_products`] /
//! [`super::intermediate::PackedY::traversals`]). The standalone
//! [`mttkrp_mode1`] below remains as the unfused reference (and for
//! callers without a pack to fuse into, e.g. the PJRT fallback path).
//!
//! Everything uses only the support rows of `V` ("we use only the rows of
//! V factor matrix corresponding to the non-zero columns of Y_k",
//! Fig. 2), so per-subject cost is `O(R·(R + c_k))` independent of J.
//!
//! ## Empty inputs
//!
//! All three modes share one convention: shapes derive from the factor
//! arguments, never from the slices, so `K = 0` (and slices with empty
//! support) are well-defined and return all-zero results of the
//! documented shape — mode 1: `R×R` with `R = v.cols()`; mode 2: `J×R`
//! with `R = h.cols()`; mode 3: `K×R` with `R = h.cols()`.
//!
//! ## Determinism
//!
//! Per-chunk partials are merged in chunk order over the frozen,
//! data-dependent boundaries of the caller's [`ChunkPlan`] (nnz-balanced
//! in the ALS driver), so every result is bitwise identical across worker
//! counts, and the cached (fused) and standalone kernels share their
//! inner loops, so they are bitwise identical to each other.

use super::intermediate::PackedY;
use crate::linalg::{blas, kernels, Mat};
use crate::threadpool::{ChunkPlan, Pool};
use std::ops::Range;

/// Per-subject intermediates cached across the fused sweep (and across
/// iterations — buffers are reused when shapes are unchanged).
/// `z[k] = Y_kᵀ H` restricted to the support: shape `c_k × R`. Holding it
/// costs exactly one extra copy of the packed `nnz(Y)`, keeping the
/// module's memory proportional to `nnz(Y)`.
#[derive(Debug, Default)]
pub struct FusedScratch {
    z: Vec<Mat>,
}

impl FusedScratch {
    pub fn new() -> FusedScratch {
        FusedScratch { z: Vec::new() }
    }

    /// Size `z` for `y` at rank `r`, reusing buffers whose shape already
    /// matches.
    fn ensure(&mut self, y: &PackedY, r: usize) {
        if self.z.len() != y.k() {
            self.z = y.slices.iter().map(|s| Mat::zeros(s.c_k(), r)).collect();
            return;
        }
        for (z, s) in self.z.iter_mut().zip(&y.slices) {
            if z.shape() != (s.c_k(), r) {
                *z = Mat::zeros(s.c_k(), r);
            }
        }
    }

    /// Heap bytes held by the cache (memory reports).
    pub fn heap_bytes(&self) -> u64 {
        self.z.iter().map(|m| (m.data().len() * 8) as u64).sum()
    }
}

/// `out = yrow · H` where `yrow = Y_k(:, j)ᵀ` (length R) — the shape-B
/// register-blocked micro-kernel ([`kernels::zt_row`]: 4 coefficient/row
/// pairs in flight, R-unrolled panel). Bitwise identical to the scalar
/// reference, so the floating-point sequence shared by the standalone and
/// fused paths is unchanged; exact zeros are skipped exactly as the
/// pre-blocking kernel did.
#[inline]
fn yt_row_times_h(yrow: &[f64], h: &Mat, out: &mut [f64]) {
    kernels::zt_row(yrow, h, out);
}

/// `out = Σ_{c} z(c,:) ∗ v(support[c],:)` — the mode-3 row epilogue.
#[inline]
fn mode3_row_from_z(z: &Mat, support: &[u32], v: &Mat, out: &mut [f64]) {
    out.fill(0.0);
    for (c, &j) in support.iter().enumerate() {
        let zrow = z.row(c);
        let vrow = v.row(j as usize);
        for ((o, &zv), &vv) in out.iter_mut().zip(zrow).zip(vrow) {
            *o += zv * vv;
        }
    }
}

/// Mode-1 MTTKRP: `M¹ = Y_(1) (W ⊙ V) ∈ R^{R×R}`.
///
/// Per subject: `P_k = Y_k V_c` (R×R), then Hadamard each row with
/// `W(k,:)` and accumulate. Partial sums merge in the plan's chunk order
/// (deterministic). This is the **standalone** (cold-traversal) form; the
/// ALS loop uses the pack-fused
/// [`super::procrustes::procrustes_pack_mode1`] instead, which is bitwise
/// identical on the same plan.
pub fn mttkrp_mode1(y: &PackedY, v: &Mat, w: &Mat, pool: &Pool, plan: &ChunkPlan) -> Mat {
    mttkrp_mode1_counted(y, v, w, pool, plan).0
}

/// [`mttkrp_mode1`] also reporting how many `Y_k·V` products it performed
/// (one per subject — the count the fused-sweep FLOP assertion checks).
pub fn mttkrp_mode1_counted(
    y: &PackedY,
    v: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
) -> (Mat, u64) {
    let k = y.k();
    let r = v.cols();
    assert_eq!(v.rows(), y.j_dim, "V rows must equal J");
    assert_eq!(w.rows(), k, "W rows must equal K");
    assert_eq!(w.cols(), r, "W/V rank mismatch");
    assert!(plan.covers(k), "chunk plan does not cover the K subjects");
    pool.par_plan_fold(
        plan,
        |range| {
            let mut acc = Mat::zeros(r, r);
            let mut yv_products = 0u64;
            for kk in range {
                let slice = &y.slices[kk];
                let mut temp = slice.yk_times_v(v); // R×R, support rows only
                yv_products += 1;
                let wk = w.row(kk);
                blas::rowhad_inplace(&mut temp, wk); // temp(r,:) *= W(k,:)
                acc.axpy(1.0, &temp);
            }
            (acc, yv_products)
        },
        |(mut a, na), (b, nb)| {
            a.axpy(1.0, &b);
            (a, na + nb)
        },
    )
    .unwrap_or_else(|| (Mat::zeros(r, r), 0))
}

/// One chunk of the mode-2 sweep: accumulate into rows indexed by the
/// sorted **union of the chunk's column supports** (never a dense `J×R`
/// buffer — held memory stays proportional to the chunk's `nnz(Y)` even
/// for very large J). When `z_chunk` is given (fused path), the per-row
/// products `Z_k(c,:) = Y_k(:,j_c)ᵀ H` are written into the cache for the
/// mode-3 epilogue; the arithmetic sequence is identical either way.
fn mode2_chunk(
    y: &PackedY,
    h: &Mat,
    w: &Mat,
    range: Range<usize>,
    mut z_chunk: Option<&mut [Mat]>,
) -> (Vec<u32>, Vec<f64>) {
    let r = h.cols();
    let mut ids: Vec<u32> = Vec::new();
    for kk in range.clone() {
        ids.extend_from_slice(&y.slices[kk].support);
    }
    ids.sort_unstable();
    ids.dedup();
    let mut acc = Mat::zeros(ids.len(), r);
    let mut row_buf = vec![0.0f64; r];
    for (local_k, kk) in range.enumerate() {
        let slice = &y.slices[kk];
        slice.note_traversal(); // one cold pass over this slice's yt rows
        let wk = w.row(kk);
        let mut z = z_chunk.as_deref_mut().map(|zs| &mut zs[local_k]);
        debug_assert!(z.as_ref().map_or(true, |zm| zm.shape() == (slice.c_k(), r)));
        for (c, &j) in slice.support.iter().enumerate() {
            // One loop for both paths: the only difference is whether the
            // Z row lands in the cache (fused) or a transient buffer —
            // keeping a single copy of the scatter preserves the
            // documented bitwise identity between the two by construction.
            let row: &mut [f64] = match z.as_deref_mut() {
                Some(zm) => zm.row_mut(c),
                None => &mut row_buf,
            };
            yt_row_times_h(slice.yt.row(c), h, row);
            let local = ids.binary_search(&j).expect("support id in union");
            let arow = acc.row_mut(local);
            for ((a, &b), &wv) in arow.iter_mut().zip(&*row).zip(wk) {
                *a += b * wv;
            }
        }
    }
    let mut vals = Vec::with_capacity(ids.len() * r);
    for t in 0..ids.len() {
        vals.extend_from_slice(acc.row(t));
    }
    (ids, vals)
}

/// Scatter-add the per-chunk `(support ids, row-major vals)` partials into
/// a dense `J×R` result, in partial (= plan chunk) order. `pub(crate)`
/// because the sharded coordinator replays this exact scatter over the
/// wire-shipped per-chunk partials, concatenated in global chunk order.
pub(crate) fn mode2_merge(j_dim: usize, r: usize, partials: Vec<(Vec<u32>, Vec<f64>)>) -> Mat {
    let mut m = Mat::zeros(j_dim, r);
    for (ids, vals) in partials {
        for (t, &j) in ids.iter().enumerate() {
            let mrow = m.row_mut(j as usize);
            for (mv, &pv) in mrow.iter_mut().zip(&vals[t * r..(t + 1) * r]) {
                *mv += pv;
            }
        }
    }
    m
}

/// Mode-2 MTTKRP: `M² = Y_(2) (W ⊙ H) ∈ R^{J×R}`.
///
/// Per subject, only the `c_k` nonzero columns of `Y_k` produce nonzero
/// rows of the partial result; each chunk accumulates over the union of
/// its subjects' supports and the chunk partials merge in chunk order
/// (deterministic across worker counts).
pub fn mttkrp_mode2(y: &PackedY, h: &Mat, w: &Mat, pool: &Pool, plan: &ChunkPlan) -> Mat {
    let r = check_mode2_shapes(y, h, w, plan);
    let partials = pool.par_plan_results(plan, |range| mode2_chunk(y, h, w, range, None));
    mode2_merge(y.j_dim, r, partials)
}

/// Fused-sweep mode 2: identical result to [`mttkrp_mode2`] (bitwise),
/// additionally filling `scratch` with `Z_k = Y_kᵀ H` for
/// [`mttkrp_mode3_from_cache`].
pub fn mttkrp_mode2_cached(
    y: &PackedY,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    scratch: &mut FusedScratch,
) -> Mat {
    let r = check_mode2_shapes(y, h, w, plan);
    let partials = mttkrp_mode2_partials_cached(y, h, w, pool, plan, scratch);
    mode2_merge(y.j_dim, r, partials)
}

/// The per-chunk half of [`mttkrp_mode2_cached`]: run the fused sweep
/// (filling the `Z_k` cache) and return the **unmerged** per-chunk
/// `(support ids, vals)` partials in plan chunk order — support ids stay
/// in the global `0..J` space, so a shard's partials scatter directly
/// into the coordinator's `J×R` accumulator via [`mode2_merge`].
pub(crate) fn mttkrp_mode2_partials_cached(
    y: &PackedY,
    h: &Mat,
    w: &Mat,
    pool: &Pool,
    plan: &ChunkPlan,
    scratch: &mut FusedScratch,
) -> Vec<(Vec<u32>, Vec<f64>)> {
    let r = check_mode2_shapes(y, h, w, plan);
    scratch.ensure(y, r);
    pool.par_plan_chunks_mut(&mut scratch.z, plan, |start, sub| {
        mode2_chunk(y, h, w, start..start + sub.len(), Some(sub))
    })
}

fn check_mode2_shapes(y: &PackedY, h: &Mat, w: &Mat, plan: &ChunkPlan) -> usize {
    let r = h.cols();
    assert_eq!(h.rows(), r, "H must be R×R");
    assert_eq!(w.rows(), y.k(), "W rows must equal K");
    assert_eq!(w.cols(), r, "W/H rank mismatch");
    assert!(plan.covers(y.k()), "chunk plan does not cover the K subjects");
    r
}

/// Mode-3 MTTKRP: `M³ = Y_(3) (V ⊙ H) ∈ R^{K×R}`.
///
/// Row k is `Σ_{j ∈ supp_k} (Y_k(:,j)ᵀ H) ∗ V(j,:)` — the same
/// "delay computations on H until an R-by-R-sized product exists" trick
/// as the paper's Fig. 4, expressed through `Z_k = Y_kᵀ H` so the fused
/// path can reuse mode 2's intermediate. Bitwise identical to
/// [`mttkrp_mode3_from_cache`] on the same inputs.
pub fn mttkrp_mode3(y: &PackedY, h: &Mat, v: &Mat, pool: &Pool, plan: &ChunkPlan) -> Mat {
    let k = y.k();
    let r = h.cols();
    assert_eq!(h.rows(), r, "H must be R×R");
    assert_eq!(v.rows(), y.j_dim, "V rows must equal J");
    assert_eq!(v.cols(), r, "V/H rank mismatch");
    assert!(plan.covers(k), "chunk plan does not cover the K subjects");
    let rows = pool.par_plan_results(plan, |range| {
        let mut out = Mat::zeros(range.len(), r);
        let mut row_buf = vec![0.0f64; r];
        for (local, kk) in range.enumerate() {
            let slice = &y.slices[kk];
            slice.note_traversal(); // standalone mode 3 streams yt again
            let orow = out.row_mut(local);
            // Interleaved: compute each Z_k row into a reused R-length
            // buffer and accumulate immediately — same c-then-column
            // floating-point order as the cached epilogue (bitwise
            // identical), without materializing a c_k×R temporary.
            for (c, &j) in slice.support.iter().enumerate() {
                yt_row_times_h(slice.yt.row(c), h, &mut row_buf);
                let vrow = v.row(j as usize);
                for ((o, &zv), &vv) in orow.iter_mut().zip(&row_buf).zip(vrow) {
                    *o += zv * vv;
                }
            }
        }
        out
    });
    assemble_rows(k, r, rows)
}

/// Fused-sweep mode 3: the epilogue over the cached `Z_k = Y_kᵀ H` from
/// [`mttkrp_mode2_cached`]. `O(c_k·R)` per subject, no traversal of `Y`,
/// no `Y_k·V` product. `v` must be the (post-update) `V` factor.
pub fn mttkrp_mode3_from_cache(
    y: &PackedY,
    v: &Mat,
    scratch: &FusedScratch,
    pool: &Pool,
    plan: &ChunkPlan,
) -> Mat {
    let k = y.k();
    let r = v.cols();
    assert_eq!(v.rows(), y.j_dim, "V rows must equal J");
    assert_eq!(scratch.z.len(), k, "scratch must be filled by mttkrp_mode2_cached");
    assert!(plan.covers(k), "chunk plan does not cover the K subjects");
    let rows = pool.par_plan_results(plan, |range| {
        let mut out = Mat::zeros(range.len(), r);
        for (local, kk) in range.enumerate() {
            let slice = &y.slices[kk];
            let z = &scratch.z[kk];
            debug_assert_eq!(z.shape(), (slice.c_k(), r));
            mode3_row_from_z(z, &slice.support, v, out.row_mut(local));
        }
        out
    });
    assemble_rows(k, r, rows)
}

fn assemble_rows(k: usize, r: usize, blocks: Vec<Mat>) -> Mat {
    let mut m = Mat::zeros(k, r);
    let mut at = 0usize;
    for block in blocks {
        for i in 0..block.rows() {
            m.row_mut(at).copy_from_slice(block.row(i));
            at += 1;
        }
    }
    m
}

/// Reference MTTKRP by explicit matricization + Khatri-Rao materialization
/// (Eqs. 7/11/14 verbatim). Exponential memory in J·K — tests only.
pub mod reference {
    use super::*;

    /// Dense frontal slices of Y from the packed representation.
    fn dense_slices(y: &PackedY) -> Vec<Mat> {
        y.slices.iter().map(|s| s.to_dense(y.j_dim)).collect()
    }

    pub fn mttkrp_dense(y: &PackedY, mode: usize, h: &Mat, v: &Mat, w: &Mat) -> Mat {
        let slices = dense_slices(y);
        let k = slices.len();
        let r = h.cols();
        let j = y.j_dim;
        match mode {
            0 => {
                // Y_(1) (W ⊙ V): Y_(1) = [Y_1 | Y_2 | ... ] (R × KJ)
                let krp = blas::khatri_rao(w, v); // KJ × R
                let mut m = Mat::zeros(r, r);
                for (kk, yk) in slices.iter().enumerate() {
                    let tkv = krp.block(kk * j, (kk + 1) * j, 0, r);
                    m.axpy(1.0, &blas::matmul(yk, &tkv));
                }
                m
            }
            1 => {
                // Y_(2) (W ⊙ H): Y_(2) = [Y_1ᵀ | Y_2ᵀ | ...] (J × RK)
                let krp = blas::khatri_rao(w, h); // KR × R
                let mut m = Mat::zeros(j, r);
                for (kk, yk) in slices.iter().enumerate() {
                    let tkh = krp.block(kk * r, (kk + 1) * r, 0, r);
                    m.axpy(1.0, &blas::matmul(&yk.transpose(), &tkh));
                }
                m
            }
            2 => {
                // M³(k, r) = H(:,r)ᵀ Y_k V(:,r)  (Eq. 15)
                let mut m = Mat::zeros(k, r);
                for (kk, yk) in slices.iter().enumerate() {
                    let p = blas::matmul(yk, v); // R × R
                    for c in 0..r {
                        let mut s = 0.0;
                        for i in 0..r {
                            s += h[(i, c)] * p[(i, c)];
                        }
                        m[(kk, c)] = s;
                    }
                }
                m
            }
            _ => panic!("mode must be 0..3"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parafac2::intermediate::PackedSlice;
    use crate::sparse::Csr;
    use crate::threadpool::partition::SUBJECT_CHUNK;
    use crate::util::rng::Pcg64;

    fn random_packed(rng: &mut Pcg64, k: usize, j: usize, r: usize) -> PackedY {
        let slices = (0..k)
            .map(|_| {
                let rows = rng.range(r.max(2), r.max(2) + 6);
                let mut trips = vec![(0usize, rng.range(0, j), 1.0)];
                for i in 0..rows {
                    for jj in 0..j {
                        if rng.chance(0.15) {
                            trips.push((i, jj, rng.normal()));
                        }
                    }
                }
                let xk = Csr::from_triplets(rows, j, trips);
                let qk = crate::linalg::random_orthonormal(rows, r, rng);
                PackedSlice::pack(&xk, &qk)
            })
            .collect();
        PackedY { slices, j_dim: j }
    }

    /// A heavy-tailed cohort: subject 0 alone holds ≈ half the packed nnz
    /// (the COPA-motivated EHR shape — packed weight is `c_k·R`, so the
    /// heavy subject touches ~J/2 columns while the rest touch a handful),
    /// making a balanced plan produce genuinely uneven chunk boundaries.
    /// Needs a wide column space (`j ≳ 10·k`) to concentrate the weight.
    fn heavy_tailed_packed(rng: &mut Pcg64, k: usize, j: usize, r: usize) -> PackedY {
        let slices = (0..k)
            .map(|kk| {
                let rows = r.max(2) + rng.range(0, 4);
                let ncols = if kk == 0 { j / 2 } else { 1 + rng.range(0, 3) };
                let mut trips = vec![(0usize, rng.range(0, j), 1.0)];
                for _ in 0..ncols {
                    let col = rng.range(0, j);
                    for i in 0..rows {
                        if rng.chance(0.7) {
                            trips.push((i, col, rng.normal()));
                        }
                    }
                }
                let xk = Csr::from_triplets(rows, j, trips);
                let qk = crate::linalg::random_orthonormal(rows, r, rng);
                PackedSlice::pack(&xk, &qk)
            })
            .collect();
        PackedY { slices, j_dim: j }
    }

    /// Packed-nnz weights of a tensor (what the ALS driver keys its
    /// balanced plan on, up to the constant R factor).
    fn packed_weights(y: &PackedY) -> Vec<u64> {
        y.slices.iter().map(|s| (s.c_k() * s.rank()) as u64).collect()
    }

    #[test]
    fn all_modes_match_reference() {
        let mut rng = Pcg64::seed(121);
        for &(k, j, r) in &[(1usize, 5usize, 2usize), (6, 10, 3), (12, 7, 4)] {
            let y = random_packed(&mut rng, k, j, r);
            let plan = ChunkPlan::fixed(k);
            let h = Mat::rand_normal(r, r, &mut rng);
            let v = Mat::rand_normal(j, r, &mut rng);
            let w = Mat::rand_normal(k, r, &mut rng);
            let pool = Pool::new(3);

            let m1 = mttkrp_mode1(&y, &v, &w, &pool, &plan);
            let m2 = mttkrp_mode2(&y, &h, &w, &pool, &plan);
            let m3 = mttkrp_mode3(&y, &h, &v, &pool, &plan);

            let r1 = reference::mttkrp_dense(&y, 0, &h, &v, &w);
            let r2 = reference::mttkrp_dense(&y, 1, &h, &v, &w);
            let r3 = reference::mttkrp_dense(&y, 2, &h, &v, &w);

            assert!(m1.max_abs_diff(&r1) < 1e-9, "mode1 ({k},{j},{r})");
            assert!(m2.max_abs_diff(&r2) < 1e-9, "mode2 ({k},{j},{r})");
            assert!(m3.max_abs_diff(&r3) < 1e-9, "mode3 ({k},{j},{r})");
        }
    }

    #[test]
    fn balanced_plan_matches_reference_on_heavy_tail() {
        // Correctness is plan-independent: the balanced (uneven) plan must
        // produce the same MTTKRPs as the dense reference on a cohort
        // where one subject holds ~50% of the nnz.
        let mut rng = Pcg64::seed(129);
        // K > SUBJECT_CHUNK so the balanced plan really is multi-chunk
        // (smaller K would collapse to one chunk and the merge across
        // uneven boundaries would go untested).
        let (k, j, r) = (SUBJECT_CHUNK + 6, 300usize, 3usize);
        let y = heavy_tailed_packed(&mut rng, k, j, r);
        let plan = ChunkPlan::balanced(&packed_weights(&y));
        assert!(plan.covers(k));
        assert!(plan.n_chunks() > 1, "plan degenerate: {:?}", plan.ranges());
        assert_ne!(plan, ChunkPlan::fixed(k), "boundaries should be uneven");
        let h = Mat::rand_normal(r, r, &mut rng);
        let v = Mat::rand_normal(j, r, &mut rng);
        let w = Mat::rand_normal(k, r, &mut rng);
        let pool = Pool::new(4);
        let m1 = mttkrp_mode1(&y, &v, &w, &pool, &plan);
        let m2 = mttkrp_mode2(&y, &h, &w, &pool, &plan);
        let m3 = mttkrp_mode3(&y, &h, &v, &pool, &plan);
        assert!(m1.max_abs_diff(&reference::mttkrp_dense(&y, 0, &h, &v, &w)) < 1e-9);
        assert!(m2.max_abs_diff(&reference::mttkrp_dense(&y, 1, &h, &v, &w)) < 1e-9);
        assert!(m3.max_abs_diff(&reference::mttkrp_dense(&y, 2, &h, &v, &w)) < 1e-9);
    }

    #[test]
    fn serial_equals_parallel_bitwise() {
        let mut rng = Pcg64::seed(122);
        // K = 70 > SUBJECT_CHUNK so fixed plans have ≥ 2 chunks (a single
        // chunk would take the inline fast path and the test would compare
        // serial against itself), and a heavy-tailed variant so balanced
        // plans exercise genuinely uneven boundaries.
        let k = SUBJECT_CHUNK + 6;
        for heavy in [false, true] {
            let j = if heavy { 500 } else { 8 };
            let y = if heavy {
                heavy_tailed_packed(&mut rng, k, j, 3)
            } else {
                random_packed(&mut rng, k, j, 3)
            };
            let h = Mat::rand_normal(3, 3, &mut rng);
            let v = Mat::rand_normal(j, 3, &mut rng);
            let w = Mat::rand_normal(k, 3, &mut rng);
            let ser = Pool::serial();
            let par = Pool::new(4);
            for plan in [ChunkPlan::fixed(k), ChunkPlan::balanced(&packed_weights(&y))] {
                assert!(plan.n_chunks() > 1, "heavy={heavy} plan degenerate");
                // chunk-ordered reduction over plan-frozen boundaries ⇒
                // identical floating point results, for every mode and for
                // the fused (cached) sweep
                assert_eq!(
                    mttkrp_mode1(&y, &v, &w, &ser, &plan).data(),
                    mttkrp_mode1(&y, &v, &w, &par, &plan).data()
                );
                assert_eq!(
                    mttkrp_mode2(&y, &h, &w, &ser, &plan).data(),
                    mttkrp_mode2(&y, &h, &w, &par, &plan).data()
                );
                assert_eq!(
                    mttkrp_mode3(&y, &h, &v, &ser, &plan).data(),
                    mttkrp_mode3(&y, &h, &v, &par, &plan).data()
                );
                let mut scr_s = FusedScratch::new();
                let mut scr_p = FusedScratch::new();
                assert_eq!(
                    mttkrp_mode2_cached(&y, &h, &w, &ser, &plan, &mut scr_s).data(),
                    mttkrp_mode2_cached(&y, &h, &w, &par, &plan, &mut scr_p).data()
                );
                assert_eq!(
                    mttkrp_mode3_from_cache(&y, &v, &scr_s, &ser, &plan).data(),
                    mttkrp_mode3_from_cache(&y, &v, &scr_p, &par, &plan).data()
                );
            }
        }
    }

    #[test]
    fn fused_sweep_matches_separate_kernels_bitwise() {
        // Regression guard for the fused path: the cached mode-2 and the
        // cache-fed mode-3 must agree **bitwise** with the standalone
        // kernels on the same inputs, on both serial and parallel pools,
        // across repeated reuse of the same scratch, and on both fixed and
        // balanced (uneven) chunk plans.
        let mut rng = Pcg64::seed(125);
        // K crosses the SUBJECT_CHUNK boundary so the fused z_chunk
        // indexing and the chunk-ordered merge are exercised for real.
        let k = SUBJECT_CHUNK + 5;
        let j = 400;
        let y = heavy_tailed_packed(&mut rng, k, j, 3);
        for plan in [ChunkPlan::fixed(k), ChunkPlan::balanced(&packed_weights(&y))] {
            let mut scratch = FusedScratch::new();
            for round in 0..3 {
                let h = Mat::rand_normal(3, 3, &mut rng);
                let v = Mat::rand_normal(j, 3, &mut rng);
                let w = Mat::rand_normal(k, 3, &mut rng);
                for pool in [Pool::serial(), Pool::new(4)] {
                    let m2_fused = mttkrp_mode2_cached(&y, &h, &w, &pool, &plan, &mut scratch);
                    let m3_fused = mttkrp_mode3_from_cache(&y, &v, &scratch, &pool, &plan);
                    assert_eq!(
                        m2_fused.data(),
                        mttkrp_mode2(&y, &h, &w, &pool, &plan).data(),
                        "round {round} mode2"
                    );
                    assert_eq!(
                        m3_fused.data(),
                        mttkrp_mode3(&y, &h, &v, &pool, &plan).data(),
                        "round {round} mode3"
                    );
                }
            }
        }
    }

    #[test]
    fn mode1_counts_one_yv_product_per_subject() {
        let mut rng = Pcg64::seed(126);
        let y = random_packed(&mut rng, 7, 6, 2);
        let v = Mat::rand_normal(6, 2, &mut rng);
        let w = Mat::rand_normal(7, 2, &mut rng);
        let plan = ChunkPlan::fixed(7);
        for pool in [Pool::serial(), Pool::new(3)] {
            let (_, n) = mttkrp_mode1_counted(&y, &v, &w, &pool, &plan);
            assert_eq!(n, 7);
        }
    }

    #[test]
    fn mode2_rows_outside_support_are_zero() {
        let mut rng = Pcg64::seed(123);
        let r = 3;
        let j = 20;
        // single slice touching only columns {4, 9}
        let xk = Csr::from_triplets(5, j, vec![(0, 4, 1.0), (3, 9, 2.0), (4, 4, -1.0)]);
        let qk = crate::linalg::random_orthonormal(5, r, &mut rng);
        let y = PackedY { slices: vec![PackedSlice::pack(&xk, &qk)], j_dim: j };
        let h = Mat::rand_normal(r, r, &mut rng);
        let w = Mat::rand_normal(1, r, &mut rng);
        let m2 = mttkrp_mode2(&y, &h, &w, &Pool::serial(), &ChunkPlan::fixed(1));
        for jj in 0..j {
            let nz = m2.row(jj).iter().any(|&x| x != 0.0);
            assert_eq!(nz, jj == 4 || jj == 9, "row {jj}");
        }
    }

    #[test]
    fn empty_inputs_consistent_across_modes() {
        // One K = 0 / empty-support convention for all three modes:
        // zero-filled results with shapes derived from the factors.
        let r = 3;
        let j = 7;
        let y = PackedY { slices: vec![], j_dim: j };
        let mut rng = Pcg64::seed(127);
        let h = Mat::rand_normal(r, r, &mut rng);
        let v = Mat::rand_normal(j, r, &mut rng);
        let w = Mat::zeros(0, r);
        let pool = Pool::new(2);
        let plan = ChunkPlan::balanced(&[]);
        let m1 = mttkrp_mode1(&y, &v, &w, &pool, &plan);
        assert_eq!(m1.shape(), (r, r));
        assert!(m1.data().iter().all(|&x| x == 0.0));
        let m2 = mttkrp_mode2(&y, &h, &w, &pool, &plan);
        assert_eq!(m2.shape(), (j, r));
        assert!(m2.data().iter().all(|&x| x == 0.0));
        let m3 = mttkrp_mode3(&y, &h, &v, &pool, &plan);
        assert_eq!(m3.shape(), (0, r));
        let mut scratch = FusedScratch::new();
        let m2c = mttkrp_mode2_cached(&y, &h, &w, &pool, &plan, &mut scratch);
        assert_eq!(m2c.shape(), (j, r));
        assert_eq!(
            mttkrp_mode3_from_cache(&y, &v, &scratch, &pool, &plan).shape(),
            (0, r)
        );
    }

    #[test]
    fn empty_support_slice_contributes_nothing() {
        let mut rng = Pcg64::seed(128);
        let (k, j, r) = (4usize, 6usize, 2usize);
        let y = random_packed(&mut rng, k, j, r);
        let h = Mat::rand_normal(r, r, &mut rng);
        let v = Mat::rand_normal(j, r, &mut rng);
        let w = Mat::rand_normal(k + 1, r, &mut rng);
        let mut padded = y.slices.clone();
        padded.push(PackedSlice::from_parts(Vec::new(), Vec::new(), Mat::zeros(0, r)));
        let yp = PackedY { slices: padded, j_dim: j };
        let wk = w.block(0, k, 0, r);
        let pool = Pool::serial();
        let plan = ChunkPlan::fixed(k);
        let plan_p = ChunkPlan::fixed(k + 1);
        assert_eq!(
            mttkrp_mode1(&y, &v, &wk, &pool, &plan).data(),
            mttkrp_mode1(&yp, &v, &w, &pool, &plan_p).data()
        );
        assert_eq!(
            mttkrp_mode2(&y, &h, &wk, &pool, &plan).data(),
            mttkrp_mode2(&yp, &h, &w, &pool, &plan_p).data()
        );
        // mode 3 gains one row for the padded subject, and it is zero
        let m3p = mttkrp_mode3(&yp, &h, &v, &pool, &plan_p);
        assert!(m3p.row(k).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn zero_rank_edge() {
        // smallest sane case R=1
        let mut rng = Pcg64::seed(124);
        let y = random_packed(&mut rng, 3, 4, 1);
        let h = Mat::rand_normal(1, 1, &mut rng);
        let v = Mat::rand_normal(4, 1, &mut rng);
        let w = Mat::rand_normal(3, 1, &mut rng);
        let pool = Pool::serial();
        let m1 = mttkrp_mode1(&y, &v, &w, &pool, &ChunkPlan::fixed(3));
        let want = reference::mttkrp_dense(&y, 0, &h, &v, &w);
        assert!(m1.max_abs_diff(&want) < 1e-10);
    }
}
