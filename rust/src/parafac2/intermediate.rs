//! Packed representation of the intermediate tensor `Y`.
//!
//! SPARTan "never forms the tensor Y explicitly and directly utilizes the
//! available collection of matrices {Y_k} instead" (paper §4.1). Moreover
//! `Y_k = Q_kᵀ X_k` inherits the **column sparsity** of `X_k`: only the
//! `c_k` columns of `X_k` that contain a nonzero are nonzero in `Y_k`, and
//! those columns are fully dense (R values each).
//!
//! So the natural storage is: the sorted list of nonzero columns
//! (`support`) plus a dense `c_k × R` block holding `Y_kᵀ` restricted to
//! the support (transposed so that the hot loops — row AXPYs during
//! packing, row streams during MTTKRP — touch contiguous memory).

use crate::linalg::{blas, kernels, Mat};
use crate::sparse::Csr;
use std::sync::atomic::{AtomicU64, Ordering};

/// One packed frontal slice `Y_k` of the intermediate tensor.
///
/// Beyond the paper's `(support, Y_kᵀ)` pair this also carries
/// `local_cols` — for each stored nonzero of `X_k` (in CSR order) the
/// local support index of its column. The support and `local_cols` depend
/// only on the *sparsity pattern* of `X_k`, which is constant across ALS
/// iterations, so [`PackedSlice::repack_from`] can refresh `yt` in place
/// every Procrustes pass without re-deriving the support or allocating:
/// the slice doubles as its own arena slot.
#[derive(Debug)]
pub struct PackedSlice {
    /// Sorted original column ids with at least one nonzero in `X_k`.
    pub support: Vec<u32>,
    /// Per-nonzero local column index (`local_cols[p]` is the support
    /// index of `X_k`'s `p`-th stored entry). Length `nnz(X_k)`.
    pub local_cols: Vec<u32>,
    /// `Y_kᵀ` restricted to the support: shape `c_k × R`, row `c` holds
    /// `Y_k(:, support[c])ᵀ`.
    pub yt: Mat,
    /// Lifetime tally of `Y_k·V` products ([`PackedSlice::yk_times_v`])
    /// performed on this slice. Per-slice (not a global) so each worker
    /// bumps a counter it already owns in cache — no cross-core
    /// contention — and so tests can measure a private tensor's count
    /// race-free: the fused sweep does exactly one per subject per CP
    /// iteration (asserted in `metrics::flops`).
    yv_count: AtomicU64,
    /// `‖Y_k‖²_F`, computed once per (re)pack while `yt` is cache-hot —
    /// in the same element order a post-hoc scan would use, so the value
    /// is bitwise identical — sparing the SSE bookkeeping two cold
    /// `O(nnz(Y))` streams per ALS iteration.
    norm_sq_cache: f64,
    /// Lifetime tally of **cold read traversals** of the packed `yt`
    /// block: standalone passes that stream the whole slice back out of
    /// memory (standalone mode-1 `Y_k·V`, the mode-2 scatter, standalone
    /// mode 3, the baseline's COO materialization). The pack itself and
    /// reads fused into it ([`PackedSlice::yk_times_v_fused`], which
    /// consumes the rows while the pack has them cache-resident) are *not*
    /// traversals — that distinction is the whole point of the DPar2-style
    /// pack→mode-1 fusion, which drops the ALS iteration from 2 cold
    /// traversals per slice (mode 1 + mode 2) to 1 (mode 2 only), asserted
    /// in `metrics::flops`.
    traversal_count: AtomicU64,
}

impl Clone for PackedSlice {
    fn clone(&self) -> PackedSlice {
        PackedSlice {
            support: self.support.clone(),
            local_cols: self.local_cols.clone(),
            yt: self.yt.clone(),
            norm_sq_cache: self.norm_sq_cache,
            yv_count: AtomicU64::new(self.yv_count.load(Ordering::Relaxed)),
            traversal_count: AtomicU64::new(self.traversal_count.load(Ordering::Relaxed)),
        }
    }
}

impl PackedSlice {
    /// An uninitialized arena slot (filled by the first
    /// [`PackedSlice::repack_from`]).
    pub fn empty() -> PackedSlice {
        PackedSlice::from_parts(Vec::new(), Vec::new(), Mat::zeros(0, 0))
    }

    /// Assemble from raw parts (tests/benches building synthetic slices;
    /// `local_cols` may be empty if the slice will never be repacked).
    pub fn from_parts(support: Vec<u32>, local_cols: Vec<u32>, yt: Mat) -> PackedSlice {
        let norm_sq_cache = Self::norm_sq_of(&yt);
        PackedSlice {
            support,
            local_cols,
            yt,
            norm_sq_cache,
            yv_count: AtomicU64::new(0),
            traversal_count: AtomicU64::new(0),
        }
    }

    /// The one canonical `‖Y_k‖²` summation (element order fixed so the
    /// pack-time cache is bitwise identical to a post-hoc scan).
    fn norm_sq_of(yt: &Mat) -> f64 {
        yt.data().iter().map(|x| x * x).sum()
    }

    /// Pack `Y_k = Q_kᵀ X_k` directly from the CSR slice and `Q_k`,
    /// touching each nonzero of `X_k` exactly once (cost `nnz_k · R`).
    pub fn pack(xk: &Csr, qk: &Mat) -> PackedSlice {
        let r = qk.cols();
        assert_eq!(qk.rows(), xk.rows(), "Q_k rows must equal I_k");
        let support = xk.col_support();
        // column id → local index (scratch; only needed on first pack)
        let mut local = vec![u32::MAX; xk.cols()];
        for (c, &j) in support.iter().enumerate() {
            local[j as usize] = c as u32;
        }
        let local_cols: Vec<u32> =
            xk.indices().iter().map(|&j| local[j as usize]).collect();
        let mut slice = PackedSlice::from_parts(support, local_cols, Mat::zeros(0, 0));
        slice.yt = Mat::zeros(slice.support.len(), r);
        slice.fill_yt(xk, qk);
        slice
    }

    /// Refresh `Y_k = Q_kᵀ X_k` reusing this slot's buffers. `xk` must be
    /// the same slice (same sparsity pattern) the slot was packed from; a
    /// shape mismatch (first use, or a rank change) falls back to a fresh
    /// [`PackedSlice::pack`]. Accumulation order is identical to `pack`,
    /// so the result is bitwise identical.
    pub fn repack_from(&mut self, xk: &Csr, qk: &Mat) {
        let r = qk.cols();
        if self.local_cols.len() != xk.nnz() || self.yt.shape() != (self.support.len(), r) {
            *self = PackedSlice::pack(xk, qk);
            return;
        }
        debug_assert_eq!(qk.rows(), xk.rows(), "Q_k rows must equal I_k");
        // The cheap shape guards above cannot distinguish two *different*
        // sparsity patterns with equal nnz and c_k; reusing a slot across
        // tensors is a caller bug that would silently scatter values into
        // wrong columns, so pin it down in debug builds.
        debug_assert_eq!(
            self.support,
            xk.col_support(),
            "repack_from requires the same sparsity pattern the slot was packed from"
        );
        self.yt.fill_zero();
        self.fill_yt(xk, qk);
    }

    /// Accumulate `Y_kᵀ` rows from the CSR entries via `local_cols`
    /// (shared by `pack` and `repack_from`; one pass over the nonzeros),
    /// then refresh the `‖Y_k‖²` cache while the block is still hot.
    fn fill_yt(&mut self, xk: &Csr, qk: &Mat) {
        let mut at = 0usize;
        for i in 0..xk.rows() {
            let qrow = qk.row(i);
            let (_cols, vals) = xk.row_parts(i);
            for &v in vals {
                let dst = self.yt.row_mut(self.local_cols[at] as usize);
                at += 1;
                for (d, &q) in dst.iter_mut().zip(qrow) {
                    *d += v * q;
                }
            }
        }
        self.norm_sq_cache = Self::norm_sq_of(&self.yt);
    }

    /// Refresh `Y_k = Q_kᵀ X̃_k` from the **resident compact-X arena**
    /// instead of the original CSR: same values in the same CSR entry
    /// order (the arena stores bit-copies), same per-entry accumulation —
    /// bitwise identical to [`PackedSlice::repack_from`] on the source
    /// slice. The slot's `local_cols` stays empty on this path (the
    /// arena owns the canonical entry→support mapping), so an arena-backed
    /// fit does not pay for the mapping twice. First use (or a rank
    /// change) sizes the buffers; steady state allocates nothing.
    pub fn repack_from_compact(&mut self, cx: &crate::sparse::CompactSlice, qk: &Mat) {
        let r = qk.cols();
        debug_assert_eq!(qk.rows(), cx.rows(), "Q_k rows must equal I_k");
        if self.yt.shape() != (cx.c_k(), r) || self.support.len() != cx.c_k() {
            self.support.clear();
            self.support.extend_from_slice(&cx.support);
            self.local_cols.clear();
            self.yt.reset_to_zeros(cx.c_k(), r);
        } else {
            // Same-pattern precondition, pinned like `repack_from` does.
            debug_assert_eq!(
                self.support, cx.support,
                "repack_from_compact requires the slot's original sparsity pattern"
            );
            self.yt.fill_zero();
        }
        let mut at = 0usize;
        for i in 0..cx.rows() {
            let qrow = qk.row(i);
            let (cols, vals) = cx.row_parts(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let dst = self.yt.row_mut(c as usize);
                for (d, &q) in dst.iter_mut().zip(qrow) {
                    *d += v * q;
                }
            }
            at += vals.len();
        }
        debug_assert_eq!(at, cx.nnz());
        self.norm_sq_cache = Self::norm_sq_of(&self.yt);
    }

    /// Number of nonzero columns `c_k`.
    #[inline]
    pub fn c_k(&self) -> usize {
        self.support.len()
    }

    /// Rank (width of the packed block).
    #[inline]
    pub fn rank(&self) -> usize {
        self.yt.cols()
    }

    /// `‖Y_k‖²_F` (used by the fit computation) — served from the
    /// pack-time cache, so the per-iteration SSE bookkeeping does not
    /// re-stream the packed slices. Bitwise identical to scanning `yt`.
    pub fn norm_sq(&self) -> f64 {
        self.norm_sq_cache
    }

    /// Gather the support rows of a J×R factor (`V_c` in the paper's
    /// Fig. 2: "only the rows of V corresponding to non-zero columns").
    pub fn gather_rows(&self, v: &Mat) -> Mat {
        let mut out = Mat::zeros(self.support.len(), v.cols());
        for (c, &j) in self.support.iter().enumerate() {
            out.row_mut(c).copy_from_slice(v.row(j as usize));
        }
        out
    }

    /// `Y_k · V_c` as an R×R product using only support rows of `v` —
    /// the hottest kernel of the CP step, as a **standalone cold pass**
    /// (counts one `yt` traversal). The per-iteration sweep performs the
    /// product exactly once per subject; each call is tallied on the slice
    /// so that invariant is assertable ([`PackedY::yv_products`], checked
    /// in `metrics::flops` tests).
    pub fn yk_times_v(&self, v: &Mat) -> Mat {
        self.traversal_count.fetch_add(1, Ordering::Relaxed);
        self.yk_times_v_fused(v)
    }

    /// `Y_k · V_c` **fused into the pack**: call immediately after
    /// [`PackedSlice::repack_from`], while the freshly written `yt` rows
    /// are still cache-resident (DPar2-style). Same arithmetic, same
    /// floating-point order, same `Y_k·V` tally as
    /// [`PackedSlice::yk_times_v`] — but *not* counted as a traversal,
    /// because the read rides the pack instead of streaming the slice
    /// back out of memory.
    pub fn yk_times_v_fused(&self, v: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        self.yk_times_v_fused_into(v, &mut out);
        out
    }

    /// [`PackedSlice::yk_times_v_fused`] into a reused output buffer (the
    /// steady-state-allocation-free form the arena-backed sweep uses).
    /// Bitwise identical: the buffer is zero-reset before the kernel runs,
    /// exactly like a fresh allocation.
    pub fn yk_times_v_fused_into(&self, v: &Mat, out: &mut Mat) {
        self.yv_count.fetch_add(1, Ordering::Relaxed);
        // Ytᵀ · V_c, streamed without materializing V_c — the shape-A
        // register-blocked micro-kernel (4 support rows in flight,
        // R-unrolled panel; bitwise identical to the scalar reference,
        // see `linalg::kernels` for the dispatch + contract).
        out.reset_to_zeros(self.rank(), v.cols());
        kernels::spmm_yt_v(&self.yt, &self.support, v, out);
    }

    /// Record one cold read traversal of this slice's packed block (the
    /// MTTKRP mode-2/mode-3 sweeps and the baseline's COO materialization
    /// call this as they stream `yt`).
    pub(crate) fn note_traversal(&self) {
        self.traversal_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Dense `R × J` materialization (tests only).
    pub fn to_dense(&self, j_dim: usize) -> Mat {
        let r = self.rank();
        let mut m = Mat::zeros(r, j_dim);
        for (c, &j) in self.support.iter().enumerate() {
            for i in 0..r {
                m[(i, j as usize)] = self.yt[(c, i)];
            }
        }
        m
    }

    /// Heap bytes (budget accounting / memory reports).
    pub fn heap_bytes(&self) -> u64 {
        (self.support.capacity() * 4 + self.local_cols.capacity() * 4 + self.yt.data().len() * 8)
            as u64
    }
}

/// The packed intermediate tensor: one [`PackedSlice`] per subject.
#[derive(Clone, Debug)]
pub struct PackedY {
    pub slices: Vec<PackedSlice>,
    /// Shared J dimension (column ids in `support` are < j_dim).
    pub j_dim: usize,
}

impl PackedY {
    /// An empty arena ready to be filled by
    /// [`crate::parafac2::procrustes::procrustes_all_into`].
    pub fn empty(j_dim: usize) -> PackedY {
        PackedY { slices: Vec::new(), j_dim }
    }

    /// Ensure exactly `k` slice slots, preserving existing slots (whose
    /// buffers get reused on repack) and filling new ones with
    /// [`PackedSlice::empty`].
    pub fn resize_slots(&mut self, k: usize) {
        if self.slices.len() != k {
            self.slices.resize_with(k, PackedSlice::empty);
        }
    }

    pub fn k(&self) -> usize {
        self.slices.len()
    }

    /// Total packed nonzeros `R · Σ c_k` — the paper's `nnz(Y)`.
    pub fn nnz(&self) -> usize {
        self.slices.iter().map(|s| s.c_k() * s.rank()).sum()
    }

    /// Σ_k ‖Y_k‖²_F.
    pub fn norm_sq(&self) -> f64 {
        self.slices.iter().map(|s| s.norm_sq()).sum()
    }

    /// Total `Y_k·V` products ever performed on this tensor's slices.
    /// Per-tensor and race-free to read: any code path that sneaks an
    /// extra `yk_times_v` into the CP step shows up here regardless of
    /// where it was called from.
    pub fn yv_products(&self) -> u64 {
        self.slices.iter().map(|s| s.yv_count.load(Ordering::Relaxed)).sum()
    }

    /// Total cold read traversals of this tensor's packed slices (see
    /// [`PackedSlice`] for what counts). The pack-fused ALS iteration
    /// performs exactly **one** per subject per iteration — mode 2 — which
    /// `metrics::flops` asserts; the pre-fusion sweep performed two
    /// (mode 1 + mode 2).
    pub fn traversals(&self) -> u64 {
        self.slices.iter().map(|s| s.traversal_count.load(Ordering::Relaxed)).sum()
    }

    pub fn heap_bytes(&self) -> u64 {
        self.slices.iter().map(|s| s.heap_bytes()).sum()
    }
}

/// Verification helper: dense `Y_k` computed the obvious way.
pub fn dense_yk(xk: &Csr, qk: &Mat) -> Mat {
    blas::matmul(&qk.transpose(), &xk.to_dense())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::random_orthonormal;
    use crate::util::rng::Pcg64;

    fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trips = Vec::new();
        for i in 0..rows {
            for j in 0..cols {
                if rng.chance(density) {
                    trips.push((i, j, rng.normal()));
                }
            }
        }
        if trips.is_empty() {
            trips.push((0, 0, 1.0));
        }
        Csr::from_triplets(rows, cols, trips)
    }

    #[test]
    fn pack_matches_dense_computation() {
        let mut rng = Pcg64::seed(101);
        for _ in 0..10 {
            let xk = random_sparse(&mut rng, 12, 15, 0.15);
            let qk = random_orthonormal(12, 4, &mut rng);
            let packed = PackedSlice::pack(&xk, &qk);
            let want = dense_yk(&xk, &qk);
            let got = packed.to_dense(15);
            assert!(got.max_abs_diff(&want) < 1e-10);
            // support matches X_k's column support exactly (paper §4.1)
            assert_eq!(packed.support, xk.col_support());
        }
    }

    #[test]
    fn packed_nonzeros_are_r_times_ck() {
        let mut rng = Pcg64::seed(102);
        let xk = random_sparse(&mut rng, 10, 20, 0.1);
        let qk = random_orthonormal(10, 3, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        assert_eq!(p.yt.shape(), (p.c_k(), 3));
        assert_eq!(p.c_k(), xk.col_support_size());
    }

    #[test]
    fn yk_times_v_matches_dense() {
        // Sweeps ranks on both sides of the kernel layer's monomorphized
        // widths (R ≤ 16 unrolled, 17 takes the runtime-width path) so the
        // dispatch is exercised where the ALS actually runs it.
        let mut rng = Pcg64::seed(103);
        for &r in &[1usize, 3, 8, 17] {
            let xk = random_sparse(&mut rng, r.max(2) + 4, 14 + r, 0.2);
            let qk = random_orthonormal(r.max(2) + 4, r, &mut rng);
            let p = PackedSlice::pack(&xk, &qk);
            let v = Mat::rand_normal(14 + r, r, &mut rng);
            let got = p.yk_times_v(&v);
            let want = blas::matmul(&dense_yk(&xk, &qk), &v);
            assert!(got.max_abs_diff(&want) < 1e-10, "R={r}");
        }
    }

    #[test]
    fn yk_times_v_empty_support_subject() {
        // The K=0/empty-support convention from PR 1, pinned at the kernel
        // boundary: a subject whose slice has no nonzero columns must
        // yield an all-zero R×R product (shape from the factor argument)
        // while still tallying the Y·V product and the cold traversal.
        let mut rng = Pcg64::seed(109);
        for &r in &[1usize, 3, 8, 17] {
            let p = PackedSlice::from_parts(Vec::new(), Vec::new(), Mat::zeros(0, r));
            let v = Mat::rand_normal(11, r, &mut rng);
            let got = p.yk_times_v(&v);
            assert_eq!(got.shape(), (r, r), "R={r}");
            assert!(got.data().iter().all(|&x| x == 0.0), "R={r}");
            let y = PackedY { slices: vec![p], j_dim: 11 };
            assert_eq!((y.yv_products(), y.traversals()), (1, 1), "R={r}");
        }
    }

    #[test]
    fn gather_rows_support_order() {
        let mut rng = Pcg64::seed(104);
        let xk = Csr::from_triplets(2, 6, vec![(0, 5, 1.0), (1, 2, 2.0)]);
        let qk = random_orthonormal(2, 2, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        let v = Mat::from_fn(6, 2, |i, j| (i * 2 + j) as f64);
        let g = p.gather_rows(&v);
        assert_eq!(p.support, vec![2, 5]);
        assert_eq!(g.row(0), v.row(2));
        assert_eq!(g.row(1), v.row(5));
    }

    #[test]
    fn repack_reuses_buffers_and_matches_pack_bitwise() {
        let mut rng = Pcg64::seed(106);
        let xk = random_sparse(&mut rng, 11, 16, 0.2);
        let q0 = random_orthonormal(11, 4, &mut rng);
        let mut slot = PackedSlice::empty();
        slot.repack_from(&xk, &q0); // first use: falls back to pack
        assert_eq!(slot.yt.data(), PackedSlice::pack(&xk, &q0).yt.data());
        let support_ptr = slot.support.as_ptr();
        let yt_before = slot.yt.data().as_ptr();
        for round in 0..3 {
            let qk = random_orthonormal(11, 4, &mut rng);
            slot.repack_from(&xk, &qk);
            let fresh = PackedSlice::pack(&xk, &qk);
            assert_eq!(slot.yt.data(), fresh.yt.data(), "round {round}");
            assert_eq!(slot.support, fresh.support);
            assert_eq!(slot.local_cols, fresh.local_cols);
        }
        // buffers were reused, not reallocated
        assert_eq!(slot.support.as_ptr(), support_ptr);
        assert_eq!(slot.yt.data().as_ptr(), yt_before);
    }

    #[test]
    fn repack_from_compact_matches_csr_repack_bitwise() {
        // The arena contract: refreshing Y_k from the resident compact
        // values must be bit-identical to refreshing from the original
        // CSR, across reuse rounds, with the slot's local_cols left empty
        // (the arena owns the mapping).
        let mut rng = Pcg64::seed(110);
        let xk = random_sparse(&mut rng, 9, 13, 0.25);
        let cx = crate::sparse::CompactSlice::pack(&xk);
        let mut slot = PackedSlice::empty();
        let mut csr_slot = PackedSlice::empty();
        for round in 0..3 {
            let qk = random_orthonormal(9, 3, &mut rng);
            slot.repack_from_compact(&cx, &qk);
            csr_slot.repack_from(&xk, &qk);
            assert_eq!(slot.support, csr_slot.support, "round {round}");
            assert_eq!(slot.yt.data().len(), csr_slot.yt.data().len());
            for (a, b) in slot.yt.data().iter().zip(csr_slot.yt.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "round {round}");
            }
            assert_eq!(slot.norm_sq().to_bits(), csr_slot.norm_sq().to_bits());
            assert!(slot.local_cols.is_empty(), "arena path must not duplicate the mapping");
        }
    }

    #[test]
    fn yk_times_v_fused_into_reuses_buffer_bitwise() {
        let mut rng = Pcg64::seed(111);
        let xk = random_sparse(&mut rng, 8, 12, 0.3);
        let qk = random_orthonormal(8, 4, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        let v = Mat::rand_normal(12, 4, &mut rng);
        let fresh = p.yk_times_v_fused(&v);
        let mut reused = Mat::rand_normal(9, 9, &mut rng); // stale contents + wrong shape
        p.yk_times_v_fused_into(&v, &mut reused);
        assert_eq!(reused.shape(), fresh.shape());
        for (a, b) in reused.data().iter().zip(fresh.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn local_cols_map_entries_to_support() {
        let mut rng = Pcg64::seed(107);
        let xk = random_sparse(&mut rng, 6, 9, 0.3);
        let qk = random_orthonormal(6, 2, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        assert_eq!(p.local_cols.len(), xk.nnz());
        for (pos, &j) in xk.indices().iter().enumerate() {
            assert_eq!(p.support[p.local_cols[pos] as usize], j);
        }
    }

    #[test]
    fn yv_and_traversal_tallies() {
        let mut rng = Pcg64::seed(108);
        let xk = random_sparse(&mut rng, 7, 9, 0.3);
        let qk = random_orthonormal(7, 3, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        let v = Mat::rand_normal(9, 3, &mut rng);
        let y = PackedY { slices: vec![p], j_dim: 9 };
        assert_eq!((y.yv_products(), y.traversals()), (0, 0));
        // standalone product: one Y·V tally AND one cold traversal
        let a = y.slices[0].yk_times_v(&v);
        assert_eq!((y.yv_products(), y.traversals()), (1, 1));
        // fused product: tallies the Y·V but NOT a traversal, and is
        // bitwise identical to the standalone kernel
        let b = y.slices[0].yk_times_v_fused(&v);
        assert_eq!((y.yv_products(), y.traversals()), (2, 1));
        assert_eq!(a.data(), b.data());
        y.slices[0].note_traversal();
        assert_eq!((y.yv_products(), y.traversals()), (2, 2));
    }

    #[test]
    fn norm_sq_consistent() {
        let mut rng = Pcg64::seed(105);
        let xk = random_sparse(&mut rng, 8, 10, 0.3);
        let qk = random_orthonormal(8, 3, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        let dense = p.to_dense(10);
        assert!((p.norm_sq() - dense.fro_norm().powi(2)).abs() < 1e-9);
    }
}
