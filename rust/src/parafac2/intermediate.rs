//! Packed representation of the intermediate tensor `Y`.
//!
//! SPARTan "never forms the tensor Y explicitly and directly utilizes the
//! available collection of matrices {Y_k} instead" (paper §4.1). Moreover
//! `Y_k = Q_kᵀ X_k` inherits the **column sparsity** of `X_k`: only the
//! `c_k` columns of `X_k` that contain a nonzero are nonzero in `Y_k`, and
//! those columns are fully dense (R values each).
//!
//! So the natural storage is: the sorted list of nonzero columns
//! (`support`) plus a dense `c_k × R` block holding `Y_kᵀ` restricted to
//! the support (transposed so that the hot loops — row AXPYs during
//! packing, row streams during MTTKRP — touch contiguous memory).

use crate::linalg::{blas, Mat};
use crate::sparse::Csr;

/// One packed frontal slice `Y_k` of the intermediate tensor.
#[derive(Clone, Debug)]
pub struct PackedSlice {
    /// Sorted original column ids with at least one nonzero in `X_k`.
    pub support: Vec<u32>,
    /// `Y_kᵀ` restricted to the support: shape `c_k × R`, row `c` holds
    /// `Y_k(:, support[c])ᵀ`.
    pub yt: Mat,
}

impl PackedSlice {
    /// Pack `Y_k = Q_kᵀ X_k` directly from the CSR slice and `Q_k`,
    /// touching each nonzero of `X_k` exactly once (cost `nnz_k · R`).
    pub fn pack(xk: &Csr, qk: &Mat) -> PackedSlice {
        let r = qk.cols();
        assert_eq!(qk.rows(), xk.rows(), "Q_k rows must equal I_k");
        let support = xk.col_support();
        // column id → local index
        let mut local = vec![u32::MAX; xk.cols()];
        for (c, &j) in support.iter().enumerate() {
            local[j as usize] = c as u32;
        }
        let mut yt = Mat::zeros(support.len(), r);
        for i in 0..xk.rows() {
            let qrow = qk.row(i);
            for (j, v) in xk.row_iter(i) {
                let dst = yt.row_mut(local[j as usize] as usize);
                for (d, &q) in dst.iter_mut().zip(qrow) {
                    *d += v * q;
                }
            }
        }
        PackedSlice { support, yt }
    }

    /// Number of nonzero columns `c_k`.
    #[inline]
    pub fn c_k(&self) -> usize {
        self.support.len()
    }

    /// Rank (width of the packed block).
    #[inline]
    pub fn rank(&self) -> usize {
        self.yt.cols()
    }

    /// ‖Y_k‖²_F (used by the fit computation).
    pub fn norm_sq(&self) -> f64 {
        self.yt.data().iter().map(|x| x * x).sum()
    }

    /// Gather the support rows of a J×R factor (`V_c` in the paper's
    /// Fig. 2: "only the rows of V corresponding to non-zero columns").
    pub fn gather_rows(&self, v: &Mat) -> Mat {
        let mut out = Mat::zeros(self.support.len(), v.cols());
        for (c, &j) in self.support.iter().enumerate() {
            out.row_mut(c).copy_from_slice(v.row(j as usize));
        }
        out
    }

    /// `Y_k · V_c` as an R×R product using only support rows of `v`
    /// (shared by the mode-1 and mode-3 kernels).
    pub fn yk_times_v(&self, v: &Mat) -> Mat {
        // Ytᵀ · V_c, streamed without materializing V_c: accumulate
        // rank-1 contributions row by row.
        let r = self.rank();
        let mut out = Mat::zeros(r, v.cols());
        for (c, &j) in self.support.iter().enumerate() {
            let yrow = self.yt.row(c);
            let vrow = v.row(j as usize);
            for (i, &yv) in yrow.iter().enumerate() {
                if yv == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for (o, &vv) in orow.iter_mut().zip(vrow) {
                    *o += yv * vv;
                }
            }
        }
        out
    }

    /// Dense `R × J` materialization (tests only).
    pub fn to_dense(&self, j_dim: usize) -> Mat {
        let r = self.rank();
        let mut m = Mat::zeros(r, j_dim);
        for (c, &j) in self.support.iter().enumerate() {
            for i in 0..r {
                m[(i, j as usize)] = self.yt[(c, i)];
            }
        }
        m
    }

    /// Heap bytes (budget accounting / memory reports).
    pub fn heap_bytes(&self) -> u64 {
        (self.support.capacity() * 4 + self.yt.data().len() * 8) as u64
    }
}

/// The packed intermediate tensor: one [`PackedSlice`] per subject.
#[derive(Clone, Debug)]
pub struct PackedY {
    pub slices: Vec<PackedSlice>,
    /// Shared J dimension (column ids in `support` are < j_dim).
    pub j_dim: usize,
}

impl PackedY {
    pub fn k(&self) -> usize {
        self.slices.len()
    }

    /// Total packed nonzeros `R · Σ c_k` — the paper's `nnz(Y)`.
    pub fn nnz(&self) -> usize {
        self.slices.iter().map(|s| s.c_k() * s.rank()).sum()
    }

    /// Σ_k ‖Y_k‖²_F.
    pub fn norm_sq(&self) -> f64 {
        self.slices.iter().map(|s| s.norm_sq()).sum()
    }

    pub fn heap_bytes(&self) -> u64 {
        self.slices.iter().map(|s| s.heap_bytes()).sum()
    }
}

/// Verification helper: dense `Y_k` computed the obvious way.
pub fn dense_yk(xk: &Csr, qk: &Mat) -> Mat {
    blas::matmul(&qk.transpose(), &xk.to_dense())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::random_orthonormal;
    use crate::util::rng::Pcg64;

    fn random_sparse(rng: &mut Pcg64, rows: usize, cols: usize, density: f64) -> Csr {
        let mut trips = Vec::new();
        for i in 0..rows {
            for j in 0..cols {
                if rng.chance(density) {
                    trips.push((i, j, rng.normal()));
                }
            }
        }
        if trips.is_empty() {
            trips.push((0, 0, 1.0));
        }
        Csr::from_triplets(rows, cols, trips)
    }

    #[test]
    fn pack_matches_dense_computation() {
        let mut rng = Pcg64::seed(101);
        for _ in 0..10 {
            let xk = random_sparse(&mut rng, 12, 15, 0.15);
            let qk = random_orthonormal(12, 4, &mut rng);
            let packed = PackedSlice::pack(&xk, &qk);
            let want = dense_yk(&xk, &qk);
            let got = packed.to_dense(15);
            assert!(got.max_abs_diff(&want) < 1e-10);
            // support matches X_k's column support exactly (paper §4.1)
            assert_eq!(packed.support, xk.col_support());
        }
    }

    #[test]
    fn packed_nonzeros_are_r_times_ck() {
        let mut rng = Pcg64::seed(102);
        let xk = random_sparse(&mut rng, 10, 20, 0.1);
        let qk = random_orthonormal(10, 3, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        assert_eq!(p.yt.shape(), (p.c_k(), 3));
        assert_eq!(p.c_k(), xk.col_support_size());
    }

    #[test]
    fn yk_times_v_matches_dense() {
        let mut rng = Pcg64::seed(103);
        let xk = random_sparse(&mut rng, 9, 14, 0.2);
        let qk = random_orthonormal(9, 5, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        let v = Mat::rand_normal(14, 5, &mut rng);
        let got = p.yk_times_v(&v);
        let want = blas::matmul(&dense_yk(&xk, &qk), &v);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn gather_rows_support_order() {
        let mut rng = Pcg64::seed(104);
        let xk = Csr::from_triplets(2, 6, vec![(0, 5, 1.0), (1, 2, 2.0)]);
        let qk = random_orthonormal(2, 2, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        let v = Mat::from_fn(6, 2, |i, j| (i * 2 + j) as f64);
        let g = p.gather_rows(&v);
        assert_eq!(p.support, vec![2, 5]);
        assert_eq!(g.row(0), v.row(2));
        assert_eq!(g.row(1), v.row(5));
    }

    #[test]
    fn norm_sq_consistent() {
        let mut rng = Pcg64::seed(105);
        let xk = random_sparse(&mut rng, 8, 10, 0.3);
        let qk = random_orthonormal(8, 3, &mut rng);
        let p = PackedSlice::pack(&xk, &qk);
        let dense = p.to_dense(10);
        assert!((p.norm_sq() - dense.fro_norm().powi(2)).abs() < 1e-9);
    }
}
