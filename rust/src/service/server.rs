//! TCP daemon (`spartan serve`) and blocking client for the service.
//!
//! The server speaks the newline-delimited JSON protocol of
//! [`super::protocol`] on a [`std::net::TcpListener`] — one handler
//! thread per connection, any number of requests per connection. The
//! client side is a set of one-shot blocking helpers (`submit`,
//! `status`, `cancel`, `result`, `ping`, `shutdown`) used by the CLI
//! subcommands and the `service_e2e` test.
//!
//! Datasets are referenced **by server-side path** in `submit` — the
//! daemon and its clients share a filesystem (the `spartan generate` /
//! `decompose` workflow), so the tensor itself never travels; only the
//! fitted factors do, bit-exactly (see [`super::protocol`]).
//!
//! With `--journal <dir>` the daemon runs durably: job lifecycles and
//! per-iteration checkpoints land under the journal directory
//! ([`super::journal`]), a restart replays them (results survive,
//! interrupted fits resume bitwise), and SIGTERM drains gracefully —
//! stop accepting, checkpoint running fits, exit — so a daemon roll
//! loses zero accepted work.

use crate::parafac2::{Backend, Parafac2Config, Parafac2Model};
use crate::service::protocol::{
    error_from_response, error_to_response, model_to_json, ok_response, status_to_json,
};
use crate::service::{JobSpec, Service, ServiceConfig, ServiceError};
use crate::sparse::IrregularTensor;
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// How to stand up the daemon.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address; port 0 picks a free port (announced on stdout).
    pub addr: String,
    pub service: ServiceConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: super::protocol::DEFAULT_ADDR.to_string(),
            service: ServiceConfig::default(),
        }
    }
}

/// Bind, announce the resolved address on stdout (machine-parsable:
/// `spartan serve: listening on <addr> …`), and serve until a `shutdown`
/// request arrives.
pub fn serve(cfg: &ServeConfig) -> Result<(), ServiceError> {
    let listener = TcpListener::bind(&cfg.addr)
        .map_err(|e| ServiceError::Io(format!("bind {}: {e}", cfg.addr)))?;
    let local = listener.local_addr().map_err(|e| ServiceError::Io(e.to_string()))?;
    {
        // Explicit flush: the announce line is how scripts (CI smoke, the
        // e2e tests) discover a port-0 bind, and a piped stdout is block
        // buffered.
        let stdout = std::io::stdout();
        let mut out = stdout.lock();
        let budget = match cfg.service.mem_budget {
            Some(b) => crate::util::humansize::bytes(b),
            None => "unlimited".to_string(),
        };
        let journal = match &cfg.service.journal {
            Some(dir) => format!(", journal {}", dir.display()),
            None => String::new(),
        };
        let _ = writeln!(
            out,
            "spartan serve: listening on {local} (workers {}, budget {budget}, queue {}{journal})",
            cfg.service.workers, cfg.service.max_pending,
        );
        let _ = out.flush();
    }
    serve_listener(listener, &cfg.service)
}

/// Serve on an already-bound listener (tests bind `127.0.0.1:0` and keep
/// the port). Returns after a `shutdown` request drains the service.
pub fn serve_listener(listener: TcpListener, cfg: &ServiceConfig) -> Result<(), ServiceError> {
    let local = listener.local_addr().map_err(|e| ServiceError::Io(e.to_string()))?;
    let service = Arc::new(Service::try_start(cfg)?);
    let stop = Arc::new(AtomicBool::new(false));
    #[cfg(unix)]
    {
        // Graceful SIGTERM: stop accepting, drain with checkpoints (the
        // journal keeps interrupted jobs resumable), unblock the accept
        // loop, exit. The watcher also exits quietly once the server
        // stops for any other reason.
        sigterm::install();
        let stop = Arc::clone(&stop);
        let service = Arc::clone(&service);
        std::thread::spawn(move || loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            if sigterm::received() {
                eprintln!("spartan serve: SIGTERM — draining (running fits stay resumable)");
                stop.store(true, Ordering::SeqCst);
                service.shutdown_draining();
                let _ = TcpStream::connect(local);
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(100));
        });
    }
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let service = Arc::clone(&service);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || handle_conn(stream, &service, &stop, local));
    }
    service.shutdown();
    Ok(())
}

fn handle_conn(stream: TcpStream, service: &Service, stop: &AtomicBool, local: SocketAddr) {
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        let (resp, quit) = dispatch(service, line.trim());
        if writeln!(writer, "{}", resp.to_string()).is_err() || writer.flush().is_err() {
            return;
        }
        if quit {
            stop.store(true, Ordering::SeqCst);
            service.shutdown();
            // Unblock the accept loop so serve_listener observes `stop`.
            let _ = TcpStream::connect(local);
            return;
        }
    }
}

/// One request line → (response, stop-the-server?).
fn dispatch(service: &Service, line: &str) -> (Json, bool) {
    let req = match json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            return (error_to_response(&ServiceError::Protocol(format!("bad request: {e}"))), false)
        }
    };
    let verb = req.get("verb").and_then(Json::as_str).unwrap_or("");
    let resp = match verb {
        "ping" => Ok(ok_response(vec![("service", Json::str("spartan"))])),
        "submit" => handle_submit(service, &req),
        "status" => req_id(&req)
            .and_then(|id| service.status(id))
            .map(|s| merge_ok(status_to_json(&s))),
        "cancel" => req_id(&req)
            .and_then(|id| service.cancel(id))
            .map(|s| merge_ok(status_to_json(&s))),
        "result" => handle_result(service, &req),
        "shutdown" => {
            return (ok_response(vec![("stopping", Json::Bool(true))]), true);
        }
        other => Err(ServiceError::Protocol(format!("unknown verb `{other}`"))),
    };
    match resp {
        Ok(j) => (j, false),
        Err(e) => (error_to_response(&e), false),
    }
}

fn req_id(req: &Json) -> Result<u64, ServiceError> {
    req.get("id")
        .and_then(Json::as_f64)
        .map(|x| x as u64)
        .ok_or_else(|| ServiceError::Protocol("missing job `id`".into()))
}

fn merge_ok(body: Json) -> Json {
    match body {
        Json::Obj(mut m) => {
            m.insert("ok".into(), Json::Bool(true));
            Json::Obj(m)
        }
        other => ok_response(vec![("body", other)]),
    }
}

fn handle_submit(service: &Service, req: &Json) -> Result<Json, ServiceError> {
    let input = req
        .get("input")
        .and_then(Json::as_str)
        .ok_or_else(|| ServiceError::Protocol("submit requires `input`".into()))?;
    let rank = req
        .get("rank")
        .and_then(Json::as_usize)
        .ok_or_else(|| ServiceError::Protocol("submit requires `rank`".into()))?;
    let data = load_tensor(input)?;
    // Defaults mirror `spartan decompose` (one shared Parafac2Config
    // default), so a submit with the same options reproduces it bitwise.
    let mut cfg = Parafac2Config { rank, ..Default::default() };
    if let Some(n) = req.get("max_iters").and_then(Json::as_usize) {
        cfg.max_iters = n;
    }
    if let Some(t) = req.get("tol").and_then(Json::as_f64) {
        cfg.tol = t;
    }
    if let Some(b) = req.get("nonneg").and_then(Json::as_bool) {
        cfg.nonneg = b;
    }
    if let Some(s) = req.get("seed").and_then(Json::as_f64) {
        cfg.seed = s as u64;
    }
    if let Some(e) = req.get("engine").and_then(Json::as_str) {
        cfg.backend = Backend::parse(e)
            .ok_or_else(|| ServiceError::Invalid(format!("unknown engine `{e}`")))?;
    }
    let cohort = req.get("cohort").and_then(Json::as_str).map(str::to_string);
    // Optional sharding: an array of `spartan shard-worker` addresses.
    // The dataset path the workers load is `input` itself (shared
    // filesystem, same convention as the local load above).
    let shards = match req.get("shards").and_then(Json::as_arr) {
        Some(arr) if !arr.is_empty() => {
            let addrs = arr
                .iter()
                .map(|a| a.as_str().map(str::to_string))
                .collect::<Option<Vec<String>>>()
                .ok_or_else(|| {
                    ServiceError::Protocol("`shards` must be an array of addresses".into())
                })?;
            let mut spec = super::shard::ShardSpec::new(addrs, input);
            spec.validate().map_err(ServiceError::Invalid)?;
            // Optional recovery policy overrides (defaults mirror the
            // `--shard-retries`/`--shard-backoff-ms` CLI defaults).
            if let Some(n) = req.get("shard_retries").and_then(Json::as_f64) {
                spec.max_retries = n as u32;
            }
            if let Some(ms) = req.get("shard_backoff_ms").and_then(Json::as_f64) {
                spec.backoff_ms = ms as u64;
            }
            Some(spec)
        }
        _ => None,
    };
    let id = service.submit(JobSpec {
        cohort,
        shards,
        source: Some(input.to_string()),
        ..JobSpec::new(data, cfg)
    })?;
    Ok(ok_response(vec![("id", Json::num(id as f64))]))
}

fn handle_result(service: &Service, req: &Json) -> Result<Json, ServiceError> {
    let id = req_id(req)?;
    let state = service.status(id)?.state;
    match service.result(id)? {
        Some(model) => Ok(ok_response(vec![
            ("ready", Json::Bool(true)),
            ("state", Json::str(state.as_str())),
            ("model", model_to_json(&model)),
        ])),
        None => Ok(ok_response(vec![
            ("ready", Json::Bool(false)),
            ("state", Json::str(state.as_str())),
        ])),
    }
}

pub(crate) fn load_tensor(path: &str) -> Result<IrregularTensor, ServiceError> {
    let p = std::path::Path::new(path);
    let loaded = if p.extension().map_or(false, |e| e == "txt") {
        crate::sparse::io::load_triplets_text(p)
    } else {
        crate::sparse::io::load_binary(p)
    };
    loaded.map_err(|e| ServiceError::InvalidData(format!("loading {path}: {e}")))
}

/// Process-wide SIGTERM latch, installed by [`serve_listener`]. Uses the
/// C `signal` symbol libstd already links — no new dependency — and only
/// flips an `AtomicBool` in the handler (the async-signal-safe subset);
/// the watcher thread does all real work outside signal context.
#[cfg(unix)]
mod sigterm {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
        }
    }

    pub fn received() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

// ---------------------------------------------------------------------------
// Blocking client

/// One request / one response over a fresh connection.
pub fn request(addr: &str, req: &Json) -> Result<Json, ServiceError> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| ServiceError::Io(format!("connect {addr}: {e}")))?;
    let mut writer = BufWriter::new(
        stream.try_clone().map_err(|e| ServiceError::Io(e.to_string()))?,
    );
    writeln!(writer, "{}", req.to_string()).map_err(|e| ServiceError::Io(e.to_string()))?;
    writer.flush().map_err(|e| ServiceError::Io(e.to_string()))?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| ServiceError::Io(e.to_string()))?;
    if line.trim().is_empty() {
        return Err(ServiceError::Io("server closed the connection".into()));
    }
    let resp = json::parse(line.trim()).map_err(ServiceError::Protocol)?;
    if resp.get("ok").and_then(Json::as_bool) == Some(true) {
        Ok(resp)
    } else {
        Err(error_from_response(&resp))
    }
}

pub fn ping(addr: &str) -> bool {
    request(addr, &Json::obj(vec![("verb", Json::str("ping"))])).is_ok()
}

/// Options for a client-side submit (server-side defaults apply to every
/// `None`, mirroring `spartan decompose`).
#[derive(Clone, Debug, Default)]
pub struct SubmitRequest {
    pub input: String,
    pub rank: usize,
    pub max_iters: Option<usize>,
    pub tol: Option<f64>,
    pub nonneg: Option<bool>,
    pub seed: Option<u64>,
    pub engine: Option<String>,
    pub cohort: Option<String>,
    /// Shard-worker addresses; non-empty runs the job as a sharded
    /// coordinator over them (dataset path = `input` on every worker).
    pub shards: Vec<String>,
    /// Reconnect attempts per lost-shard incident (see
    /// `shard::ShardSpec::max_retries`); `None` keeps the server default.
    pub shard_retries: Option<u32>,
    /// Base backoff delay in ms between reconnect attempts (see
    /// `shard::ShardSpec::backoff_ms`); `None` keeps the server default.
    pub shard_backoff_ms: Option<u64>,
}

pub fn submit(addr: &str, req: &SubmitRequest) -> Result<u64, ServiceError> {
    let mut fields = vec![
        ("verb", Json::str("submit")),
        ("input", Json::str(req.input.clone())),
        ("rank", Json::num(req.rank as f64)),
    ];
    if let Some(n) = req.max_iters {
        fields.push(("max_iters", Json::num(n as f64)));
    }
    if let Some(t) = req.tol {
        fields.push(("tol", Json::num(t)));
    }
    if let Some(b) = req.nonneg {
        fields.push(("nonneg", Json::Bool(b)));
    }
    if let Some(s) = req.seed {
        fields.push(("seed", Json::num(s as f64)));
    }
    if let Some(e) = &req.engine {
        fields.push(("engine", Json::str(e.clone())));
    }
    if let Some(c) = &req.cohort {
        fields.push(("cohort", Json::str(c.clone())));
    }
    if !req.shards.is_empty() {
        fields.push(("shards", Json::arr(req.shards.iter().map(|a| Json::str(a.clone())))));
    }
    if let Some(n) = req.shard_retries {
        fields.push(("shard_retries", Json::num(n as f64)));
    }
    if let Some(ms) = req.shard_backoff_ms {
        fields.push(("shard_backoff_ms", Json::num(ms as f64)));
    }
    let resp = request(addr, &Json::obj(fields))?;
    resp.get("id")
        .and_then(Json::as_f64)
        .map(|x| x as u64)
        .ok_or_else(|| ServiceError::Protocol("submit response missing id".into()))
}

/// Raw status body (`state`, `iterations`, `records`, …).
pub fn status(addr: &str, id: u64) -> Result<Json, ServiceError> {
    request(
        addr,
        &Json::obj(vec![("verb", Json::str("status")), ("id", Json::num(id as f64))]),
    )
}

/// Snapshot at token-set time (its `iterations` anchors the
/// within-one-iteration cancellation guarantee).
pub fn cancel(addr: &str, id: u64) -> Result<Json, ServiceError> {
    request(
        addr,
        &Json::obj(vec![("verb", Json::str("cancel")), ("id", Json::num(id as f64))]),
    )
}

/// `Ok(None)` while the job is still in flight; the decoded (bit-exact)
/// model once terminal. Failed jobs surface [`ServiceError::JobFailed`].
pub fn result(addr: &str, id: u64) -> Result<Option<Parafac2Model>, ServiceError> {
    let resp = request(
        addr,
        &Json::obj(vec![("verb", Json::str("result")), ("id", Json::num(id as f64))]),
    )?;
    if resp.get("ready").and_then(Json::as_bool) != Some(true) {
        return Ok(None);
    }
    let mj = resp.get("model").ok_or_else(|| {
        ServiceError::Protocol("ready result missing model".into())
    })?;
    crate::service::protocol::model_from_json(mj)
        .map(Some)
        .map_err(ServiceError::Protocol)
}

/// Ask the daemon to stop (drains in-flight jobs via cancellation).
pub fn shutdown(addr: &str) -> Result<(), ServiceError> {
    request(addr, &Json::obj(vec![("verb", Json::str("shutdown"))])).map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::synthetic::{generate, SyntheticSpec};
    use crate::parafac2::fit_parafac2;

    #[test]
    fn wire_roundtrip_submit_status_result_shutdown() {
        let data = generate(&SyntheticSpec {
            k: 16,
            j: 10,
            max_i_k: 6,
            target_nnz: 600,
            rank: 2,
            noise: 0.05,
            seed: 3,
        })
        .tensor;
        let dir = std::env::temp_dir()
            .join(format!("spartan_server_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wire.spt");
        crate::sparse::io::save_binary(&data, &path).unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let svc_cfg = ServiceConfig { workers: 1, ..Default::default() };
        let server = std::thread::spawn(move || serve_listener(listener, &svc_cfg));

        assert!(ping(&addr));
        let req = SubmitRequest {
            input: path.to_string_lossy().into_owned(),
            rank: 2,
            max_iters: Some(4),
            seed: Some(42),
            ..Default::default()
        };
        let id = submit(&addr, &req).unwrap();
        // poll over the wire until terminal
        let model = loop {
            if let Some(m) = result(&addr, id).unwrap() {
                break m;
            }
            std::thread::yield_now();
        };
        let st = status(&addr, id).unwrap();
        assert_eq!(st.get("state").and_then(Json::as_str), Some("done"));
        assert_eq!(
            st.get("iterations").and_then(Json::as_usize),
            Some(model.stats.iterations)
        );

        // the fetched model is bit-identical to a direct in-process fit
        let cfg = crate::parafac2::Parafac2Config {
            rank: 2,
            max_iters: 4,
            ..Default::default()
        };
        let direct = fit_parafac2(&data, &cfg).unwrap();
        assert_eq!(model.h.data(), direct.h.data());
        assert_eq!(model.v.data(), direct.v.data());
        assert_eq!(model.w.data(), direct.w.data());
        assert_eq!(model.stats.final_sse.to_bits(), direct.stats.final_sse.to_bits());

        // structured errors cross the wire typed
        assert!(matches!(status(&addr, 999), Err(ServiceError::UnknownJob(999))));

        shutdown(&addr).unwrap();
        server.join().unwrap().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
