//! Durable fit checkpoints: the on-disk state a crashed coordinator (or
//! daemon) resumes from, **bitwise**.
//!
//! A checkpoint is one JSON document capturing everything iteration `i`'s
//! boundary determines: the factor iterate `H`/`V`/`W`, the loop state
//! ([`ResumeState`]: `prev_sse` bits, convergence flag, fit history,
//! spent counters/timings), the full [`Parafac2Config`], the kernel
//! backend the trajectory ran on, the dataset path, the per-slice
//! `‖X_k‖²` bits (the data-identity contract — a resume re-packs the
//! arena and insists these match bit-for-bit, exactly like the shard
//! `reattach` verb), and for sharded fits the shard layout. Every
//! trajectory-relevant float travels as 16-hex-digit IEEE-754 bits via
//! the [`crate::service::protocol`] helpers — JSON decimal syntax never
//! touches them; only wall-clock timings are plain numbers.
//!
//! Files are committed with [`crate::util::atomicfile::write_atomic`]
//! (write-temp → fsync → rename), so a crash mid-write leaves either the
//! previous complete checkpoint or the new one — never a torn file. A
//! torn or truncated file handed to [`load_checkpoint`] fails JSON
//! parsing or field validation and is rejected with a structured
//! [`ServiceError::InvalidData`], never silently refit. The normative
//! file-format spec lives in `docs/PROTOCOL.md` § checkpoint files.

use crate::linalg::Mat;
use crate::parafac2::init::InitMethod;
use crate::parafac2::{Backend, Parafac2Config, ResumeState};
use crate::service::protocol::{
    f64_from_bits_str, f64_list_from_json, f64_list_to_json, f64_to_bits_str, mat_from_json,
    mat_to_json,
};
use crate::service::shard::ShardSpec;
use crate::service::ServiceError;
use crate::util::atomicfile::write_atomic;
use crate::util::json::{self, Json};
use std::path::Path;

/// Identifies a checkpoint document (vs any other JSON lying around).
pub const CHECKPOINT_FORMAT: &str = "spartan-checkpoint";

/// Schema version; bump on any change to the checkpoint layout. A loader
/// at a different version rejects the file loudly — resuming through a
/// misread schema could corrupt the bitwise contract.
pub const CHECKPOINT_VERSION: u64 = 1;

/// Where a sharded fit's workers were, plus the retry policy — enough to
/// rebuild the [`ShardSpec`] (the dataset path is stored once, top-level,
/// shared with the local-resume path).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardLayout {
    pub addrs: Vec<String>,
    pub max_retries: u32,
    pub backoff_ms: u64,
    pub read_timeout_secs: u64,
}

impl ShardLayout {
    pub fn from_spec(spec: &ShardSpec) -> ShardLayout {
        ShardLayout {
            addrs: spec.addrs.clone(),
            max_retries: spec.max_retries,
            backoff_ms: spec.backoff_ms,
            read_timeout_secs: spec.read_timeout_secs,
        }
    }

    /// Rebuild the spec for a resume. `path` comes from the checkpoint's
    /// top-level `input` (or a caller override).
    pub fn to_spec(&self, path: impl Into<String>) -> ShardSpec {
        ShardSpec {
            addrs: self.addrs.clone(),
            path: path.into(),
            read_timeout_secs: self.read_timeout_secs,
            max_retries: self.max_retries,
            backoff_ms: self.backoff_ms,
        }
    }
}

/// One durable checkpoint (see the module docs for what each part pins).
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Dataset path the fit was (and the resume must be) packed from.
    pub input: String,
    pub cfg: Parafac2Config,
    /// Kernel backend name the trajectory ran on. A resume requires exact
    /// equality — the same rule the shard `hello` handshake enforces — so
    /// a checkpoint from an `avx2` box never continues on `avx512` bits.
    pub kernel_backend: String,
    pub h: Mat,
    pub v: Mat,
    pub w: Mat,
    /// Loop state at the boundary (iter, prev_sse bits, history,
    /// counters).
    pub state: ResumeState,
    /// Per-slice `‖X_k‖²`, flat in subject order — the data-identity
    /// bits a resume revalidates against the re-packed arena.
    pub x_norm_bits: Vec<f64>,
    /// Present iff the fit was sharded.
    pub shards: Option<ShardLayout>,
}

fn init_name(init: InitMethod) -> &'static str {
    match init {
        InitMethod::Random => "random",
        InitMethod::SvdWarm => "svd-warm",
    }
}

fn backend_name(backend: Backend) -> &'static str {
    match backend {
        Backend::Spartan => "spartan",
        Backend::Baseline => "baseline",
    }
}

pub(crate) fn config_to_json(cfg: &Parafac2Config) -> Json {
    let mut fields = vec![
        ("rank", Json::num(cfg.rank as f64)),
        ("max_iters", Json::num(cfg.max_iters as f64)),
        // tol feeds `sse_converged` — it must survive exactly.
        ("tol_bits", f64_to_bits_str(cfg.tol)),
        ("nonneg", Json::Bool(cfg.nonneg)),
        ("init", Json::str(init_name(cfg.init))),
        ("workers", Json::num(cfg.workers as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("backend", Json::str(backend_name(cfg.backend))),
    ];
    if let Some(b) = cfg.mem_budget {
        fields.push(("mem_budget", Json::num(b as f64)));
    }
    Json::obj(fields)
}

pub(crate) fn config_from_json(j: &Json) -> Result<Parafac2Config, String> {
    let usize_of = |k: &str| j.get(k).and_then(Json::as_usize).ok_or(format!("config missing {k}"));
    let init_s = j.get("init").and_then(Json::as_str).ok_or("config missing init")?;
    let backend_s = j.get("backend").and_then(Json::as_str).ok_or("config missing backend")?;
    Ok(Parafac2Config {
        rank: usize_of("rank")?,
        max_iters: usize_of("max_iters")?,
        tol: f64_from_bits_str(j.get("tol_bits").ok_or("config missing tol_bits")?)?,
        nonneg: j.get("nonneg").and_then(Json::as_bool).ok_or("config missing nonneg")?,
        init: InitMethod::parse(init_s).ok_or_else(|| format!("bad init `{init_s}`"))?,
        workers: usize_of("workers")?,
        seed: j.get("seed").and_then(Json::as_f64).ok_or("config missing seed")? as u64,
        backend: Backend::parse(backend_s).ok_or_else(|| format!("bad backend `{backend_s}`"))?,
        mem_budget: j.get("mem_budget").and_then(Json::as_f64).map(|b| b as u64),
    })
}

pub(crate) fn shards_to_json(s: &ShardLayout) -> Json {
    Json::obj(vec![
        ("addrs", Json::arr(s.addrs.iter().map(|a| Json::str(a.clone())))),
        ("max_retries", Json::num(s.max_retries as f64)),
        ("backoff_ms", Json::num(s.backoff_ms as f64)),
        ("read_timeout_secs", Json::num(s.read_timeout_secs as f64)),
    ])
}

pub(crate) fn shards_from_json(j: &Json) -> Result<ShardLayout, String> {
    let addrs = j
        .get("addrs")
        .and_then(Json::as_arr)
        .ok_or("shards missing addrs")?
        .iter()
        .map(|a| a.as_str().map(str::to_string).ok_or("bad shard addr"))
        .collect::<Result<Vec<String>, _>>()?;
    let num = |k: &str| j.get(k).and_then(Json::as_f64).ok_or(format!("shards missing {k}"));
    Ok(ShardLayout {
        addrs,
        max_retries: num("max_retries")? as u32,
        backoff_ms: num("backoff_ms")? as u64,
        read_timeout_secs: num("read_timeout_secs")? as u64,
    })
}

pub fn checkpoint_to_json(c: &Checkpoint) -> Json {
    let s = &c.state;
    let mut fields = vec![
        ("format", Json::str(CHECKPOINT_FORMAT)),
        ("version", Json::num(CHECKPOINT_VERSION as f64)),
        ("input", Json::str(c.input.clone())),
        ("kernel_backend", Json::str(c.kernel_backend.clone())),
        ("config", config_to_json(&c.cfg)),
        ("iter", Json::num(s.iter as f64)),
        ("converged", Json::Bool(s.converged)),
        ("prev_sse_bits", f64_to_bits_str(f64::from_bits(s.prev_sse_bits))),
        ("fit_history_bits", f64_list_to_json(&s.fit_history)),
        ("h", mat_to_json(&c.h)),
        ("v", mat_to_json(&c.v)),
        ("w", mat_to_json(&c.w)),
        ("x_norm_bits", f64_list_to_json(&c.x_norm_bits)),
        (
            "counters",
            Json::obj(vec![
                ("yv_products", Json::num(s.yv_products as f64)),
                ("traversals", Json::num(s.traversals as f64)),
                ("x_traversals", Json::num(s.x_traversals as f64)),
                ("shard_reconnects", Json::num(s.shard_reconnects as f64)),
                ("shard_retries", Json::num(s.shard_retries as f64)),
                ("procrustes_secs", Json::num(s.procrustes_secs)),
                ("cp_secs", Json::num(s.cp_secs)),
                ("total_secs", Json::num(s.total_secs)),
            ]),
        ),
    ];
    if let Some(sh) = &c.shards {
        fields.push(("shards", shards_to_json(sh)));
    }
    Json::obj(fields)
}

pub fn checkpoint_from_json(j: &Json) -> Result<Checkpoint, String> {
    match j.get("format").and_then(Json::as_str) {
        Some(CHECKPOINT_FORMAT) => {}
        Some(f) => return Err(format!("not a checkpoint (format `{f}`)")),
        None => return Err("not a checkpoint (missing format)".into()),
    }
    match j.get("version").and_then(Json::as_f64).map(|v| v as u64) {
        Some(CHECKPOINT_VERSION) => {}
        Some(v) => {
            return Err(format!(
                "checkpoint version {v} (this build reads {CHECKPOINT_VERSION})"
            ))
        }
        None => return Err("checkpoint missing version".into()),
    }
    let input = j.get("input").and_then(Json::as_str).ok_or("checkpoint missing input")?;
    let kernel_backend = j
        .get("kernel_backend")
        .and_then(Json::as_str)
        .ok_or("checkpoint missing kernel_backend")?;
    let cfg = config_from_json(j.get("config").ok_or("checkpoint missing config")?)?;
    let iter = j.get("iter").and_then(Json::as_usize).ok_or("checkpoint missing iter")?;
    let converged =
        j.get("converged").and_then(Json::as_bool).ok_or("checkpoint missing converged")?;
    let prev_sse_bits =
        f64_from_bits_str(j.get("prev_sse_bits").ok_or("checkpoint missing prev_sse_bits")?)?
            .to_bits();
    let fit_history = f64_list_from_json(
        j.get("fit_history_bits").ok_or("checkpoint missing fit_history_bits")?,
    )?;
    let h = mat_from_json(j.get("h").ok_or("checkpoint missing h")?)?;
    let v = mat_from_json(j.get("v").ok_or("checkpoint missing v")?)?;
    let w = mat_from_json(j.get("w").ok_or("checkpoint missing w")?)?;
    let x_norm_bits =
        f64_list_from_json(j.get("x_norm_bits").ok_or("checkpoint missing x_norm_bits")?)?;
    let cj = j.get("counters").ok_or("checkpoint missing counters")?;
    let cnum = |k: &str| cj.get(k).and_then(Json::as_f64).ok_or(format!("counters missing {k}"));
    let state = ResumeState {
        iter,
        prev_sse_bits,
        converged,
        fit_history,
        yv_products: cnum("yv_products")? as u64,
        traversals: cnum("traversals")? as u64,
        x_traversals: cnum("x_traversals")? as u64,
        procrustes_secs: cnum("procrustes_secs")?,
        cp_secs: cnum("cp_secs")?,
        total_secs: cnum("total_secs")?,
        shard_reconnects: cnum("shard_reconnects")? as u64,
        shard_retries: cnum("shard_retries")? as u64,
    };
    let shards = match j.get("shards") {
        Some(sj) => Some(shards_from_json(sj)?),
        None => None,
    };

    // Structural validation — a checkpoint that passes decodes into a
    // self-consistent boundary; anything else is a torn/corrupt file.
    let r = cfg.rank;
    if h.shape() != (r, r) || v.cols() != r || w.cols() != r {
        return Err(format!(
            "checkpoint factor shapes {:?}/{:?}/{:?} do not match rank {r}",
            h.shape(),
            v.shape(),
            w.shape()
        ));
    }
    if w.rows() != x_norm_bits.len() {
        return Err(format!(
            "checkpoint W has {} rows but {} slice norms",
            w.rows(),
            x_norm_bits.len()
        ));
    }
    if state.fit_history.len() != iter {
        return Err(format!(
            "checkpoint fit_history has {} entries at iteration {iter}",
            state.fit_history.len()
        ));
    }
    Ok(Checkpoint {
        input: input.to_string(),
        cfg,
        kernel_backend: kernel_backend.to_string(),
        h,
        v,
        w,
        state,
        x_norm_bits,
        shards,
    })
}

/// Commit a checkpoint to `path` atomically (write-temp → fsync →
/// rename): a crash at any instant leaves the previous complete
/// checkpoint or the new one, never a torn file.
pub fn save_checkpoint(path: &Path, c: &Checkpoint) -> Result<(), ServiceError> {
    let mut text = checkpoint_to_json(c).pretty();
    text.push('\n');
    write_atomic(path, text.as_bytes())
        .map_err(|e| ServiceError::Io(format!("writing checkpoint {}: {e}", path.display())))
}

/// Load and validate a checkpoint. Unreadable files are [`ServiceError::
/// Io`]; anything that parses or validates wrong — including a torn
/// partial write — is a structured [`ServiceError::InvalidData`].
pub fn load_checkpoint(path: &Path) -> Result<Checkpoint, ServiceError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| ServiceError::Io(format!("reading checkpoint {}: {e}", path.display())))?;
    let parsed = json::parse(&text).map_err(|e| {
        ServiceError::InvalidData(format!("checkpoint {}: not valid JSON: {e}", path.display()))
    })?;
    checkpoint_from_json(&parsed)
        .map_err(|e| ServiceError::InvalidData(format!("checkpoint {}: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(shards: bool) -> Checkpoint {
        Checkpoint {
            input: "/tmp/data dir/run 7.spt".into(),
            cfg: Parafac2Config {
                rank: 2,
                max_iters: 9,
                tol: 1e-7,
                nonneg: false,
                init: InitMethod::SvdWarm,
                workers: 3,
                seed: 99,
                backend: Backend::Spartan,
                mem_budget: Some(1 << 30),
            },
            kernel_backend: "blocked".into(),
            h: Mat::from_vec(2, 2, vec![0.1 + 0.2, -0.0, 5e-324, 1.0 / 3.0]),
            v: Mat::from_vec(3, 2, vec![1.5, -2.5, f64::MIN_POSITIVE, 0.0, 6.02e23, -1e-300]),
            w: Mat::from_vec(2, 2, vec![0.25, 0.5, 0.75, 1.0]),
            state: ResumeState {
                iter: 2,
                prev_sse_bits: (42.125f64).to_bits(),
                converged: false,
                fit_history: vec![0.5, 0.75],
                yv_products: 18,
                traversals: 18,
                x_traversals: 27,
                procrustes_secs: 0.125,
                cp_secs: 0.25,
                total_secs: 0.5,
                shard_reconnects: 1,
                shard_retries: 2,
            },
            x_norm_bits: vec![3.25, -0.0],
            shards: if shards {
                Some(ShardLayout {
                    addrs: vec!["127.0.0.1:9001".into(), "127.0.0.1:9002".into()],
                    max_retries: 5,
                    backoff_ms: 100,
                    read_timeout_secs: 30,
                })
            } else {
                None
            },
        }
    }

    #[test]
    fn roundtrip_is_bitwise_local_and_sharded() {
        for shards in [false, true] {
            let c = sample(shards);
            let text = checkpoint_to_json(&c).to_string();
            let back = checkpoint_from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.input, c.input);
            assert_eq!(back.kernel_backend, c.kernel_backend);
            assert_eq!(back.cfg.rank, c.cfg.rank);
            assert_eq!(back.cfg.tol.to_bits(), c.cfg.tol.to_bits());
            assert_eq!(back.cfg.init, c.cfg.init);
            assert_eq!(back.cfg.mem_budget, c.cfg.mem_budget);
            for (m, bm) in [(&c.h, &back.h), (&c.v, &back.v), (&c.w, &back.w)] {
                for (a, b) in m.data().iter().zip(bm.data()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            assert_eq!(back.state.iter, c.state.iter);
            assert_eq!(back.state.prev_sse_bits, c.state.prev_sse_bits);
            for (a, b) in back.state.fit_history.iter().zip(&c.state.fit_history) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in back.x_norm_bits.iter().zip(&c.x_norm_bits) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(back.state.yv_products, c.state.yv_products);
            assert_eq!(back.state.x_traversals, c.state.x_traversals);
            assert_eq!(back.shards, c.shards);
        }
    }

    #[test]
    fn save_load_roundtrip_and_torn_file_rejection() {
        let dir = std::env::temp_dir().join(format!("spartan_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fit.ckpt");
        let c = sample(true);
        save_checkpoint(&path, &c).unwrap();
        let back = load_checkpoint(&path).unwrap();
        assert_eq!(back.state.iter, c.state.iter);
        assert_eq!(back.h.data(), c.h.data());

        // Every strict prefix of the committed document must be rejected
        // (the atomic commit makes torn files impossible, but a loader
        // must still never trust one from a foreign writer).
        let full = std::fs::read(&path).unwrap();
        let torn = dir.join("torn.ckpt");
        for frac in [1, 3, 7, 9] {
            let cut = full.len() * frac / 10;
            std::fs::write(&torn, &full[..cut]).unwrap();
            match load_checkpoint(&torn) {
                Err(ServiceError::InvalidData(_)) => {}
                other => panic!("torn prefix ({cut} bytes) accepted: {:?}", other.map(|_| ())),
            }
        }
        std::fs::remove_file(&torn).ok();
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir(&dir).ok();
    }

    #[test]
    fn version_and_format_gates_reject_foreign_documents() {
        let c = sample(false);
        let good = checkpoint_to_json(&c);
        // wrong format marker
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("format".into(), Json::str("other"));
        }
        assert!(checkpoint_from_json(&j).unwrap_err().contains("not a checkpoint"));
        // future version
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::num((CHECKPOINT_VERSION + 1) as f64));
        }
        assert!(checkpoint_from_json(&j).unwrap_err().contains("version"));
        // inconsistent boundary: history length ≠ iter
        let mut j = good;
        if let Json::Obj(m) = &mut j {
            m.insert("iter".into(), Json::num(5.0));
        }
        assert!(checkpoint_from_json(&j).unwrap_err().contains("fit_history"));
    }
}
