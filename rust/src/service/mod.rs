//! The resident fit service: many concurrent PARAFAC2 fits on one
//! shared worker pool, with membudget admission and warm-started re-fits.
//!
//! A [`Service`] is what `spartan serve` runs behind the wire protocol
//! (see [`server`]), but it is a plain library type — tests and embedders
//! drive it in-process. It owns:
//!
//! * **one shared [`Pool`]** — every job's `ChunkPlan` is scheduled onto
//!   the same workers (the pool's FIFO job queue interleaves chunk grants
//!   across jobs; subjects never shard across jobs, so each fit stays
//!   bitwise identical to running alone — pinned by
//!   `concurrent_jobs_bitwise_equal_standalone` in [`crate::threadpool`]
//!   and end-to-end by `rust/tests/service_e2e.rs`);
//! * **one shared [`MemBudget`]** — admission is *enforced*, not
//!   advisory: a job's arena estimate (`data.heap_bytes()` +
//!   [`CompactX::estimate_heap_bytes`]) is charged via
//!   [`crate::util::membudget::SharedCharge`] inside the
//!   [`FitSession`], so a submit whose estimate can never fit is rejected
//!   up front ([`ServiceError::BudgetExceeded`]) and one that merely
//!   does not fit *right now* queues until running jobs release;
//! * **a job registry** — submit / status / cancel / result over
//!   monotonically increasing job ids, with per-iteration
//!   [`IterationRecord`] progress;
//! * **a bounded FIFO queue** — at most `max_pending` jobs waiting
//!   ([`ServiceError::QueueFull`] beyond that), drained strictly in
//!   order by a scheduler thread;
//! * **a warm-model cache** ([`warm::WarmCache`]) keyed by cohort id —
//!   a submit naming a cohort warm-starts from that cohort's previous
//!   `H/V/W` when the shapes match, skipping init entirely.
//!
//! Scheduling admits **one job into session construction at a time**
//! (the `starting` latch): the arena pack is the only moment a job's
//! charge races another admission decision, so serializing construction
//! makes the headroom check sound without double-charging. Fits
//! themselves run fully concurrently, one OS thread per running job,
//! all sharing the pool's workers.
//!
//! Cancellation sets the session's cancel flag; the running fit observes
//! it at the next iteration boundary (within one ALS iteration — the
//! engine checkpoints at step entry and between sweeps) and concludes
//! with a partial model at the last completed iterate.
//!
//! Determinism contract: a job submitted **without** a cohort id (or
//! missing the cache) runs exactly the batch fit — same init, same
//! trajectory, bitwise — regardless of what else the service is doing.
//! Naming a cohort opts into warm-starting, which by design changes the
//! trajectory; omit it for runs that must reproduce `spartan decompose`.

pub mod checkpoint;
pub mod journal;
pub mod protocol;
pub mod server;
pub mod shard;
pub mod warm;

use crate::parafac2::{
    DataHandle, FitSession, IterationRecord, Parafac2Config, Parafac2Model, SessionOptions,
    StepOutcome, WarmStart,
};
use crate::sparse::{CompactX, IrregularTensor};
use crate::threadpool::Pool;
use crate::util::membudget::MemBudget;
use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

// ---------------------------------------------------------------------------
// Errors

/// Structured failures of the service API (satellite of the job-level
/// [`crate::parafac2::FitError`], which surfaces as [`JobState::Failed`]).
#[derive(Clone, Debug, PartialEq)]
pub enum ServiceError {
    /// The pending queue is at capacity; resubmit later.
    QueueFull { pending: usize, max: usize },
    /// The job's arena estimate exceeds the budget limit outright — it
    /// could never run, so it is rejected at submit instead of queued.
    BudgetExceeded { estimate: u64, limit: u64 },
    /// No job with that id.
    UnknownJob(u64),
    /// The job ran and failed; `reason` is the fit error's rendering.
    JobFailed { id: u64, reason: String },
    /// Invalid submission (rank bounds, empty data, bad options).
    Invalid(String),
    /// The data itself is unusable: malformed on disk (non-finite
    /// values, non-monotone `row_ptr`), or it no longer matches what a
    /// checkpoint/reattach recorded (`‖X_k‖²` bits diverge). Rejected
    /// with structure before any fitting — never silently refit.
    InvalidData(String),
    /// The service is shutting down and no longer accepts jobs.
    ShuttingDown,
    /// A shard worker died mid-fit (connection refused, EOF, read
    /// timeout, or a structured error from the worker): the coordinator
    /// aborts the remaining shards and surfaces which one was lost.
    ShardLost(String),
    /// Client-side transport failure (connect/read/write).
    Io(String),
    /// Malformed request or response on the wire.
    Protocol(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::QueueFull { pending, max } => {
                write!(f, "queue full: {pending} job(s) pending (max {max})")
            }
            ServiceError::BudgetExceeded { estimate, limit } => write!(
                f,
                "memory budget exceeded: job needs an estimated {} but the budget limit is {}",
                crate::util::humansize::bytes(*estimate),
                crate::util::humansize::bytes(*limit),
            ),
            ServiceError::UnknownJob(id) => write!(f, "unknown job id {id}"),
            ServiceError::JobFailed { id, reason } => write!(f, "job {id} failed: {reason}"),
            ServiceError::Invalid(msg) => write!(f, "invalid submission: {msg}"),
            ServiceError::InvalidData(msg) => write!(f, "invalid data: {msg}"),
            ServiceError::ShuttingDown => write!(f, "service is shutting down"),
            ServiceError::ShardLost(msg) => write!(f, "shard lost: {msg}"),
            ServiceError::Io(msg) => write!(f, "service i/o error: {msg}"),
            ServiceError::Protocol(msg) => write!(f, "service protocol error: {msg}"),
        }
    }
}

impl std::error::Error for ServiceError {}

// ---------------------------------------------------------------------------
// Configuration & job types

/// How to stand up a [`Service`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Shared pool size (0 ⇒ all cores, [`Pool::new`] semantics).
    pub workers: usize,
    /// Shared memory budget in bytes (`None` ⇒ accounting only).
    pub mem_budget: Option<u64>,
    /// Max jobs waiting in the queue (running jobs don't count).
    pub max_pending: usize,
    /// Warm-model cache capacity in cohorts (0 disables warm-starting).
    pub warm_cache: usize,
    /// Durable-journal directory (`None` disables journaling). When set,
    /// every job submitted **with a dataset path** appends lifecycle
    /// records and per-iteration checkpoints under this directory (see
    /// [`journal`]), and [`Service::try_start`] replays it on boot:
    /// persisted results are restored, unfinished jobs are re-admitted
    /// and resumed from their last checkpoint, bitwise.
    pub journal: Option<PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            workers: 0,
            mem_budget: None,
            max_pending: 16,
            warm_cache: 8,
            journal: None,
        }
    }
}

/// One fit job: the (owned) data, the fit config, and an optional cohort
/// id for warm-start caching. `cfg.workers` and `cfg.mem_budget` are
/// ignored — the service's shared pool and budget govern.
///
/// When `shards` is set the job runs as a **sharded coordinator** over
/// the named `spartan shard-worker` processes instead of fitting locally
/// (see [`shard`]): the heavy per-subject work happens in the workers'
/// address spaces, the coordinator only replays the deterministic merge,
/// so the job charges nothing against the service budget and does not
/// warm-start (its trajectory must stay bitwise identical to a cold
/// local fit).
pub struct JobSpec {
    pub data: IrregularTensor,
    pub cfg: Parafac2Config,
    pub cohort: Option<String>,
    pub shards: Option<shard::ShardSpec>,
    /// Dataset path `data` was loaded from. Journaled services persist
    /// it so a restarted daemon can re-pack the arena; a job without a
    /// source path is served normally but never journaled (there is
    /// nothing to reload it from).
    pub source: Option<String>,
    /// Resume from a durable checkpoint instead of initializing: the
    /// re-packed arena is revalidated bitwise against the checkpoint's
    /// `‖X_k‖²` bits, then the fit continues at the recorded iteration
    /// (any divergence fails the job with
    /// [`ServiceError::InvalidData`]'s rendering — never a silent
    /// refit).
    pub resume_from: Option<checkpoint::Checkpoint>,
}

impl JobSpec {
    /// A plain local fit of `data`: no cohort, no shards, no journaling.
    pub fn new(data: IrregularTensor, cfg: Parafac2Config) -> JobSpec {
        JobSpec { data, cfg, cohort: None, shards: None, source: None, resume_from: None }
    }
}

/// Lifecycle of a job. `Starting` is the brief session-construction
/// window (arena pack + init); `Cancelled` jobs that ran at all still
/// carry a partial model at the last completed iterate.
#[derive(Clone, Debug, PartialEq)]
pub enum JobState {
    Queued,
    Starting,
    Running,
    Done,
    Cancelled,
    Failed(String),
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Cancelled | JobState::Failed(_))
    }

    /// Wire name (see [`protocol`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Starting => "starting",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Cancelled => "cancelled",
            JobState::Failed(_) => "failed",
        }
    }
}

/// Point-in-time snapshot of a job (what `status` returns).
#[derive(Clone, Debug)]
pub struct JobStatus {
    pub id: u64,
    pub state: JobState,
    /// One record per completed ALS iteration, in order.
    pub records: Vec<IterationRecord>,
    /// Whether the job skipped init by warm-starting from its cohort.
    pub warm_started: bool,
    /// Admission estimate charged for this job (data + arena bound).
    pub estimate_bytes: u64,
    pub subjects: usize,
    pub variables: usize,
    pub nnz: usize,
}

/// Bytes a job will charge against the shared budget: the owned CSR
/// slices plus the compact-X arena packing bound. This is exactly what
/// [`FitSession::with_options`] charges for an owned-data session, so
/// "admitted here" ⇒ "constructs there" (modulo concurrent releases,
/// which only add headroom).
pub fn estimate_job_bytes(data: &IrregularTensor) -> u64 {
    data.heap_bytes() + CompactX::estimate_heap_bytes(data)
}

// ---------------------------------------------------------------------------
// Service internals

struct JobEntry {
    state: JobState,
    cancel: Arc<AtomicBool>,
    records: Vec<IterationRecord>,
    model: Option<Parafac2Model>,
    warm_started: bool,
    estimate: u64,
    subjects: usize,
    variables: usize,
    nnz: usize,
    /// True when the job's lifecycle is persisted to the journal (the
    /// service has one and the job carries a source path).
    journaled: bool,
}

impl JobEntry {
    fn snapshot(&self, id: u64) -> JobStatus {
        JobStatus {
            id,
            state: self.state.clone(),
            records: self.records.clone(),
            warm_started: self.warm_started,
            estimate_bytes: self.estimate,
            subjects: self.subjects,
            variables: self.variables,
            nnz: self.nnz,
        }
    }
}

struct Pending {
    id: u64,
    spec: JobSpec,
    estimate: u64,
}

struct RegistryState {
    next_id: u64,
    jobs: HashMap<u64, JobEntry>,
    pending: VecDeque<Pending>,
    running: usize,
    /// True while one job thread is constructing its session — the
    /// scheduler admits nothing else until the charge lands (serialized
    /// admission keeps the headroom check sound).
    starting: bool,
}

struct Inner {
    pool: Pool,
    budget: Arc<MemBudget>,
    max_pending: usize,
    state: Mutex<RegistryState>,
    /// Scheduler wake: submits, job conclusions, construction acks.
    wake: Condvar,
    /// Waiter wake: any registry mutation (used by [`Service::wait`]).
    progress: Condvar,
    warm: Mutex<warm::WarmCache>,
    shutdown: AtomicBool,
    /// The durable journal, when this service runs with one.
    journal: Option<journal::Journal>,
    /// Set by [`Service::shutdown_draining`]: suppress terminal journal
    /// records for drain-cancelled jobs so a restart resumes them.
    draining: AtomicBool,
}

/// The resident fit service. Dropping it cancels everything in flight
/// and joins the scheduler (each running fit stops within one iteration).
pub struct Service {
    inner: Arc<Inner>,
    scheduler: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    /// [`Service::try_start`] for services without a journal (which
    /// cannot fail to start). Panics if `cfg.journal` is set and the
    /// journal cannot be opened or replayed — daemons should call
    /// [`Service::try_start`] and surface the error instead.
    pub fn start(cfg: &ServiceConfig) -> Service {
        Service::try_start(cfg).expect("service start")
    }

    /// Stand the service up. With [`ServiceConfig::journal`] set, opens
    /// (or creates) the journal directory and replays it: terminal jobs
    /// come back with their persisted results, unfinished jobs are
    /// re-admitted in id order — resuming from their last durable
    /// checkpoint when one was committed — so a daemon restart loses no
    /// accepted work.
    pub fn try_start(cfg: &ServiceConfig) -> Result<Service, ServiceError> {
        let budget = match cfg.mem_budget {
            Some(limit) => MemBudget::limited(limit),
            None => MemBudget::unlimited(),
        };
        let journal = match &cfg.journal {
            Some(dir) => Some(journal::Journal::open(dir)?),
            None => None,
        };
        let inner = Arc::new(Inner {
            pool: Pool::new(cfg.workers),
            budget,
            max_pending: cfg.max_pending,
            state: Mutex::new(RegistryState {
                next_id: 1,
                jobs: HashMap::new(),
                pending: VecDeque::new(),
                running: 0,
                starting: false,
            }),
            wake: Condvar::new(),
            progress: Condvar::new(),
            warm: Mutex::new(warm::WarmCache::new(cfg.warm_cache)),
            shutdown: AtomicBool::new(false),
            journal,
            draining: AtomicBool::new(false),
        });
        if inner.journal.is_some() {
            replay_journal(&inner)?;
        }
        let sched = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("spartan-scheduler".into())
                .spawn(move || scheduler_loop(inner))
                .expect("spawn scheduler thread")
        };
        Ok(Service { inner, scheduler: Some(sched) })
    }

    /// Queue a fit. Fails fast with a structured error when the queue is
    /// full, the submission is invalid, or the estimate exceeds the
    /// budget limit outright; otherwise returns the job id.
    pub fn submit(&self, spec: JobSpec) -> Result<u64, ServiceError> {
        if self.inner.shutdown.load(Ordering::SeqCst) {
            return Err(ServiceError::ShuttingDown);
        }
        let (k, j, nnz) = (spec.data.k(), spec.data.j(), spec.data.nnz());
        if spec.cfg.rank == 0 {
            return Err(ServiceError::Invalid("rank must be ≥ 1".into()));
        }
        if spec.cfg.rank > j {
            return Err(ServiceError::Invalid(format!(
                "rank {} exceeds variable count J={j}",
                spec.cfg.rank
            )));
        }
        let estimate = estimate_job_bytes(&spec.data);
        if let Some(limit) = self.inner.budget.limit() {
            if estimate > limit {
                return Err(ServiceError::BudgetExceeded { estimate, limit });
            }
        }
        let mut st = self.inner.state.lock().unwrap();
        if st.pending.len() >= self.inner.max_pending {
            return Err(ServiceError::QueueFull {
                pending: st.pending.len(),
                max: self.inner.max_pending,
            });
        }
        let id = st.next_id;
        st.next_id += 1;
        let journaled = self.inner.journal.is_some() && spec.source.is_some();
        st.jobs.insert(
            id,
            JobEntry {
                state: JobState::Queued,
                cancel: Arc::new(AtomicBool::new(false)),
                records: Vec::new(),
                model: None,
                warm_started: false,
                estimate,
                subjects: k,
                variables: j,
                nnz,
                journaled,
            },
        );
        if journaled {
            let jr = self.inner.journal.as_ref().expect("journaled service");
            jr.submitted(
                id,
                &journal::SubmitRecord {
                    input: spec.source.clone().expect("journaled job has a source"),
                    cfg: spec.cfg.clone(),
                    cohort: spec.cohort.clone(),
                    shards: spec.shards.as_ref().map(checkpoint::ShardLayout::from_spec),
                    estimate,
                    subjects: k,
                    variables: j,
                    nnz,
                },
            );
        }
        st.pending.push_back(Pending { id, spec, estimate });
        self.inner.wake.notify_all();
        self.inner.progress.notify_all();
        Ok(id)
    }

    /// Snapshot a job's state and per-iteration progress.
    pub fn status(&self, id: u64) -> Result<JobStatus, ServiceError> {
        let st = self.inner.state.lock().unwrap();
        st.jobs.get(&id).map(|e| e.snapshot(id)).ok_or(ServiceError::UnknownJob(id))
    }

    /// Request cancellation. Queued jobs are removed immediately; running
    /// jobs stop within one ALS iteration. Returns the snapshot at
    /// token-set time — `records.len()` is the iteration count the
    /// "within one iteration" guarantee is measured from. Cancelling a
    /// terminal job is a no-op (its snapshot is returned unchanged).
    pub fn cancel(&self, id: u64) -> Result<JobStatus, ServiceError> {
        let mut st = self.inner.state.lock().unwrap();
        let entry = st.jobs.get_mut(&id).ok_or(ServiceError::UnknownJob(id))?;
        match entry.state {
            JobState::Queued => {
                entry.state = JobState::Cancelled;
                if entry.journaled {
                    journal_terminal(&self.inner, id, &JobState::Cancelled, None);
                }
                let snap = entry.snapshot(id);
                st.pending.retain(|p| p.id != id);
                self.inner.wake.notify_all();
                self.inner.progress.notify_all();
                Ok(snap)
            }
            JobState::Starting | JobState::Running => {
                entry.cancel.store(true, Ordering::SeqCst);
                Ok(entry.snapshot(id))
            }
            _ => Ok(entry.snapshot(id)),
        }
    }

    /// The fitted model, once terminal. `Ok(None)` while the job is still
    /// queued/starting/running; cancelled jobs yield the partial model at
    /// the last completed iterate (or `None` if they never started);
    /// failed jobs surface [`ServiceError::JobFailed`].
    pub fn result(&self, id: u64) -> Result<Option<Parafac2Model>, ServiceError> {
        let st = self.inner.state.lock().unwrap();
        let entry = st.jobs.get(&id).ok_or(ServiceError::UnknownJob(id))?;
        match &entry.state {
            JobState::Failed(reason) => {
                Err(ServiceError::JobFailed { id, reason: reason.clone() })
            }
            s if s.is_terminal() => Ok(entry.model.clone()),
            _ => Ok(None),
        }
    }

    /// Block until the job reaches a terminal state; returns the final
    /// snapshot.
    pub fn wait(&self, id: u64) -> Result<JobStatus, ServiceError> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            match st.jobs.get(&id) {
                None => return Err(ServiceError::UnknownJob(id)),
                Some(e) if e.state.is_terminal() => return Ok(e.snapshot(id)),
                Some(_) => st = self.inner.progress.wait(st).unwrap(),
            }
        }
    }

    /// The shared budget (for inspection: `used()`, `peak()`, `limit()`).
    pub fn budget(&self) -> &Arc<MemBudget> {
        &self.inner.budget
    }

    /// Stop accepting jobs, cancel everything pending or running. The
    /// scheduler exits once running jobs conclude (each within one
    /// iteration); [`Service::drop`] joins it.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        let st = self.inner.state.lock().unwrap();
        for entry in st.jobs.values() {
            entry.cancel.store(true, Ordering::SeqCst);
        }
        drop(st);
        self.inner.wake.notify_all();
        self.inner.progress.notify_all();
    }

    /// SIGTERM-style shutdown: like [`Service::shutdown`], but terminal
    /// journal records for the jobs the drain itself interrupts are
    /// suppressed — in the journal they stay queued/running, each running
    /// fit's last per-iteration checkpoint stays on disk, and the next
    /// [`Service::try_start`] re-admits and resumes them bitwise. A
    /// daemon roll therefore loses zero accepted work. Jobs that finish
    /// (`Done`) during the drain are journaled normally.
    pub fn shutdown_draining(&self) {
        self.inner.draining.store(true, Ordering::SeqCst);
        self.shutdown();
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Scheduler & job threads

fn scheduler_loop(inner: Arc<Inner>) {
    let mut st = inner.state.lock().unwrap();
    loop {
        if inner.shutdown.load(Ordering::SeqCst) {
            // Flush the queue as cancelled, then wait for running jobs to
            // conclude (their cancel flags are already set).
            while let Some(p) = st.pending.pop_front() {
                if let Some(e) = st.jobs.get_mut(&p.id) {
                    e.state = JobState::Cancelled;
                    if e.journaled {
                        journal_terminal(&inner, p.id, &JobState::Cancelled, None);
                    }
                }
            }
            inner.progress.notify_all();
            if st.running == 0 && !st.starting {
                return;
            }
            st = inner.wake.wait(st).unwrap();
            continue;
        }
        // Serialize admission: while one session is packing its arena, its
        // charge is still landing — admitting another job against the same
        // headroom could overcommit.
        if st.starting {
            st = inner.wake.wait(st).unwrap();
            continue;
        }
        let admit = match st.pending.front() {
            None => false,
            Some(front) => match inner.budget.limit() {
                None => true,
                Some(limit) => front.estimate <= limit.saturating_sub(inner.budget.used()),
            },
        };
        if !admit {
            // Nothing to run, or the front job waits for running jobs to
            // release memory (it fits the limit — submit rejected it
            // otherwise — so the queue always drains).
            st = inner.wake.wait(st).unwrap();
            continue;
        }
        let p = st.pending.pop_front().expect("admitted front job");
        let journaled = match st.jobs.get_mut(&p.id) {
            Some(e) => {
                e.state = JobState::Starting;
                e.journaled
            }
            None => false,
        };
        if journaled {
            if let Some(jr) = &inner.journal {
                jr.started(p.id);
            }
        }
        st.starting = true;
        st.running += 1;
        inner.progress.notify_all();
        let inner2 = Arc::clone(&inner);
        std::thread::Builder::new()
            .name(format!("spartan-job-{}", p.id))
            .spawn(move || run_job(inner2, p.id, p.spec))
            .expect("spawn job thread");
    }
}

/// Terminal bookkeeping for one job; `clear_starting` is set on paths
/// that conclude before the construction ack.
fn conclude(
    inner: &Arc<Inner>,
    id: u64,
    state: JobState,
    model: Option<Parafac2Model>,
    clear_starting: bool,
) {
    let mut st = inner.state.lock().unwrap();
    if clear_starting {
        st.starting = false;
    }
    st.running -= 1;
    if let Some(e) = st.jobs.get_mut(&id) {
        if e.journaled {
            journal_terminal(inner, id, &state, model.as_ref());
        }
        e.state = state;
        e.model = model;
    }
    inner.wake.notify_all();
    inner.progress.notify_all();
}

/// Persist a journaled job's terminal record — result first (atomically,
/// so a `done` record never points at a missing or torn result), then
/// the `done` line, then the now-obsolete checkpoint is retired.
///
/// Suppressed while draining for every state but `Done`: a SIGTERM'd
/// daemon leaves drain-cancelled jobs *running* in the journal so the
/// restarted daemon resumes them from their last checkpoint instead of
/// surfacing a cancellation nobody asked for.
fn journal_terminal(inner: &Inner, id: u64, state: &JobState, model: Option<&Parafac2Model>) {
    let Some(jr) = &inner.journal else { return };
    if inner.draining.load(Ordering::SeqCst) && *state != JobState::Done {
        return;
    }
    if let Some(m) = model {
        let mut text = protocol::model_to_json(m).pretty();
        text.push('\n');
        if let Err(e) = crate::util::atomicfile::write_atomic(&jr.result_path(id), text.as_bytes())
        {
            eprintln!("spartan serve: job {id}: persisting result failed: {e}");
        }
    }
    jr.done(id, state);
    std::fs::remove_file(jr.checkpoint_path(id)).ok();
}

/// Commit job `id`'s checkpoint for the boundary just reached and append
/// the `checkpointed` journal record. Failures are logged and do not
/// interrupt the fit — the previous checkpoint (atomically replaced,
/// never torn) stays valid, so durability degrades by one boundary at
/// worst.
fn journal_checkpoint(inner: &Inner, id: u64, iter: usize, ckpt: &checkpoint::Checkpoint) {
    let Some(jr) = &inner.journal else { return };
    match checkpoint::save_checkpoint(&jr.checkpoint_path(id), ckpt) {
        Ok(()) => jr.checkpointed(id, iter),
        Err(e) => eprintln!("spartan serve: job {id}: checkpoint failed: {e}"),
    }
}

/// Register a replayed job as failed (dataset missing, checkpoint
/// unreadable, …) and journal the terminal record so the *next* restart
/// sees it settled.
fn restore_failed(
    st: &mut RegistryState,
    jr: &journal::Journal,
    id: u64,
    submit: &journal::SubmitRecord,
    reason: String,
) {
    jr.done(id, &JobState::Failed(reason.clone()));
    st.jobs.insert(
        id,
        JobEntry {
            state: JobState::Failed(reason),
            cancel: Arc::new(AtomicBool::new(false)),
            records: Vec::new(),
            model: None,
            warm_started: false,
            estimate: submit.estimate,
            subjects: submit.subjects,
            variables: submit.variables,
            nnz: submit.nnz,
            journaled: true,
        },
    );
}

/// Fold the journal into a fresh registry (no scheduler is running yet):
/// terminal jobs are restored with their persisted results; queued and
/// interrupted jobs are re-admitted under their original ids, the latter
/// resuming from their last durable checkpoint.
fn replay_journal(inner: &Arc<Inner>) -> Result<(), ServiceError> {
    let jr = inner.journal.as_ref().expect("journaled service");
    let replayed = journal::replay(jr.dir())?;
    let mut st = inner.state.lock().unwrap();
    for job in replayed {
        let journal::ReplayJob { id, submit, state } = job;
        st.next_id = st.next_id.max(id + 1);
        match state {
            journal::ReplayState::Terminal(term) => {
                let model = std::fs::read_to_string(jr.result_path(id))
                    .ok()
                    .and_then(|t| crate::util::json::parse(&t).ok())
                    .and_then(|j| protocol::model_from_json(&j).ok());
                st.jobs.insert(
                    id,
                    JobEntry {
                        state: term,
                        cancel: Arc::new(AtomicBool::new(false)),
                        records: Vec::new(),
                        model,
                        warm_started: false,
                        estimate: submit.estimate,
                        subjects: submit.subjects,
                        variables: submit.variables,
                        nnz: submit.nnz,
                        journaled: true,
                    },
                );
            }
            journal::ReplayState::Queued | journal::ReplayState::Running => {
                let cpath = jr.checkpoint_path(id);
                let resume = if state == journal::ReplayState::Running && cpath.exists() {
                    match checkpoint::load_checkpoint(&cpath) {
                        Ok(c) => Some(c),
                        Err(e) => {
                            restore_failed(&mut st, jr, id, &submit, e.to_string());
                            continue;
                        }
                    }
                } else {
                    None
                };
                let data = match server::load_tensor(&submit.input) {
                    Ok(d) => d,
                    Err(e) => {
                        restore_failed(&mut st, jr, id, &submit, e.to_string());
                        continue;
                    }
                };
                let estimate = estimate_job_bytes(&data);
                let (k, j, nnz) = (data.k(), data.j(), data.nnz());
                let spec = JobSpec {
                    data,
                    cfg: submit.cfg,
                    cohort: submit.cohort,
                    shards: submit.shards.map(|l| l.to_spec(submit.input.clone())),
                    source: Some(submit.input),
                    resume_from: resume,
                };
                st.jobs.insert(
                    id,
                    JobEntry {
                        state: JobState::Queued,
                        cancel: Arc::new(AtomicBool::new(false)),
                        records: Vec::new(),
                        model: None,
                        warm_started: false,
                        estimate,
                        subjects: k,
                        variables: j,
                        nnz,
                        journaled: true,
                    },
                );
                st.pending.push_back(Pending { id, spec, estimate });
            }
        }
    }
    Ok(())
}

fn run_job(inner: Arc<Inner>, id: u64, spec: JobSpec) {
    let cancel = {
        let st = inner.state.lock().unwrap();
        st.jobs.get(&id).expect("registered job").cancel.clone()
    };
    if spec.shards.is_some() {
        run_sharded_job(inner, id, spec, cancel);
        return;
    }
    let JobSpec { data, cfg, cohort, source, resume_from, .. } = spec;
    let journaled = inner.journal.is_some() && source.is_some();
    if let Some(ckpt) = &resume_from {
        let ours = crate::linalg::kernels::active_backend().name();
        if ckpt.kernel_backend != ours {
            let e = ServiceError::InvalidData(format!(
                "checkpoint ran on kernel backend `{}` but this daemon runs `{ours}`",
                ckpt.kernel_backend
            ));
            conclude(&inner, id, JobState::Failed(e.to_string()), None, true);
            return;
        }
    }
    let warm = match &resume_from {
        // A resume *is* a warm start at the checkpoint's iterate — the
        // cohort cache must never override the recorded trajectory.
        Some(c) => Some(WarmStart { h: c.h.clone(), v: c.v.clone(), w: c.w.clone() }),
        None => cohort
            .as_deref()
            .and_then(|c| inner.warm.lock().unwrap().get(c, cfg.rank, data.j(), data.k())),
    };
    let warm_started = resume_from.is_none() && warm.is_some();
    let options = SessionOptions {
        pool: Some(inner.pool.clone()),
        budget: Some(Arc::clone(&inner.budget)),
        warm,
        keep_data: false,
        cancel: Some(cancel),
    };
    let mut session = match FitSession::with_options(DataHandle::Owned(data), &cfg, options) {
        Ok(s) => s,
        Err(e) => {
            conclude(&inner, id, JobState::Failed(e.to_string()), None, true);
            return;
        }
    };
    if let Some(ckpt) = resume_from {
        let got = session.slice_norm_sq();
        let same = got.len() == ckpt.x_norm_bits.len()
            && got.iter().zip(&ckpt.x_norm_bits).all(|(a, b)| a.to_bits() == b.to_bits());
        if !same {
            drop(session);
            let e = ServiceError::InvalidData(format!(
                "resume re-packed a different arena (‖X_k‖² bits diverge) — has `{}` changed \
                 since the checkpoint?",
                ckpt.input
            ));
            conclude(&inner, id, JobState::Failed(e.to_string()), None, true);
            return;
        }
        session.restore(ckpt.state);
    }
    {
        // Construction ack: the charge has landed, admission may resume.
        let mut st = inner.state.lock().unwrap();
        if let Some(e) = st.jobs.get_mut(&id) {
            e.state = JobState::Running;
            e.warm_started = warm_started;
        }
        st.starting = false;
        inner.wake.notify_all();
        inner.progress.notify_all();
    }
    enum End {
        Done,
        Cancelled,
        Failed(String),
    }
    let end = loop {
        match session.step() {
            Ok(StepOutcome::Iterated(rec)) => {
                let iter = rec.iter;
                {
                    let mut st = inner.state.lock().unwrap();
                    if let Some(e) = st.jobs.get_mut(&id) {
                        e.records.push(rec);
                    }
                    inner.progress.notify_all();
                }
                if journaled {
                    let (h, v, w) = session.factors();
                    let ckpt = checkpoint::Checkpoint {
                        input: source.clone().expect("journaled job has a source"),
                        cfg: cfg.clone(),
                        kernel_backend: crate::linalg::kernels::active_backend()
                            .name()
                            .to_string(),
                        h: h.clone(),
                        v: v.clone(),
                        w: w.clone(),
                        state: session.resume_state(),
                        x_norm_bits: session.slice_norm_sq(),
                        shards: None,
                    };
                    journal_checkpoint(&inner, id, iter, &ckpt);
                }
            }
            Ok(StepOutcome::Done) => break End::Done,
            Ok(StepOutcome::Cancelled) => break End::Cancelled,
            Err(e) => break End::Failed(e.to_string()),
        }
    };
    match end {
        End::Failed(reason) => {
            // Release the session's charge before waking the scheduler.
            drop(session);
            conclude(&inner, id, JobState::Failed(reason), None, false);
        }
        End::Done | End::Cancelled => {
            let cancelled = matches!(end, End::Cancelled);
            let model = session.finish();
            if let Some(c) = &cohort {
                // Even a cancelled fit's partial factors beat SvdWarm for
                // the cohort's next submit.
                inner.warm.lock().unwrap().put(c, WarmStart::from_model(&model));
            }
            let state = if cancelled { JobState::Cancelled } else { JobState::Done };
            conclude(&inner, id, state, Some(model), false);
        }
    }
}

/// The sharded-coordinator variant of [`run_job`]: the per-subject work
/// happens in the shard workers' address spaces, so the job charges
/// nothing against the shared budget, never warm-starts (the sharded
/// trajectory must stay bitwise identical to a cold local fit), and does
/// not feed the warm cache. State transitions, per-iteration records, and
/// cancellation semantics are identical to a local job.
fn run_sharded_job(inner: Arc<Inner>, id: u64, spec: JobSpec, cancel: Arc<AtomicBool>) {
    let JobSpec { data, cfg, shards, source, resume_from, .. } = spec;
    let shard_spec = shards.expect("sharded job");
    let journaled = inner.journal.is_some() && source.is_some();
    let built = match resume_from {
        Some(c) => shard::ShardedFitSession::resume(
            data,
            &cfg,
            &shard_spec,
            Some(cancel),
            shard::ShardedResume {
                h: c.h,
                v: c.v,
                w: c.w,
                state: c.state,
                x_norm_bits: c.x_norm_bits,
            },
        ),
        None => shard::ShardedFitSession::new(data, &cfg, &shard_spec, Some(cancel)),
    };
    let mut session = match built {
        Ok(s) => s,
        Err(e) => {
            conclude(&inner, id, JobState::Failed(e.to_string()), None, true);
            return;
        }
    };
    {
        // Construction ack: the shards are planned, admission may resume
        // (a sharded job never held budget, but it did hold the latch).
        let mut st = inner.state.lock().unwrap();
        if let Some(e) = st.jobs.get_mut(&id) {
            e.state = JobState::Running;
        }
        st.starting = false;
        inner.wake.notify_all();
        inner.progress.notify_all();
    }
    enum End {
        Done,
        Cancelled,
        Failed(String),
    }
    let end = loop {
        match session.step() {
            Ok(StepOutcome::Iterated(rec)) => {
                let iter = rec.iter;
                {
                    let mut st = inner.state.lock().unwrap();
                    if let Some(e) = st.jobs.get_mut(&id) {
                        e.records.push(rec);
                    }
                    inner.progress.notify_all();
                }
                if journaled {
                    let (h, v, w) = session.factors();
                    let ckpt = checkpoint::Checkpoint {
                        input: source.clone().expect("journaled job has a source"),
                        cfg: cfg.clone(),
                        kernel_backend: crate::linalg::kernels::active_backend()
                            .name()
                            .to_string(),
                        h: h.clone(),
                        v: v.clone(),
                        w: w.clone(),
                        state: session.resume_state(),
                        x_norm_bits: session.slice_norm_sq(),
                        shards: Some(checkpoint::ShardLayout::from_spec(&shard_spec)),
                    };
                    journal_checkpoint(&inner, id, iter, &ckpt);
                }
            }
            Ok(StepOutcome::Done) => break End::Done,
            Ok(StepOutcome::Cancelled) => break End::Cancelled,
            Err(e) => break End::Failed(e.to_string()),
        }
    };
    match end {
        End::Failed(reason) => conclude(&inner, id, JobState::Failed(reason), None, false),
        End::Done | End::Cancelled => {
            let cancelled = matches!(end, End::Cancelled);
            match session.finish() {
                Ok(model) => {
                    let state = if cancelled { JobState::Cancelled } else { JobState::Done };
                    conclude(&inner, id, state, Some(model), false);
                }
                Err(e) => conclude(&inner, id, JobState::Failed(e.to_string()), None, false),
            }
        }
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::synthetic::{generate, SyntheticSpec};
    use crate::parafac2::fit_parafac2;

    fn data(seed: u64) -> IrregularTensor {
        generate(&SyntheticSpec {
            k: 24,
            j: 12,
            max_i_k: 8,
            target_nnz: 1_500,
            rank: 2,
            noise: 0.05,
            seed,
        })
        .tensor
    }

    fn cfg(rank: usize, max_iters: usize) -> Parafac2Config {
        Parafac2Config { rank, max_iters, workers: 1, ..Default::default() }
    }

    #[test]
    fn concurrent_service_jobs_bitwise_match_direct_fits() {
        let svc = Service::start(&ServiceConfig { workers: 2, ..Default::default() });
        let (d1, d2) = (data(11), data(12));
        let c1 = cfg(3, 8);
        let c2 = cfg(2, 10);
        let id1 = svc
            .submit(JobSpec::new(d1.clone(), c1.clone()))
            .unwrap();
        let id2 = svc
            .submit(JobSpec::new(d2.clone(), c2.clone()))
            .unwrap();
        assert_eq!(svc.wait(id1).unwrap().state, JobState::Done);
        assert_eq!(svc.wait(id2).unwrap().state, JobState::Done);
        let m1 = svc.result(id1).unwrap().expect("done job has model");
        let m2 = svc.result(id2).unwrap().expect("done job has model");
        let r1 = fit_parafac2(&d1, &c1).unwrap();
        let r2 = fit_parafac2(&d2, &c2).unwrap();
        for (got, want) in [(&m1, &r1), (&m2, &r2)] {
            assert_eq!(got.h.data(), want.h.data());
            assert_eq!(got.v.data(), want.v.data());
            assert_eq!(got.w.data(), want.w.data());
            assert_eq!(got.stats.final_sse.to_bits(), want.stats.final_sse.to_bits());
            for (qa, qb) in got.q.iter().zip(&want.q) {
                assert_eq!(qa.data(), qb.data());
            }
        }
        // all charges released once jobs concluded
        assert_eq!(svc.budget().used(), 0);
        assert!(svc.budget().peak() > 0);
    }

    #[test]
    fn admission_blocks_queue_until_memory_frees_and_bounds_queue() {
        let d = data(21);
        let est = estimate_job_bytes(&d);
        // Room for exactly one resident job at a time: a running job holds
        // at least its arena (~half the estimate, the CSR half is released
        // after the pack), so the est/4 slack never admits a second job.
        let svc = Service::start(&ServiceConfig {
            workers: 1,
            mem_budget: Some(est + est / 4),
            max_pending: 1,
            ..Default::default()
        });
        // Job 1 runs "forever" (tol 0 never converges) until cancelled.
        let mut long = cfg(2, 1_000_000);
        long.tol = 0.0;
        let id1 = svc
            .submit(JobSpec::new(d.clone(), long))
            .unwrap();
        // Let the scheduler claim job 1 so the bounded queue is empty.
        while matches!(svc.status(id1).unwrap().state, JobState::Queued) {
            std::thread::yield_now();
        }
        // Job 2 fits the limit but not the current headroom → stays queued.
        let id2 = svc
            .submit(JobSpec::new(d.clone(), cfg(2, 3)))
            .unwrap();
        // Queue is bounded: a third submit is a structured reject.
        match svc.submit(JobSpec::new(d.clone(), cfg(2, 3))) {
            Err(ServiceError::QueueFull { pending: 1, max: 1 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // Wait until job 1 is actually running, then confirm 2 is queued.
        while !matches!(svc.status(id1).unwrap().state, JobState::Running) {
            std::thread::yield_now();
        }
        assert_eq!(svc.status(id2).unwrap().state, JobState::Queued);
        // Cancelling job 1 frees its charge; job 2 is admitted and runs.
        svc.cancel(id1).unwrap();
        assert_eq!(svc.wait(id1).unwrap().state, JobState::Cancelled);
        assert_eq!(svc.wait(id2).unwrap().state, JobState::Done);
        assert!(svc.result(id2).unwrap().is_some());
        assert_eq!(svc.budget().used(), 0);
    }

    #[test]
    fn oversized_job_rejected_at_submit_and_service_stays_usable() {
        let d = data(31);
        let est = estimate_job_bytes(&d);
        let svc = Service::start(&ServiceConfig {
            workers: 1,
            mem_budget: Some(est / 2),
            ..Default::default()
        });
        match svc.submit(JobSpec::new(d.clone(), cfg(2, 3))) {
            Err(ServiceError::BudgetExceeded { estimate, limit }) => {
                assert_eq!(estimate, est);
                assert_eq!(limit, est / 2);
            }
            other => panic!("expected BudgetExceeded, got {other:?}"),
        }
        // Nothing was charged or registered; the daemon keeps serving.
        assert_eq!(svc.budget().used(), 0);
        assert!(matches!(svc.status(1), Err(ServiceError::UnknownJob(1))));
        let tiny = generate(&SyntheticSpec {
            k: 4,
            j: 6,
            max_i_k: 3,
            target_nnz: 40,
            rank: 2,
            noise: 0.0,
            seed: 5,
        })
        .tensor;
        assert!(estimate_job_bytes(&tiny) <= est / 2, "test premise: tiny job fits");
        let id = svc
            .submit(JobSpec::new(tiny, cfg(2, 3)))
            .unwrap();
        assert_eq!(svc.wait(id).unwrap().state, JobState::Done);
    }

    #[test]
    fn cancel_queued_job_never_runs() {
        let d = data(41);
        let est = estimate_job_bytes(&d);
        let svc = Service::start(&ServiceConfig {
            workers: 1,
            mem_budget: Some(est + est / 4),
            ..Default::default()
        });
        let mut long = cfg(2, 1_000_000);
        long.tol = 0.0;
        let id1 = svc
            .submit(JobSpec::new(d.clone(), long))
            .unwrap();
        while !matches!(svc.status(id1).unwrap().state, JobState::Running) {
            std::thread::yield_now();
        }
        let id2 = svc
            .submit(JobSpec::new(d.clone(), cfg(2, 3)))
            .unwrap();
        let snap = svc.cancel(id2).unwrap();
        assert_eq!(snap.state, JobState::Cancelled);
        assert_eq!(snap.records.len(), 0);
        assert!(svc.result(id2).unwrap().is_none(), "never-started job has no model");
        svc.cancel(id1).unwrap();
        assert_eq!(svc.wait(id1).unwrap().state, JobState::Cancelled);
    }

    #[test]
    fn cohort_refits_warm_start_and_shape_mismatch_cold_starts() {
        let svc = Service::start(&ServiceConfig { workers: 1, ..Default::default() });
        let d = data(51);
        let id1 = svc
            .submit(JobSpec {
                cohort: Some("ehr-weekly".into()),
                ..JobSpec::new(d.clone(), cfg(3, 5))
            })
            .unwrap();
        let s1 = svc.wait(id1).unwrap();
        assert_eq!(s1.state, JobState::Done);
        assert!(!s1.warm_started, "first fit of a cohort cold-starts");
        // Same cohort, same shape → warm-started from the cached factors.
        let id2 = svc
            .submit(JobSpec {
                cohort: Some("ehr-weekly".into()),
                ..JobSpec::new(d.clone(), cfg(3, 5))
            })
            .unwrap();
        let s2 = svc.wait(id2).unwrap();
        assert_eq!(s2.state, JobState::Done);
        assert!(s2.warm_started);
        // Different rank → shape miss, silent cold start.
        let id3 = svc
            .submit(JobSpec {
                cohort: Some("ehr-weekly".into()),
                ..JobSpec::new(d.clone(), cfg(2, 5))
            })
            .unwrap();
        let s3 = svc.wait(id3).unwrap();
        assert_eq!(s3.state, JobState::Done);
        assert!(!s3.warm_started);
    }

    #[test]
    fn invalid_submissions_are_structured() {
        let svc = Service::start(&ServiceConfig { workers: 1, ..Default::default() });
        let d = data(61);
        assert!(matches!(
            svc.submit(JobSpec::new(d.clone(), cfg(0, 3))),
            Err(ServiceError::Invalid(_))
        ));
        assert!(matches!(
            svc.submit(JobSpec::new(d.clone(), cfg(999, 3))),
            Err(ServiceError::Invalid(_))
        ));
        assert!(matches!(svc.status(42), Err(ServiceError::UnknownJob(42))));
        assert!(matches!(svc.cancel(42), Err(ServiceError::UnknownJob(42))));
        assert!(matches!(svc.result(42), Err(ServiceError::UnknownJob(42))));
    }

    #[test]
    fn errors_render_and_are_std_errors() {
        let errs: Vec<Box<dyn std::error::Error>> = vec![
            Box::new(ServiceError::QueueFull { pending: 3, max: 3 }),
            Box::new(ServiceError::BudgetExceeded { estimate: 1 << 30, limit: 1 << 20 }),
            Box::new(ServiceError::UnknownJob(7)),
            Box::new(ServiceError::JobFailed { id: 7, reason: "boom".into() }),
            Box::new(ServiceError::Invalid("rank".into())),
            Box::new(ServiceError::InvalidData("value at slice 3 row 1 is not finite".into())),
            Box::new(ServiceError::ShuttingDown),
        ];
        for e in errs {
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn journaled_restart_restores_results_and_drain_resumes_bitwise() {
        let dir = std::env::temp_dir().join(format!("spartan_svc_journal_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("data.spt");
        crate::sparse::io::save_binary(&data(71), &input).unwrap();
        // Use the tensor exactly as a restarted daemon will re-load it.
        let d = server::load_tensor(input.to_str().unwrap()).unwrap();
        let fit_cfg = cfg(2, 6);
        let want = fit_parafac2(&d, &fit_cfg).unwrap();
        let svc_cfg = ServiceConfig {
            workers: 1,
            journal: Some(dir.join("journal")),
            ..Default::default()
        };
        // Run a journaled job to completion, then roll the daemon: the
        // restarted service serves the persisted result, bitwise.
        let svc = Service::start(&svc_cfg);
        let id = svc
            .submit(JobSpec {
                source: Some(input.to_string_lossy().into_owned()),
                ..JobSpec::new(d.clone(), fit_cfg.clone())
            })
            .unwrap();
        assert_eq!(svc.wait(id).unwrap().state, JobState::Done);
        drop(svc);
        let svc = Service::start(&svc_cfg);
        assert_eq!(svc.status(id).unwrap().state, JobState::Done);
        let m = svc.result(id).unwrap().expect("restart serves the persisted result");
        assert_eq!(m.h.data(), want.h.data());
        assert_eq!(m.v.data(), want.v.data());
        assert_eq!(m.w.data(), want.w.data());
        assert_eq!(m.stats.final_sse.to_bits(), want.stats.final_sse.to_bits());
        // Interrupt a running job with a drain: the restarted service
        // re-admits it, resumes from its last per-iteration checkpoint,
        // and finishes on the uninterrupted trajectory, bitwise.
        let mut slow = fit_cfg.clone();
        slow.tol = 0.0; // never converges early: 30 full iterations
        slow.max_iters = 30;
        let want2 = fit_parafac2(&d, &slow).unwrap();
        let id2 = svc
            .submit(JobSpec {
                source: Some(input.to_string_lossy().into_owned()),
                ..JobSpec::new(d.clone(), slow)
            })
            .unwrap();
        loop {
            let s = svc.status(id2).unwrap();
            if !s.records.is_empty() || s.state.is_terminal() {
                break;
            }
            std::thread::yield_now();
        }
        svc.shutdown_draining();
        drop(svc);
        let svc = Service::start(&svc_cfg);
        assert_eq!(svc.wait(id2).unwrap().state, JobState::Done);
        let m2 = svc.result(id2).unwrap().expect("resumed job finishes");
        assert_eq!(m2.h.data(), want2.h.data());
        assert_eq!(m2.v.data(), want2.v.data());
        assert_eq!(m2.w.data(), want2.w.data());
        assert_eq!(m2.stats.final_sse.to_bits(), want2.stats.final_sse.to_bits());
        assert_eq!(m2.stats.fit_history.len(), want2.stats.fit_history.len());
        for (a, b) in m2.stats.fit_history.iter().zip(&want2.stats.fit_history) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        drop(svc);
        std::fs::remove_dir_all(&dir).ok();
    }
}
