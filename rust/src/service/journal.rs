//! The daemon's append-only job journal: the record a restarted
//! `spartan serve --journal <dir>` folds to pick up exactly where the
//! dead one stopped.
//!
//! Layout under the journal directory:
//!
//! * `journal.ndjson` — one JSON record per line, append-only, fsynced
//!   per append. Four record kinds: `submitted` (everything needed to
//!   rebuild the [`crate::service::JobSpec`] — the tensor itself is
//!   reloaded from the recorded `input` path), `started`, `checkpointed`
//!   (informational; the checkpoint *file* is authoritative), and `done`
//!   (terminal state + failure reason).
//! * `checkpoints/job-<id>.ckpt` — the job's latest durable checkpoint
//!   ([`crate::service::checkpoint`]), atomically replaced each
//!   iteration and removed once the job's terminal record lands.
//! * `results/job-<id>.json` — the finished model
//!   ([`crate::service::protocol::model_to_json`]), written atomically
//!   before the `done` record so a restart never claims a result it
//!   cannot serve.
//!
//! [`replay`] folds the records per job id: `submitted` alone replays as
//! queued, `started` without `done` replays as running (resumed from its
//! checkpoint when one exists), `done` is terminal. A crash mid-append
//! leaves at most one torn **trailing** line — every earlier record was
//! written and fsynced whole — so replay drops a malformed final line
//! and rejects a malformed interior one loudly
//! ([`crate::service::ServiceError::InvalidData`]). The normative record
//! format lives in `docs/PROTOCOL.md` § the job journal.

use crate::parafac2::Parafac2Config;
use crate::service::checkpoint::{
    config_from_json, config_to_json, shards_from_json, shards_to_json, ShardLayout,
};
use crate::service::{JobState, ServiceError};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// File name of the NDJSON record stream inside the journal directory.
pub const JOURNAL_FILE: &str = "journal.ndjson";

/// An open journal: the directory plus the append handle. All appends
/// are serialized and fsynced, so every record before a crash point is
/// intact on replay.
pub struct Journal {
    dir: PathBuf,
    file: Mutex<File>,
}

/// Everything a `submitted` record carries — enough to rebuild the job
/// on replay without the original process's memory.
#[derive(Clone, Debug)]
pub struct SubmitRecord {
    /// Dataset path the tensor is reloaded from on re-admission.
    pub input: String,
    pub cfg: Parafac2Config,
    pub cohort: Option<String>,
    /// Present iff the job runs as a sharded coordinator.
    pub shards: Option<ShardLayout>,
    pub estimate: u64,
    pub subjects: usize,
    pub variables: usize,
    pub nnz: usize,
}

/// A job's folded lifecycle after [`replay`].
#[derive(Clone, Debug, PartialEq)]
pub enum ReplayState {
    /// Submitted, never started: re-admit from scratch.
    Queued,
    /// Started but no terminal record: re-admit, resuming from the job's
    /// checkpoint file when one was committed.
    Running,
    /// Finished; the result (if any) is under `results/`.
    Terminal(JobState),
}

/// One journaled job as [`replay`] reconstructs it.
#[derive(Clone, Debug)]
pub struct ReplayJob {
    pub id: u64,
    pub submit: SubmitRecord,
    pub state: ReplayState,
}

impl Journal {
    /// Open (creating as needed) the journal directory and its record
    /// stream. Idempotent: an existing journal is appended to, never
    /// truncated.
    pub fn open(dir: &Path) -> Result<Journal, ServiceError> {
        for sub in [dir.to_path_buf(), dir.join("checkpoints"), dir.join("results")] {
            std::fs::create_dir_all(&sub).map_err(|e| {
                ServiceError::Io(format!("creating journal dir {}: {e}", sub.display()))
            })?;
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join(JOURNAL_FILE))
            .map_err(|e| {
                ServiceError::Io(format!("opening journal in {}: {e}", dir.display()))
            })?;
        Ok(Journal { dir: dir.to_path_buf(), file: Mutex::new(file) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Where job `id`'s latest durable checkpoint lives.
    pub fn checkpoint_path(&self, id: u64) -> PathBuf {
        self.dir.join("checkpoints").join(format!("job-{id}.ckpt"))
    }

    /// Where job `id`'s persisted result lives once it concludes.
    pub fn result_path(&self, id: u64) -> PathBuf {
        self.dir.join("results").join(format!("job-{id}.json"))
    }

    /// Append one record and fsync it. Failures are logged, not fatal —
    /// a journal that stops advancing degrades durability, never the
    /// fit itself.
    fn append(&self, record: Json) {
        let line = record.to_string();
        let mut f = self.file.lock().unwrap();
        if let Err(e) = writeln!(f, "{line}").and_then(|()| f.sync_data()) {
            eprintln!("spartan serve: journal append failed: {e}");
        }
    }

    pub fn submitted(&self, id: u64, r: &SubmitRecord) {
        let mut fields = vec![
            ("event", Json::str("submitted")),
            ("id", Json::num(id as f64)),
            ("input", Json::str(r.input.clone())),
            ("config", config_to_json(&r.cfg)),
            ("estimate", Json::num(r.estimate as f64)),
            ("subjects", Json::num(r.subjects as f64)),
            ("variables", Json::num(r.variables as f64)),
            ("nnz", Json::num(r.nnz as f64)),
        ];
        if let Some(c) = &r.cohort {
            fields.push(("cohort", Json::str(c.clone())));
        }
        if let Some(s) = &r.shards {
            fields.push(("shards", shards_to_json(s)));
        }
        self.append(Json::obj(fields));
    }

    pub fn started(&self, id: u64) {
        self.append(Json::obj(vec![
            ("event", Json::str("started")),
            ("id", Json::num(id as f64)),
        ]));
    }

    pub fn checkpointed(&self, id: u64, iter: usize) {
        self.append(Json::obj(vec![
            ("event", Json::str("checkpointed")),
            ("id", Json::num(id as f64)),
            ("iter", Json::num(iter as f64)),
        ]));
    }

    pub fn done(&self, id: u64, state: &JobState) {
        let mut fields = vec![
            ("event", Json::str("done")),
            ("id", Json::num(id as f64)),
            ("state", Json::str(state.as_str())),
        ];
        if let JobState::Failed(reason) = state {
            fields.push(("reason", Json::str(reason.clone())));
        }
        self.append(Json::obj(fields));
    }
}

fn submit_from_json(ev: &Json) -> Result<SubmitRecord, String> {
    let input =
        ev.get("input").and_then(Json::as_str).ok_or("submitted record missing input")?;
    let cfg = config_from_json(ev.get("config").ok_or("submitted record missing config")?)?;
    let num = |k: &str| {
        ev.get(k).and_then(Json::as_f64).ok_or(format!("submitted record missing {k}"))
    };
    let shards = match ev.get("shards") {
        Some(s) => Some(shards_from_json(s)?),
        None => None,
    };
    Ok(SubmitRecord {
        input: input.to_string(),
        cfg,
        cohort: ev.get("cohort").and_then(Json::as_str).map(str::to_string),
        shards,
        estimate: num("estimate")? as u64,
        subjects: num("subjects")? as usize,
        variables: num("variables")? as usize,
        nnz: num("nnz")? as usize,
    })
}

fn apply(jobs: &mut BTreeMap<u64, ReplayJob>, ev: &Json) -> Result<(), String> {
    let kind = ev.get("event").and_then(Json::as_str).ok_or("record missing event")?;
    let id = ev.get("id").and_then(Json::as_f64).ok_or("record missing id")? as u64;
    match kind {
        "submitted" => {
            let submit = submit_from_json(ev)?;
            jobs.insert(id, ReplayJob { id, submit, state: ReplayState::Queued });
        }
        "started" => {
            let job = jobs.get_mut(&id).ok_or(format!("job {id} started before submitted"))?;
            job.state = ReplayState::Running;
        }
        // The checkpoint file itself is authoritative — nothing to fold.
        "checkpointed" => {}
        "done" => {
            let job = jobs.get_mut(&id).ok_or(format!("job {id} done before submitted"))?;
            let state = ev.get("state").and_then(Json::as_str).ok_or("done record missing state")?;
            job.state = ReplayState::Terminal(match state {
                "done" => JobState::Done,
                "cancelled" => JobState::Cancelled,
                "failed" => JobState::Failed(
                    ev.get("reason").and_then(Json::as_str).unwrap_or("unknown").to_string(),
                ),
                other => return Err(format!("job {id}: bad terminal state `{other}`")),
            });
        }
        other => return Err(format!("unknown journal record `{other}`")),
    }
    Ok(())
}

/// Fold the record stream under `dir` into per-job states, id order. A
/// missing journal file replays as empty (first boot); a torn trailing
/// line (crash mid-append) is dropped; any other malformed record is a
/// loud [`ServiceError::InvalidData`] — a journal we cannot read exactly
/// is not one to rebuild jobs from.
pub fn replay(dir: &Path) -> Result<Vec<ReplayJob>, ServiceError> {
    let path = dir.join(JOURNAL_FILE);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => {
            return Err(ServiceError::Io(format!("reading journal {}: {e}", path.display())))
        }
    };
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut jobs = BTreeMap::new();
    for (i, line) in lines.iter().enumerate() {
        let fold = json::parse(line)
            .map_err(|e| e.to_string())
            .and_then(|ev| apply(&mut jobs, &ev));
        if let Err(e) = fold {
            if i + 1 == lines.len() && json::parse(line).is_err() {
                // Crash mid-append: every earlier record was fsynced
                // whole, so only the final line can be torn.
                eprintln!("spartan serve: journal: dropping torn trailing record");
                break;
            }
            return Err(ServiceError::InvalidData(format!(
                "journal {}: record {}: {e}",
                path.display(),
                i + 1
            )));
        }
    }
    Ok(jobs.into_values().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("spartan_journal_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    fn record(k: usize, j: usize) -> SubmitRecord {
        SubmitRecord {
            input: "/tmp/data dir/week 3.spt".into(),
            cfg: Parafac2Config { rank: 3, max_iters: 7, seed: 5, ..Default::default() },
            cohort: Some("ehr-weekly".into()),
            shards: None,
            estimate: 4096,
            subjects: k,
            variables: j,
            nnz: 99,
        }
    }

    #[test]
    fn replay_folds_lifecycles_in_id_order() {
        let dir = tmpdir("fold");
        let jr = Journal::open(&dir).unwrap();
        jr.submitted(1, &record(8, 4));
        jr.submitted(2, &record(9, 5));
        jr.submitted(3, &record(10, 6));
        jr.started(1);
        jr.checkpointed(1, 1);
        jr.started(2);
        jr.done(2, &JobState::Failed("boom".into()));
        jr.done(1, &JobState::Done);
        drop(jr);
        let jobs = replay(&dir).unwrap();
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].id, 1);
        assert_eq!(jobs[0].state, ReplayState::Terminal(JobState::Done));
        assert_eq!(jobs[0].submit.cohort.as_deref(), Some("ehr-weekly"));
        assert_eq!(jobs[0].submit.cfg.rank, 3);
        assert_eq!(jobs[0].submit.subjects, 8);
        assert_eq!(jobs[1].state, ReplayState::Terminal(JobState::Failed("boom".into())));
        assert_eq!(jobs[2].state, ReplayState::Queued);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn started_without_done_replays_as_running() {
        let dir = tmpdir("running");
        let jr = Journal::open(&dir).unwrap();
        jr.submitted(7, &record(4, 4));
        jr.started(7);
        jr.checkpointed(7, 2);
        drop(jr);
        let jobs = replay(&dir).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].id, 7);
        assert_eq!(jobs[0].state, ReplayState::Running);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_trailing_record_is_dropped_but_interior_corruption_rejected() {
        let dir = tmpdir("torn");
        let jr = Journal::open(&dir).unwrap();
        jr.submitted(1, &record(4, 4));
        jr.started(1);
        drop(jr);
        let path = dir.join(JOURNAL_FILE);
        // Crash mid-append: a torn final line replays cleanly.
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"event\":\"done\",\"id\":1,\"sta");
        std::fs::write(&path, &text).unwrap();
        let jobs = replay(&dir).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].state, ReplayState::Running);
        // Corruption anywhere else is not a crash artifact: reject.
        let interior = text.replace("\"event\":\"started\"", "\"event\":\"sta");
        std::fs::write(&path, interior).unwrap();
        match replay(&dir) {
            Err(ServiceError::InvalidData(_)) => {}
            other => panic!("interior corruption accepted: {:?}", other.map(|_| ())),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_journal_replays_empty_and_paths_are_stable() {
        let dir = tmpdir("paths");
        assert!(replay(&dir).unwrap().is_empty());
        let jr = Journal::open(&dir).unwrap();
        assert_eq!(jr.checkpoint_path(3), dir.join("checkpoints").join("job-3.ckpt"));
        assert_eq!(jr.result_path(3), dir.join("results").join("job-3.json"));
        assert!(replay(&dir).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
