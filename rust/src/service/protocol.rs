//! Newline-delimited JSON wire protocol for `spartan serve`.
//!
//! One request object per line, one response object per line, over a
//! plain TCP stream ([`crate::util::json`] does the encoding — no new
//! dependencies). Verbs:
//!
//! | verb       | request fields                                   | response |
//! |------------|--------------------------------------------------|----------|
//! | `ping`     | —                                                | `{"ok":true,"service":"spartan"}` |
//! | `submit`   | `input` (dataset path on the server), `rank`, optional `max_iters`/`tol`/`nonneg`/`seed`/`engine`/`cohort`/`shards` | `{"ok":true,"id":N}` |
//! | `status`   | `id`                                             | job snapshot (state, per-iteration records) |
//! | `cancel`   | `id`                                             | snapshot at token-set time |
//! | `result`   | `id`                                             | `ready` flag + the full model once terminal |
//! | `shutdown` | —                                                | `{"ok":true,"stopping":true}` |
//!
//! A `spartan shard-worker` process speaks the same framing with its own
//! verb set (`hello`/`plan`/`sweep`/`mode2`/`mode3`/`finish`/`abort`/
//! `shutdown`, plus `ping`), opened by a [`PROTOCOL_VERSION`] handshake.
//! The **normative spec** of the whole wire format — framing, every verb
//! above and every shard verb, payload schemas, error slugs, and the
//! bitwise-transport rationale — is `docs/PROTOCOL.md`; this module is
//! its implementation.
//!
//! Failures are `{"ok":false,"kind":K,"error":MSG,...}` with a stable
//! machine-readable `kind` per [`ServiceError`] variant.
//!
//! **Bitwise model transport.** `result` carries every factor matrix
//! (`H`, `V`, `W`, all `Q_k`) as arrays of 16-hex-digit IEEE-754 bit
//! patterns — the same idiom as the golden-trajectory fixture
//! ([`crate::bench::als_runner::golden`]) — so a model fetched over the
//! wire is **bit-identical** to the one the server fitted; JSON's
//! decimal float syntax never touches factor data. Timing fields in
//! `stats` and the per-iteration progress records are display-oriented
//! and travel as plain numbers; `final_sse`/`final_fit` also get bit
//! encodings so the SSE trajectory endpoint survives exactly.

use crate::linalg::Mat;
use crate::parafac2::{FitStats, IterationRecord, Parafac2Model};
use crate::service::{JobState, JobStatus, ServiceError};
use crate::util::json::Json;

/// Default listen address of `spartan serve`.
pub const DEFAULT_ADDR: &str = "127.0.0.1:7473";

/// Wire protocol version, exchanged in the shard `hello` handshake. A
/// worker built at a different version refuses the connection with an
/// `invalid` error naming both versions — a silent mismatch could merge
/// partials whose encoding (or merge order) changed, corrupting the
/// bitwise contract instead of failing loudly. Bump on any change to a
/// shard payload schema or to the documented merge/fold order
/// (`docs/PROTOCOL.md` keeps the version history).
///
/// Version 2 added the mandatory `kernel_backend` field to the shard
/// `hello` exchange: coordinator and worker each name their selected
/// kernel backend and the connection is refused on mismatch, so a
/// mixed-ISA topology (e.g. an `avx512` worker under an `avx2`
/// coordinator) fails loudly instead of silently merging trajectories
/// from different lane families.
///
/// Version 3 added the `reattach` verb: a coordinator that lost a worker
/// mid-fit reconnects (capped exponential backoff), replays `hello`, and
/// sends `reattach` — the `plan` fields plus the fit id, the current
/// iteration number, and the frozen pre-iteration `H`/`V`/`W` (this
/// shard's rows) — so a fresh worker process re-packs the same arena and
/// the fit resumes at the iteration boundary, bitwise identical to an
/// uninterrupted run. `shard_lost` now means *retries exhausted*, not
/// first failure.
pub const PROTOCOL_VERSION: u64 = 3;

// ---------------------------------------------------------------------------
// f64 bit-exact transport (golden-fixture idiom)

/// One f64 as a 16-hex-digit IEEE-754 bit pattern (`"3ff0000000000000"`).
pub fn f64_to_bits_str(x: f64) -> Json {
    Json::str(format!("{:016x}", x.to_bits()))
}

/// Inverse of [`f64_to_bits_str`].
pub fn f64_from_bits_str(j: &Json) -> Result<f64, String> {
    let s = j.as_str().ok_or("expected hex bit string")?;
    u64::from_str_radix(s, 16).map(f64::from_bits).map_err(|_| format!("bad f64 bits `{s}`"))
}

/// A flat f64 slice as an array of bit strings (per-slice norms, packed
/// mode-2 partial values — anything that must survive the wire bitwise).
pub fn f64_list_to_json(xs: &[f64]) -> Json {
    Json::arr(xs.iter().map(|x| f64_to_bits_str(*x)))
}

/// Inverse of [`f64_list_to_json`].
pub fn f64_list_from_json(j: &Json) -> Result<Vec<f64>, String> {
    j.as_arr().ok_or("expected bit-string array")?.iter().map(f64_from_bits_str).collect()
}

/// `{rows, cols, bits: ["3ff0…", …]}` — row-major, bit-exact.
pub fn mat_to_json(m: &Mat) -> Json {
    Json::obj(vec![
        ("rows", Json::num(m.rows() as f64)),
        ("cols", Json::num(m.cols() as f64)),
        ("bits", Json::arr(m.data().iter().map(|x| f64_to_bits_str(*x)))),
    ])
}

pub fn mat_from_json(j: &Json) -> Result<Mat, String> {
    let rows = j.get("rows").and_then(Json::as_usize).ok_or("mat missing rows")?;
    let cols = j.get("cols").and_then(Json::as_usize).ok_or("mat missing cols")?;
    let bits = j.get("bits").and_then(Json::as_arr).ok_or("mat missing bits")?;
    if bits.len() != rows * cols {
        return Err(format!("mat bits len {} ≠ {rows}×{cols}", bits.len()));
    }
    let data = bits.iter().map(f64_from_bits_str).collect::<Result<Vec<f64>, _>>()?;
    Ok(Mat::from_vec(rows, cols, data))
}

// ---------------------------------------------------------------------------
// Shard partial transport
//
// A shard never ships merged results — it ships the *unmerged* per-chunk
// partials of its contiguous run of global plan chunks, in chunk order, so
// the coordinator can replay the exact single-process fold over the global
// chunk sequence (see `docs/PROTOCOL.md` § determinism).

/// Per-chunk fused-sweep partials: `[{m1, yv}, …]` in chunk order.
pub fn m1_partials_to_json(parts: &[(Mat, u64)]) -> Json {
    Json::arr(parts.iter().map(|(m1, yv)| {
        Json::obj(vec![("m1", mat_to_json(m1)), ("yv", Json::num(*yv as f64))])
    }))
}

/// Inverse of [`m1_partials_to_json`].
pub fn m1_partials_from_json(j: &Json) -> Result<Vec<(Mat, u64)>, String> {
    j.as_arr()
        .ok_or("expected m1-partial array")?
        .iter()
        .map(|p| {
            let m1 = mat_from_json(p.get("m1").ok_or("partial missing m1")?)?;
            let yv = p.get("yv").and_then(Json::as_f64).ok_or("partial missing yv")? as u64;
            Ok((m1, yv))
        })
        .collect()
}

/// Per-chunk mode-2 partials: `[{ids, bits}, …]` in chunk order, `ids` in
/// the **global** `0..J` column space, `bits` the row-major values
/// (`ids.len()×R`) bit-encoded.
pub fn mode2_partials_to_json(parts: &[(Vec<u32>, Vec<f64>)]) -> Json {
    Json::arr(parts.iter().map(|(ids, vals)| {
        Json::obj(vec![
            ("ids", Json::arr(ids.iter().map(|&i| Json::num(i as f64)))),
            ("bits", f64_list_to_json(vals)),
        ])
    }))
}

/// Inverse of [`mode2_partials_to_json`]; `r` validates the per-chunk
/// value count (`ids.len()×r`).
pub fn mode2_partials_from_json(j: &Json, r: usize) -> Result<Vec<(Vec<u32>, Vec<f64>)>, String> {
    j.as_arr()
        .ok_or("expected mode2-partial array")?
        .iter()
        .map(|p| {
            let ids = p
                .get("ids")
                .and_then(Json::as_arr)
                .ok_or("partial missing ids")?
                .iter()
                .map(|v| v.as_usize().map(|u| u as u32).ok_or("bad support id"))
                .collect::<Result<Vec<u32>, _>>()?;
            let vals = f64_list_from_json(p.get("bits").ok_or("partial missing bits")?)?;
            if vals.len() != ids.len() * r {
                return Err(format!("mode2 partial vals len {} ≠ {}×{r}", vals.len(), ids.len()));
            }
            Ok((ids, vals))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Shard re-attach transport

/// Chunk ranges as `[[start,end], …]` — the same shape `plan` ships.
pub fn ranges_to_json(ranges: &[(usize, usize)]) -> Json {
    Json::arr(ranges.iter().map(|&(s, e)| {
        Json::arr(vec![Json::num(s as f64), Json::num(e as f64)])
    }))
}

/// Inverse of [`ranges_to_json`].
pub fn ranges_from_json(j: &Json) -> Result<Vec<(usize, usize)>, String> {
    j.as_arr()
        .ok_or("expected range array")?
        .iter()
        .map(|pair| {
            let p = pair.as_arr().filter(|p| p.len() == 2).ok_or("range must be [start,end]")?;
            let s = p[0].as_usize().ok_or("bad range start")?;
            let e = p[1].as_usize().ok_or("bad range end")?;
            Ok((s, e))
        })
        .collect()
}

/// Everything a `reattach` request carries (protocol v3): the `plan`
/// fields that rebuild the worker's arena, plus the fit identity and the
/// frozen pre-iteration factors (`w` holds only this shard's rows). The
/// factors travel bit-exactly — the replayed iteration must start from
/// the same snapshot the surviving shards replay from.
#[derive(Clone, Debug, PartialEq)]
pub struct ReattachPayload {
    pub fit_id: String,
    /// ALS iterations completed before the incident — the fit resumes at
    /// this iteration boundary.
    pub iter: u64,
    pub path: String,
    pub lo: usize,
    pub hi: usize,
    /// Rebased local chunk ranges (tile `0..hi-lo` exactly).
    pub ranges: Vec<(usize, usize)>,
    pub h: Mat,
    pub v: Mat,
    pub w: Mat,
}

/// Encode a `reattach` request line (includes the verb).
pub fn reattach_to_json(p: &ReattachPayload) -> Json {
    Json::obj(vec![
        ("verb", Json::str("reattach")),
        ("fit_id", Json::str(p.fit_id.clone())),
        ("iter", Json::num(p.iter as f64)),
        ("path", Json::str(p.path.clone())),
        ("lo", Json::num(p.lo as f64)),
        ("hi", Json::num(p.hi as f64)),
        ("ranges", ranges_to_json(&p.ranges)),
        ("h", mat_to_json(&p.h)),
        ("v", mat_to_json(&p.v)),
        ("w", mat_to_json(&p.w)),
    ])
}

/// Inverse of [`reattach_to_json`] (factors bit-exact).
pub fn reattach_from_json(j: &Json) -> Result<ReattachPayload, String> {
    Ok(ReattachPayload {
        fit_id: j
            .get("fit_id")
            .and_then(Json::as_str)
            .ok_or("reattach missing fit_id")?
            .to_string(),
        iter: j.get("iter").and_then(Json::as_f64).ok_or("reattach missing iter")? as u64,
        path: j.get("path").and_then(Json::as_str).ok_or("reattach missing path")?.to_string(),
        lo: j.get("lo").and_then(Json::as_usize).ok_or("reattach missing lo")?,
        hi: j.get("hi").and_then(Json::as_usize).ok_or("reattach missing hi")?,
        ranges: ranges_from_json(j.get("ranges").ok_or("reattach missing ranges")?)?,
        h: mat_from_json(j.get("h").ok_or("reattach missing h")?)?,
        v: mat_from_json(j.get("v").ok_or("reattach missing v")?)?,
        w: mat_from_json(j.get("w").ok_or("reattach missing w")?)?,
    })
}

// ---------------------------------------------------------------------------
// Model transport

pub fn model_to_json(m: &Parafac2Model) -> Json {
    let s = &m.stats;
    Json::obj(vec![
        ("rank", Json::num(m.rank as f64)),
        ("h", mat_to_json(&m.h)),
        ("v", mat_to_json(&m.v)),
        ("w", mat_to_json(&m.w)),
        ("q", Json::arr(m.q.iter().map(mat_to_json))),
        (
            "stats",
            Json::obj(vec![
                ("iterations", Json::num(s.iterations as f64)),
                ("final_sse_bits", f64_to_bits_str(s.final_sse)),
                ("final_fit_bits", f64_to_bits_str(s.final_fit)),
                ("final_sse", Json::num(s.final_sse)),
                ("final_fit", Json::num(s.final_fit)),
                ("total_secs", Json::num(s.total_secs)),
                ("procrustes_secs", Json::num(s.procrustes_secs)),
                ("cp_secs", Json::num(s.cp_secs)),
                ("secs_per_iter", Json::num(s.secs_per_iter)),
                ("yv_products", Json::num(s.yv_products as f64)),
                ("traversals", Json::num(s.traversals as f64)),
                ("x_traversals", Json::num(s.x_traversals as f64)),
                ("heap_bytes", Json::num(s.heap_bytes as f64)),
                ("shard_reconnects", Json::num(s.shard_reconnects as f64)),
                ("shard_retries", Json::num(s.shard_retries as f64)),
                ("resumed_from_iter", Json::num(s.resumed_from_iter as f64)),
                ("kernel_backend", Json::str(s.kernel_backend.clone())),
            ]),
        ),
    ])
}

/// Inverse of [`model_to_json`]. `fit_history` does not travel (it is
/// reconstructible from the status records); everything else round-trips,
/// factors bit-exactly.
pub fn model_from_json(j: &Json) -> Result<Parafac2Model, String> {
    let rank = j.get("rank").and_then(Json::as_usize).ok_or("model missing rank")?;
    let h = mat_from_json(j.get("h").ok_or("model missing h")?)?;
    let v = mat_from_json(j.get("v").ok_or("model missing v")?)?;
    let w = mat_from_json(j.get("w").ok_or("model missing w")?)?;
    let q = j
        .get("q")
        .and_then(Json::as_arr)
        .ok_or("model missing q")?
        .iter()
        .map(mat_from_json)
        .collect::<Result<Vec<Mat>, _>>()?;
    let sj = j.get("stats").ok_or("model missing stats")?;
    let num = |k: &str| sj.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    let stats = FitStats {
        iterations: sj.get("iterations").and_then(Json::as_usize).unwrap_or(0),
        final_sse: sj.get("final_sse_bits").map(f64_from_bits_str).transpose()?.unwrap_or(0.0),
        final_fit: sj.get("final_fit_bits").map(f64_from_bits_str).transpose()?.unwrap_or(0.0),
        fit_history: Vec::new(),
        total_secs: num("total_secs"),
        procrustes_secs: num("procrustes_secs"),
        cp_secs: num("cp_secs"),
        secs_per_iter: num("secs_per_iter"),
        yv_products: num("yv_products") as u64,
        traversals: num("traversals") as u64,
        x_traversals: num("x_traversals") as u64,
        heap_bytes: num("heap_bytes") as u64,
        shard_reconnects: num("shard_reconnects") as u64,
        shard_retries: num("shard_retries") as u64,
        resumed_from_iter: num("resumed_from_iter") as u64,
        kernel_backend: sj
            .get("kernel_backend")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
    };
    Ok(Parafac2Model { rank, h, v, w, q, stats })
}

// ---------------------------------------------------------------------------
// Status transport

pub fn record_to_json(r: &IterationRecord) -> Json {
    Json::obj(vec![
        ("iter", Json::num(r.iter as f64)),
        ("sse", Json::num(r.sse)),
        ("fit", Json::num(r.fit)),
        ("procrustes_secs", Json::num(r.procrustes_secs)),
        ("cp_secs", Json::num(r.cp_secs)),
    ])
}

/// Snapshot → response body (caller adds `"ok": true`).
pub fn status_to_json(s: &JobStatus) -> Json {
    let mut fields = vec![
        ("id", Json::num(s.id as f64)),
        ("state", Json::str(s.state.as_str())),
        ("iterations", Json::num(s.records.len() as f64)),
        ("warm_started", Json::Bool(s.warm_started)),
        ("estimate_bytes", Json::num(s.estimate_bytes as f64)),
        ("subjects", Json::num(s.subjects as f64)),
        ("variables", Json::num(s.variables as f64)),
        ("nnz", Json::num(s.nnz as f64)),
        ("records", Json::arr(s.records.iter().map(record_to_json))),
    ];
    if let JobState::Failed(reason) = &s.state {
        fields.push(("reason", Json::str(reason.clone())));
    }
    if let Some(last) = s.records.last() {
        fields.push(("fit", Json::num(last.fit)));
        fields.push(("sse", Json::num(last.sse)));
    }
    Json::obj(fields)
}

// ---------------------------------------------------------------------------
// Responses & errors

/// `{"ok":true, …fields}`.
pub fn ok_response(mut fields: Vec<(&str, Json)>) -> Json {
    fields.insert(0, ("ok", Json::Bool(true)));
    Json::obj(fields)
}

/// Stable machine-readable `kind` slug per error variant.
pub fn error_kind(e: &ServiceError) -> &'static str {
    match e {
        ServiceError::QueueFull { .. } => "queue_full",
        ServiceError::BudgetExceeded { .. } => "budget_exceeded",
        ServiceError::UnknownJob(_) => "unknown_job",
        ServiceError::JobFailed { .. } => "job_failed",
        ServiceError::Invalid(_) => "invalid",
        ServiceError::InvalidData(_) => "invalid_data",
        ServiceError::ShuttingDown => "shutting_down",
        ServiceError::ShardLost(_) => "shard_lost",
        ServiceError::Io(_) => "io",
        ServiceError::Protocol(_) => "protocol",
    }
}

/// `{"ok":false,"kind":…,"error":…}` plus the variant's structured fields.
pub fn error_to_response(e: &ServiceError) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(false)),
        ("kind", Json::str(error_kind(e))),
        ("error", Json::str(e.to_string())),
    ];
    match e {
        ServiceError::QueueFull { pending, max } => {
            fields.push(("pending", Json::num(*pending as f64)));
            fields.push(("max", Json::num(*max as f64)));
        }
        ServiceError::BudgetExceeded { estimate, limit } => {
            fields.push(("estimate", Json::num(*estimate as f64)));
            fields.push(("limit", Json::num(*limit as f64)));
        }
        ServiceError::UnknownJob(id) | ServiceError::JobFailed { id, .. } => {
            fields.push(("id", Json::num(*id as f64)));
        }
        ServiceError::ShardLost(which) => {
            // `error` carries the "shard lost: …" rendering; this field
            // keeps the inner message so the variant round-trips exactly.
            fields.push(("shard", Json::str(which.clone())));
        }
        _ => {}
    }
    Json::obj(fields)
}

/// Reconstruct a [`ServiceError`] from a `{"ok":false,…}` response.
pub fn error_from_response(j: &Json) -> ServiceError {
    let msg = j.get("error").and_then(Json::as_str).unwrap_or("unknown error").to_string();
    let u64_of = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
    match j.get("kind").and_then(Json::as_str).unwrap_or("") {
        "queue_full" => ServiceError::QueueFull {
            pending: u64_of("pending") as usize,
            max: u64_of("max") as usize,
        },
        "budget_exceeded" => {
            ServiceError::BudgetExceeded { estimate: u64_of("estimate"), limit: u64_of("limit") }
        }
        "unknown_job" => ServiceError::UnknownJob(u64_of("id")),
        "job_failed" => ServiceError::JobFailed { id: u64_of("id"), reason: msg },
        "invalid" => ServiceError::Invalid(msg),
        "invalid_data" => ServiceError::InvalidData(msg),
        "shutting_down" => ServiceError::ShuttingDown,
        "shard_lost" => ServiceError::ShardLost(
            j.get("shard").and_then(Json::as_str).map(str::to_string).unwrap_or(msg),
        ),
        "io" => ServiceError::Io(msg),
        _ => ServiceError::Protocol(msg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::synthetic::{generate, SyntheticSpec};
    use crate::parafac2::{fit_parafac2, Parafac2Config};
    use crate::util::json;

    #[test]
    fn mat_roundtrip_is_bitwise_even_for_odd_values() {
        let m = Mat::from_vec(
            2,
            3,
            vec![0.1 + 0.2, -0.0, f64::MIN_POSITIVE, 1.0 / 3.0, 6.02214076e23, 1e-300],
        );
        let j = mat_to_json(&m);
        let text = j.to_string();
        let back = mat_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.rows(), 2);
        assert_eq!(back.cols(), 3);
        assert_eq!(back.data(), m.data());
    }

    #[test]
    fn model_roundtrip_is_bitwise_through_a_real_fit() {
        let d = generate(&SyntheticSpec {
            k: 12,
            j: 8,
            max_i_k: 5,
            target_nnz: 400,
            rank: 2,
            noise: 0.05,
            seed: 7,
        })
        .tensor;
        let cfg = Parafac2Config { rank: 2, max_iters: 4, workers: 1, ..Default::default() };
        let model = fit_parafac2(&d, &cfg).unwrap();
        let text = model_to_json(&model).to_string();
        let back = model_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.rank, model.rank);
        assert_eq!(back.h.data(), model.h.data());
        assert_eq!(back.v.data(), model.v.data());
        assert_eq!(back.w.data(), model.w.data());
        assert_eq!(back.q.len(), model.q.len());
        for (a, b) in back.q.iter().zip(&model.q) {
            assert_eq!(a.data(), b.data());
        }
        assert_eq!(back.stats.final_sse.to_bits(), model.stats.final_sse.to_bits());
        assert_eq!(back.stats.final_fit.to_bits(), model.stats.final_fit.to_bits());
        assert_eq!(back.stats.iterations, model.stats.iterations);
        assert!(!model.stats.kernel_backend.is_empty(), "fit must record its backend");
        assert_eq!(back.stats.kernel_backend, model.stats.kernel_backend);
    }

    #[test]
    fn shard_partials_roundtrip_bitwise() {
        let parts = vec![
            (Mat::from_vec(2, 2, vec![0.1 + 0.2, -0.0, 1.0 / 3.0, 1e-300]), 7u64),
            (Mat::from_vec(2, 2, vec![1.5, 2.5, -3.5, 4.5]), 0u64),
        ];
        let text = m1_partials_to_json(&parts).to_string();
        let back = m1_partials_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.len(), 2);
        for ((m, n), (bm, bn)) in parts.iter().zip(&back) {
            assert_eq!(m.data(), bm.data());
            assert_eq!(n, bn);
        }

        let m2 = vec![
            (vec![0u32, 3, 9], vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]),
            (vec![4u32], vec![-0.0, f64::MIN_POSITIVE]),
        ];
        let text = mode2_partials_to_json(&m2).to_string();
        let back = mode2_partials_from_json(&json::parse(&text).unwrap(), 2).unwrap();
        assert_eq!(back.len(), 2);
        for ((ids, vals), (bids, bvals)) in m2.iter().zip(&back) {
            assert_eq!(ids, bids);
            assert_eq!(vals.len(), bvals.len());
            for (a, b) in vals.iter().zip(bvals) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // wrong rank → length validation trips
        assert!(mode2_partials_from_json(&json::parse(&text).unwrap(), 3).is_err());
    }

    #[test]
    fn reattach_roundtrip_is_bitwise() {
        let p = ReattachPayload {
            fit_id: "fit-1234-0".into(),
            iter: 5,
            path: "/data/shared.spt".into(),
            lo: 64,
            hi: 192,
            ranges: vec![(0, 64), (64, 128)],
            h: Mat::from_vec(2, 2, vec![0.1 + 0.2, -0.0, 1.0 / 3.0, 1e-300]),
            v: Mat::from_vec(3, 2, vec![1.5, -2.5, f64::MIN_POSITIVE, 0.0, 6.02e23, -1.0]),
            w: Mat::from_vec(2, 2, vec![0.25, 0.5, 0.75, 1.0]),
        };
        let line = reattach_to_json(&p).to_string();
        let parsed = json::parse(&line).unwrap();
        assert_eq!(parsed.get("verb").and_then(Json::as_str), Some("reattach"));
        let back = reattach_from_json(&parsed).unwrap();
        assert_eq!(back, p);
        // `==` on f64 treats -0.0 == 0.0; the factors must survive *bitwise*.
        for (m, bm) in [(&p.h, &back.h), (&p.v, &back.v), (&p.w, &back.w)] {
            for (a, b) in m.data().iter().zip(bm.data()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn errors_roundtrip_with_structured_fields() {
        let cases = vec![
            ServiceError::QueueFull { pending: 9, max: 9 },
            ServiceError::BudgetExceeded { estimate: 123_456, limit: 99 },
            ServiceError::UnknownJob(41),
            ServiceError::JobFailed { id: 6, reason: "job 6 failed: boom".into() },
            ServiceError::InvalidData("slice 3: value at row 1 is not finite".into()),
            ServiceError::ShuttingDown,
            ServiceError::ShardLost("shard 1 (127.0.0.1:9) died: eof".into()),
        ];
        for e in cases {
            let resp = error_to_response(&e);
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
            let back = error_from_response(&json::parse(&resp.to_string()).unwrap());
            match (&e, &back) {
                (ServiceError::QueueFull { pending: a, max: b },
                 ServiceError::QueueFull { pending: c, max: d }) => {
                    assert_eq!((a, b), (c, d));
                }
                (ServiceError::BudgetExceeded { estimate: a, limit: b },
                 ServiceError::BudgetExceeded { estimate: c, limit: d }) => {
                    assert_eq!((a, b), (c, d));
                }
                (ServiceError::UnknownJob(a), ServiceError::UnknownJob(b)) => assert_eq!(a, b),
                (ServiceError::JobFailed { id: a, .. }, ServiceError::JobFailed { id: b, .. }) => {
                    assert_eq!(a, b)
                }
                (ServiceError::InvalidData(_), ServiceError::InvalidData(_)) => {}
                (ServiceError::ShuttingDown, ServiceError::ShuttingDown) => {}
                (ServiceError::ShardLost(a), ServiceError::ShardLost(b)) => assert_eq!(a, b),
                other => panic!("variant changed across the wire: {other:?}"),
            }
        }
    }
}
