//! Warm-model cache keyed by cohort id.
//!
//! Re-fits of an updated cohort (nightly EHR refresh, a MovieLens window
//! sliding one week) converge in far fewer sweeps when seeded from the
//! previous factors than from SvdWarm init. The service keeps the most
//! recent `H/V/W` per cohort id; a submit that names the same cohort and
//! matches its shape picks them up as a [`WarmStart`] instead of running
//! initialization.
//!
//! Shape discipline: a cached start is only handed out when the rank,
//! variable count `J`, **and** subject count `K` all match — `W` is `K×R`,
//! so a cohort that gained subjects cannot reuse the old factors directly
//! (that is ROADMAP item 3's append path, not a cache hit). A mismatch is
//! a silent miss, never an error: the job simply cold-starts.
//!
//! Recency is LRU over both hits and inserts, bounded by `capacity`
//! (capacity 0 disables the cache entirely).

use crate::parafac2::WarmStart;
use std::collections::VecDeque;

/// Bounded LRU of the latest fitted factors per cohort id.
pub struct WarmCache {
    capacity: usize,
    /// Most recently used at the back.
    entries: VecDeque<(String, WarmStart)>,
}

impl WarmCache {
    pub fn new(capacity: usize) -> WarmCache {
        WarmCache { capacity, entries: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert (or replace) the factors for `cohort`, evicting the least
    /// recently used entry when over capacity.
    pub fn put(&mut self, cohort: &str, warm: WarmStart) {
        if self.capacity == 0 {
            return;
        }
        self.entries.retain(|(k, _)| k != cohort);
        self.entries.push_back((cohort.to_string(), warm));
        while self.entries.len() > self.capacity {
            self.entries.pop_front();
        }
    }

    /// Clone the cached start for `cohort` if its shape matches the job
    /// (`rank`, `J`, `K`); refreshes recency on hit. Shape mismatch or an
    /// unknown cohort is a miss.
    pub fn get(&mut self, cohort: &str, rank: usize, j: usize, k: usize) -> Option<WarmStart> {
        let pos = self.entries.iter().position(|(key, _)| key == cohort)?;
        let fits = {
            let (_, w) = &self.entries[pos];
            w.h.shape() == (rank, rank) && w.v.shape() == (j, rank) && w.w.shape() == (k, rank)
        };
        if !fits {
            return None;
        }
        let entry = self.entries.remove(pos).expect("position just found");
        let warm = entry.1.clone();
        self.entries.push_back(entry);
        Some(warm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn warm(rank: usize, j: usize, k: usize) -> WarmStart {
        WarmStart {
            h: Mat::zeros(rank, rank),
            v: Mat::zeros(j, rank),
            w: Mat::zeros(k, rank),
        }
    }

    #[test]
    fn put_get_roundtrip_and_shape_gate() {
        let mut c = WarmCache::new(4);
        c.put("ehr-2026w31", warm(3, 10, 20));
        assert!(c.get("ehr-2026w31", 3, 10, 20).is_some());
        // any shape mismatch is a miss, not an error
        assert!(c.get("ehr-2026w31", 4, 10, 20).is_none());
        assert!(c.get("ehr-2026w31", 3, 11, 20).is_none());
        assert!(c.get("ehr-2026w31", 3, 10, 21).is_none());
        assert!(c.get("unknown", 3, 10, 20).is_none());
    }

    #[test]
    fn replaces_existing_cohort_entry() {
        let mut c = WarmCache::new(2);
        c.put("a", warm(2, 5, 5));
        c.put("a", warm(3, 5, 5));
        assert_eq!(c.len(), 1);
        assert!(c.get("a", 2, 5, 5).is_none());
        assert!(c.get("a", 3, 5, 5).is_some());
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = WarmCache::new(2);
        c.put("a", warm(2, 5, 5));
        c.put("b", warm(2, 5, 5));
        assert!(c.get("a", 2, 5, 5).is_some()); // refresh `a`
        c.put("c", warm(2, 5, 5)); // evicts `b`, the LRU
        assert!(c.get("b", 2, 5, 5).is_none());
        assert!(c.get("a", 2, 5, 5).is_some());
        assert!(c.get("c", 2, 5, 5).is_some());
    }

    #[test]
    fn zero_capacity_disables_cache() {
        let mut c = WarmCache::new(0);
        c.put("a", warm(2, 5, 5));
        assert!(c.is_empty());
        assert!(c.get("a", 2, 5, 5).is_none());
    }
}
