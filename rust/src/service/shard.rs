//! Sharded fitting: `spartan shard-worker` processes own contiguous
//! subject ranges; a coordinator replays the single-process merge.
//!
//! **Unit of distribution: the subject.** Each worker loads the shared
//! dataset file, slices out its contiguous subject range, packs its own
//! compact-X arena, and serves one ALS phase per request — only `R×R`
//! mode-1 partials, support-compact mode-2 partials, `K_s×R` mode-3
//! blocks, and per-slice norm bits ever cross the wire (framing and
//! payload schemas: `docs/PROTOCOL.md`). The coordinator
//! ([`ShardedFitSession`]) holds no slice data at all: it drives the
//! per-iteration fan-out and runs the factor-sized algebra locally.
//!
//! **Bitwise determinism.** A sharded fit must reproduce the
//! single-process trajectory *bitwise* (pinned by
//! `rust/tests/shard_e2e.rs`; the golden gate is never re-blessed for
//! sharding). Three decisions make that hold:
//!
//! 1. **Shards align to the global chunk plan.** The coordinator builds
//!    the same nnz-balanced [`subject_plan`] a local fit would and deals
//!    each shard a contiguous *run of whole chunks*; a worker executes
//!    its run with the plan chunk boundaries intact (rebased to its local
//!    subject indices), so every per-chunk reduction happens over exactly
//!    the subjects it would cover locally.
//! 2. **Workers ship unmerged per-chunk partials.** No shard-local
//!    folding: the coordinator concatenates the per-chunk partials in
//!    global chunk order and replays the *flat* single-process folds —
//!    [`merge_fused_partials`] for M¹, [`mode2_merge`] for M², plain row
//!    concatenation for M³ (a pure copy, no arithmetic) — instead of a
//!    two-level shard-then-global reduction, which FP non-associativity
//!    would make a different (non-bitwise) sum.
//! 3. **Norms travel as bits, folded in subject order.** `‖X‖²`/`‖Y‖²`
//!    are flat left-to-right sums over per-slice cached norms; workers
//!    ship the per-slice values bit-exactly and the coordinator runs the
//!    identical fold over all `K` in subject order.
//!
//! Init runs on the coordinator (it is data-shape-dependent only, and
//! bitwise across pool sizes per the determinism contract), as does every
//! factor-sized solve — through the *same* `cp_als`/`blas`/`solve`
//! functions the local path uses.
//!
//! **Robustness.** Every worker connection carries a read timeout; a
//! refused connect, EOF, timeout, or structured worker error surfaces as
//! [`ServiceError::ShardLost`] naming the shard, after a best-effort
//! `abort` fan-out to the surviving workers. Cancellation is observed at
//! the same checkpoints as a local [`crate::parafac2::FitSession`] (step
//! entry and post-sweep), so a cancel reaches every shard within one
//! iteration — workers are request-driven and simply stop being asked.

use crate::linalg::{blas, kernels, solve, Mat};
use crate::parafac2::als::{fit_from_sse, sse_converged, sse_from_parts};
use crate::parafac2::cp_als::{normalize_cols_safe, residual_stats, solve_mode, CpFactors};
use crate::parafac2::init::initialize;
use crate::parafac2::intermediate::PackedY;
use crate::parafac2::mttkrp::{
    mode2_merge, mttkrp_mode2_partials_cached, mttkrp_mode3, mttkrp_mode3_from_cache,
    FusedScratch,
};
use crate::parafac2::procrustes::{
    merge_fused_partials, procrustes_all_into, procrustes_pack_mode1_partials,
    scratch_heap_bytes, subject_plan, SubjectScratch,
};
use crate::parafac2::{
    Backend, FitStats, IterationRecord, Parafac2Config, Parafac2Model, StepOutcome,
};
use crate::service::protocol::{
    error_to_response, f64_list_from_json, f64_list_to_json, m1_partials_from_json,
    m1_partials_to_json, mat_from_json, mat_to_json, mode2_partials_from_json,
    mode2_partials_to_json, ok_response, PROTOCOL_VERSION,
};
use crate::service::ServiceError;
use crate::sparse::{CompactX, IrregularTensor};
use crate::threadpool::{ChunkPlan, Pool};
use crate::util::json::{self, Json};
use crate::util::timer::Stopwatch;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Default per-response read timeout on coordinator→worker connections.
/// Generous — a worker phase is a fraction of a local iteration — but
/// finite, so a hung worker becomes [`ServiceError::ShardLost`] instead
/// of a hung coordinator.
pub const DEFAULT_READ_TIMEOUT_SECS: u64 = 600;

/// Where the shards are and what they should load.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardSpec {
    /// Worker addresses (`host:port`), one per shard, in subject order:
    /// shard 0 gets the lowest subject range.
    pub addrs: Vec<String>,
    /// Dataset path, resolvable by **every worker** (shared filesystem —
    /// the same convention as `submit`'s `input`).
    pub path: String,
    /// Per-response read timeout (seconds) on worker connections.
    pub read_timeout_secs: u64,
}

impl ShardSpec {
    pub fn new(addrs: Vec<String>, path: impl Into<String>) -> ShardSpec {
        ShardSpec { addrs, path: path.into(), read_timeout_secs: DEFAULT_READ_TIMEOUT_SECS }
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Everything a worker holds for its subject range between requests:
/// the same arenas a local [`crate::parafac2::FitSession`] owns, built
/// over the *rebased* chunk plan so chunk boundaries match the global
/// plan exactly.
struct WorkerFit {
    pool: Pool,
    plan: ChunkPlan,
    cx: CompactX,
    y: PackedY,
    sweep_scratch: Vec<SubjectScratch>,
    scratch: FusedScratch,
    /// This shard's `W` rows as of the last `sweep` — mode 2 consumes the
    /// pre-update `W` with the post-update `H`, mirroring
    /// [`crate::parafac2::cp_als::cp_iteration_from_m1`].
    w: Mat,
    /// Phase tracking: `sweep` must precede `mode2`, `mode2` must precede
    /// `mode3` (the `Z_k` cache is filled by mode 2).
    swept: bool,
    mode2_done: bool,
}

/// Run a shard worker: bind, announce the resolved address on stdout
/// (machine-parsable, same idiom as `spartan serve`), and serve
/// coordinators until a `shutdown` request. One coordinator connection at
/// a time — the fit protocol is strictly sequential — with per-connection
/// state dropped at EOF, so a worker survives its coordinator and can
/// serve the next fit.
pub fn run_worker(addr: &str, workers: usize) -> Result<(), ServiceError> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| ServiceError::Io(format!("bind {addr}: {e}")))?;
    let local = listener.local_addr().map_err(|e| ServiceError::Io(e.to_string()))?;
    {
        let stdout = std::io::stdout();
        let mut out = stdout.lock();
        let _ = writeln!(out, "spartan shard-worker: listening on {local} (workers {workers})");
        let _ = out.flush();
    }
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        if !serve_coordinator(stream, workers) {
            return Ok(());
        }
    }
    Ok(())
}

/// Serve one coordinator connection to EOF. Returns `false` when a
/// `shutdown` request asks the whole worker process to exit.
fn serve_coordinator(stream: TcpStream, workers: usize) -> bool {
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return true,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = BufWriter::new(stream);
    let mut state: Option<WorkerFit> = None;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return true,
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        let (resp, quit) = dispatch_worker(&mut state, workers, line.trim());
        if writeln!(writer, "{}", resp.to_string()).is_err() || writer.flush().is_err() {
            return true;
        }
        if quit {
            return false;
        }
    }
}

/// One request line → (response, stop-the-worker-process?).
fn dispatch_worker(state: &mut Option<WorkerFit>, workers: usize, line: &str) -> (Json, bool) {
    let req = match json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            return (error_to_response(&ServiceError::Protocol(format!("bad request: {e}"))), false)
        }
    };
    let verb = req.get("verb").and_then(Json::as_str).unwrap_or("");
    if verb == "shutdown" {
        return (ok_response(vec![("stopping", Json::Bool(true))]), true);
    }
    let resp = match verb {
        "ping" => Ok(ok_response(vec![("service", Json::str("spartan-shard"))])),
        "hello" => handle_hello(&req),
        "plan" => handle_plan(state, workers, &req),
        "sweep" => handle_sweep(state, &req),
        "mode2" => handle_mode2(state, &req),
        "mode3" => handle_mode3(state, &req),
        "finish" => handle_finish(state, &req),
        "abort" => {
            *state = None;
            Ok(ok_response(vec![("aborted", Json::Bool(true))]))
        }
        other => Err(ServiceError::Protocol(format!("unknown verb `{other}`"))),
    };
    match resp {
        Ok(j) => (j, false),
        Err(e) => (error_to_response(&e), false),
    }
}

fn handle_hello(req: &Json) -> Result<Json, ServiceError> {
    let theirs = req.get("version").and_then(Json::as_f64).map(|x| x as u64);
    match theirs {
        Some(v) if v == PROTOCOL_VERSION => {}
        Some(v) => {
            return Err(ServiceError::Invalid(format!(
                "protocol version mismatch: coordinator speaks {v}, worker speaks {PROTOCOL_VERSION}"
            )))
        }
        None => return Err(ServiceError::Protocol("hello requires `version`".into())),
    }
    // Same-version peers must also be in the same kernel lane family — a
    // worker running a different backend than the coordinator (e.g. the
    // reordered `avx512` under a bitwise coordinator, or mixed ISAs on
    // heterogeneous hosts) would merge partials from a different FP
    // trajectory. Reject loudly instead of silently diverging.
    let ours = kernels::active_backend().name();
    match req.get("kernel_backend").and_then(Json::as_str) {
        Some(k) if k == ours => Ok(ok_response(vec![
            ("service", Json::str("spartan-shard")),
            ("version", Json::num(PROTOCOL_VERSION as f64)),
            ("kernel_backend", Json::str(ours)),
        ])),
        Some(k) => Err(ServiceError::Invalid(format!(
            "kernel backend mismatch: coordinator runs `{k}`, worker runs `{ours}` \
             (force a common backend with --kernel/SPARTAN_KERNEL)"
        ))),
        None => Err(ServiceError::Protocol("hello requires `kernel_backend`".into())),
    }
}

fn handle_plan(
    state: &mut Option<WorkerFit>,
    workers: usize,
    req: &Json,
) -> Result<Json, ServiceError> {
    let path = req
        .get("path")
        .and_then(Json::as_str)
        .ok_or_else(|| ServiceError::Protocol("plan requires `path`".into()))?;
    let lo = req
        .get("lo")
        .and_then(Json::as_usize)
        .ok_or_else(|| ServiceError::Protocol("plan requires `lo`".into()))?;
    let hi = req
        .get("hi")
        .and_then(Json::as_usize)
        .ok_or_else(|| ServiceError::Protocol("plan requires `hi`".into()))?;
    let ranges = req
        .get("ranges")
        .and_then(Json::as_arr)
        .ok_or_else(|| ServiceError::Protocol("plan requires `ranges`".into()))?
        .iter()
        .map(|pair| {
            let p = pair.as_arr().filter(|p| p.len() == 2).ok_or("range must be [start,end]")?;
            let s = p[0].as_usize().ok_or("bad range start")?;
            let e = p[1].as_usize().ok_or("bad range end")?;
            Ok(s..e)
        })
        .collect::<Result<Vec<Range<usize>>, &str>>()
        .map_err(|e| ServiceError::Protocol(e.into()))?;

    let full = super::server::load_tensor(path)?;
    if lo >= hi || hi > full.k() {
        return Err(ServiceError::Invalid(format!(
            "subject range {lo}..{hi} out of bounds for K={}",
            full.k()
        )));
    }
    // Contiguous subject range, local indices 0..(hi-lo). The rebased
    // chunk ranges must tile it exactly — `from_ranges` validates.
    let local = IrregularTensor::new_unchecked(full.slices()[lo..hi].to_vec());
    let plan = ChunkPlan::from_ranges(ranges, hi - lo).map_err(ServiceError::Invalid)?;
    let pool = Pool::new(workers);
    let cx = CompactX::pack(&local, &pool, &plan);
    let x_norm_bits: Vec<f64> = cx.slices.iter().map(|s| s.norm_sq()).collect();
    let (j, nnz) = (local.j(), local.nnz());
    let y = PackedY::empty(j);
    let sweep_scratch = SubjectScratch::for_plan(&plan);
    // The original CSR slices drop here — every fit-path read below is
    // served by the arena, the same memory diet as an owned FitSession.
    *state = Some(WorkerFit {
        pool,
        plan,
        cx,
        y,
        sweep_scratch,
        scratch: FusedScratch::new(),
        w: Mat::zeros(0, 0),
        swept: false,
        mode2_done: false,
    });
    Ok(ok_response(vec![
        ("k", Json::num((hi - lo) as f64)),
        ("j", Json::num(j as f64)),
        ("nnz", Json::num(nnz as f64)),
        ("x_norm_bits", f64_list_to_json(&x_norm_bits)),
    ]))
}

fn planned(state: &mut Option<WorkerFit>) -> Result<&mut WorkerFit, ServiceError> {
    state.as_mut().ok_or_else(|| ServiceError::Invalid("no plan loaded (send `plan` first)".into()))
}

fn req_mat(req: &Json, key: &str) -> Result<Mat, ServiceError> {
    let j = req
        .get(key)
        .ok_or_else(|| ServiceError::Protocol(format!("request missing `{key}`")))?;
    mat_from_json(j).map_err(ServiceError::Protocol)
}

fn handle_sweep(state: &mut Option<WorkerFit>, req: &Json) -> Result<Json, ServiceError> {
    let st = planned(state)?;
    let (v, h, w) = (req_mat(req, "v")?, req_mat(req, "h")?, req_mat(req, "w")?);
    let r = v.cols();
    if h.rows() != r || h.cols() != r || w.cols() != r || v.rows() != st.cx.j() {
        return Err(ServiceError::Invalid(format!(
            "sweep factor shapes {:?}/{:?}/{:?} do not match J={}, R={r}",
            v.shape(),
            h.shape(),
            w.shape(),
            st.cx.j()
        )));
    }
    if w.rows() != st.cx.k() {
        return Err(ServiceError::Invalid(format!(
            "sweep W has {} rows but the shard owns {} subjects",
            w.rows(),
            st.cx.k()
        )));
    }
    st.w = w;
    let partials = procrustes_pack_mode1_partials(
        &st.cx,
        &v,
        &h,
        &st.w,
        &st.pool,
        &st.plan,
        &mut st.y,
        &mut st.sweep_scratch,
    );
    st.swept = true;
    st.mode2_done = false;
    let y_norm_bits: Vec<f64> = st.y.slices.iter().map(|s| s.norm_sq()).collect();
    Ok(ok_response(vec![
        ("m1", m1_partials_to_json(&partials)),
        ("y_norm_bits", f64_list_to_json(&y_norm_bits)),
    ]))
}

fn handle_mode2(state: &mut Option<WorkerFit>, req: &Json) -> Result<Json, ServiceError> {
    let st = planned(state)?;
    if !st.swept {
        return Err(ServiceError::Invalid("mode2 before sweep".into()));
    }
    let h = req_mat(req, "h")?;
    if h.rows() != h.cols() || h.cols() != st.w.cols() {
        return Err(ServiceError::Invalid(format!(
            "mode2 H shape {:?} does not match rank {}",
            h.shape(),
            st.w.cols()
        )));
    }
    let partials =
        mttkrp_mode2_partials_cached(&st.y, &h, &st.w, &st.pool, &st.plan, &mut st.scratch);
    st.mode2_done = true;
    Ok(ok_response(vec![("m2", mode2_partials_to_json(&partials))]))
}

fn handle_mode3(state: &mut Option<WorkerFit>, req: &Json) -> Result<Json, ServiceError> {
    let st = planned(state)?;
    if !st.mode2_done {
        return Err(ServiceError::Invalid("mode3 before mode2".into()));
    }
    let v = req_mat(req, "v")?;
    if v.rows() != st.cx.j() || v.cols() != st.w.cols() {
        return Err(ServiceError::Invalid(format!(
            "mode3 V shape {:?} does not match J={}, R={}",
            v.shape(),
            st.cx.j(),
            st.w.cols()
        )));
    }
    let m3 = mttkrp_mode3_from_cache(&st.y, &v, &st.scratch, &st.pool, &st.plan);
    Ok(ok_response(vec![("m3", mat_to_json(&m3))]))
}

fn handle_finish(state: &mut Option<WorkerFit>, req: &Json) -> Result<Json, ServiceError> {
    let st = planned(state)?;
    let (v, h, w) = (req_mat(req, "v")?, req_mat(req, "h")?, req_mat(req, "w")?);
    let r = v.cols();
    if v.rows() != st.cx.j() || h.rows() != r || h.cols() != r || w.cols() != r {
        return Err(ServiceError::Invalid("finish factor shapes mismatch".into()));
    }
    if w.rows() != st.cx.k() {
        return Err(ServiceError::Invalid(format!(
            "finish W has {} rows but the shard owns {} subjects",
            w.rows(),
            st.cx.k()
        )));
    }
    st.w = w;
    let qs = procrustes_all_into(
        &st.cx,
        &v,
        &h,
        &st.w,
        &st.pool,
        &st.plan,
        true,
        &mut st.y,
        &mut st.sweep_scratch,
    )
    .expect("keep_q requested");
    let m3 = mttkrp_mode3(&st.y, &h, &v, &st.pool, &st.plan);
    let y_norm_bits: Vec<f64> = st.y.slices.iter().map(|s| s.norm_sq()).collect();
    let heap = st.cx.heap_bytes()
        + st.y.heap_bytes()
        + scratch_heap_bytes(&st.sweep_scratch)
        + st.scratch.heap_bytes();
    Ok(ok_response(vec![
        ("q", Json::arr(qs.iter().map(mat_to_json))),
        ("m3", mat_to_json(&m3)),
        ("y_norm_bits", f64_list_to_json(&y_norm_bits)),
        ("yv_products", Json::num(st.y.yv_products() as f64)),
        ("traversals", Json::num(st.y.traversals() as f64)),
        ("x_traversals", Json::num(st.cx.x_traversals() as f64)),
        ("heap_bytes", Json::num(heap as f64)),
    ]))
}

// ---------------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------------

/// One persistent coordinator→worker connection, carrying this shard's
/// subject range and its run of global plan chunks.
struct ShardConn {
    index: usize,
    addr: String,
    subjects: Range<usize>,
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl ShardConn {
    fn lost(&self, what: &str) -> ServiceError {
        ServiceError::ShardLost(format!("shard {} ({}): {what}", self.index, self.addr))
    }

    /// Fan-out half: write one request line.
    fn send(&mut self, req: &Json) -> Result<(), ServiceError> {
        writeln!(self.writer, "{}", req.to_string())
            .and_then(|_| self.writer.flush())
            .map_err(|e| self.lost(&format!("write failed: {e}")))
    }

    /// Fan-in half: read one response line (bounded by the read timeout),
    /// surfacing worker-side errors typed.
    fn recv(&mut self) -> Result<Json, ServiceError> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => return Err(self.lost("connection closed (worker died?)")),
            Err(e) => return Err(self.lost(&format!("read failed: {e}"))),
            Ok(_) => {}
        }
        let resp = json::parse(line.trim())
            .map_err(|e| self.lost(&format!("bad response: {e}")))?;
        if resp.get("ok").and_then(Json::as_bool) == Some(true) {
            Ok(resp)
        } else {
            Err(crate::service::protocol::error_from_response(&resp))
        }
    }

    fn request(&mut self, req: &Json) -> Result<Json, ServiceError> {
        self.send(req)?;
        self.recv()
    }
}

/// The sharded counterpart of [`crate::parafac2::FitSession`]: same
/// step/finish surface, same `IterationRecord`s, but every per-subject
/// phase runs in the shard workers and the coordinator replays the
/// deterministic merge (module docs). Trajectory is bitwise identical to
/// a local fit of the same config.
pub struct ShardedFitSession {
    cfg: Parafac2Config,
    conns: Vec<ShardConn>,
    factors: CpFactors,
    j: usize,
    k: usize,
    x_norm_sq: f64,
    x_norm: f64,
    /// `‖Y‖²` of the last sweep (flat subject-order fold of shipped bits).
    y_norm_sq: f64,
    stats: FitStats,
    total_sw: Stopwatch,
    prev_sse: f64,
    iters_done: usize,
    converged: bool,
    cancel: Arc<AtomicBool>,
}

impl ShardedFitSession {
    /// Connect to every worker, deal out the global chunk plan, and have
    /// each shard load + pack its subject range. `data` is only read for
    /// its shape, per-subject nnz (the global plan), and init — it is
    /// dropped before the first iteration; the workers load their ranges
    /// from `spec.path`.
    pub fn new(
        data: IrregularTensor,
        cfg: &Parafac2Config,
        spec: &ShardSpec,
        cancel: Option<Arc<AtomicBool>>,
    ) -> Result<ShardedFitSession, ServiceError> {
        if cfg.rank == 0 {
            return Err(ServiceError::Invalid("rank must be ≥ 1".into()));
        }
        if cfg.rank > data.j() {
            return Err(ServiceError::Invalid(format!(
                "rank {} exceeds variable count J={}",
                cfg.rank,
                data.j()
            )));
        }
        if spec.addrs.is_empty() {
            return Err(ServiceError::Invalid("no shard addresses".into()));
        }
        if !matches!(cfg.backend, Backend::Spartan) {
            return Err(ServiceError::Invalid(
                "sharded fitting requires the spartan engine (the workers run the fused sweep)"
                    .into(),
            ));
        }
        let total_sw = Stopwatch::start();

        // The same global plan a local fit would build; shard boundaries
        // align to its chunk boundaries (module docs, invariant 1).
        let plan = subject_plan(&data);
        let nc = plan.n_chunks();
        let ns = spec.addrs.len();
        if ns > nc {
            return Err(ServiceError::Invalid(format!(
                "{ns} shards but the plan has only {nc} chunks (fewer subjects than shards?)"
            )));
        }
        // Shard s owns the contiguous chunk run [s·nc/ns, (s+1)·nc/ns).
        let chunk_runs: Vec<Range<usize>> =
            (0..ns).map(|s| (s * nc / ns)..((s + 1) * nc / ns)).collect();

        // Init on the coordinator — bitwise identical to the local fit's
        // (the determinism contract covers pool-size independence).
        let init = initialize(&data, cfg.rank, cfg.init, cfg.seed, &Pool::serial());
        let factors = CpFactors { h: init.h, v: init.v, w: init.w };
        let (j, k) = (data.j(), data.k());
        drop(data);

        // Connect + handshake + plan, shard by shard. An early failure
        // aborts the shards already planned.
        let mut conns: Vec<ShardConn> = Vec::with_capacity(ns);
        let mut x_norm_parts: Vec<Vec<f64>> = Vec::with_capacity(ns);
        for (index, (addr, run)) in spec.addrs.iter().zip(&chunk_runs).enumerate() {
            let subjects = plan.ranges()[run.start].start..plan.ranges()[run.end - 1].end;
            let mut conn = match connect_shard(index, addr, subjects.clone(), spec) {
                Ok(c) => c,
                Err(e) => {
                    abort_all(&mut conns);
                    return Err(e);
                }
            };
            let lo = subjects.start;
            let ranges = Json::arr(plan.ranges()[run.clone()].iter().map(|r| {
                Json::arr(vec![
                    Json::num((r.start - lo) as f64),
                    Json::num((r.end - lo) as f64),
                ])
            }));
            let req = Json::obj(vec![
                ("verb", Json::str("plan")),
                ("path", Json::str(spec.path.clone())),
                ("lo", Json::num(lo as f64)),
                ("hi", Json::num(subjects.end as f64)),
                ("ranges", ranges),
            ]);
            let resp = match conn.request(&req) {
                Ok(r) => r,
                Err(e) => {
                    abort_all(&mut conns);
                    return Err(e);
                }
            };
            match parse_plan_reply(&resp, subjects.len(), j, &spec.path) {
                Ok(bits) => x_norm_parts.push(bits),
                Err(msg) => {
                    abort_all(&mut conns);
                    let _ = conn.request(&Json::obj(vec![("verb", Json::str("abort"))]));
                    return Err(ServiceError::Invalid(format!("shard {index} ({addr}): {msg}")));
                }
            }
            conns.push(conn);
        }

        // ‖X‖²: the flat per-slice fold `CompactX::norm_sq` runs locally,
        // replayed over all K slices in subject order.
        let x_norm_sq: f64 = x_norm_parts.iter().flatten().sum();
        let x_norm = x_norm_sq.sqrt();

        Ok(ShardedFitSession {
            cfg: cfg.clone(),
            conns,
            factors,
            j,
            k,
            x_norm_sq,
            x_norm,
            y_norm_sq: 0.0,
            stats: FitStats::default(),
            total_sw,
            prev_sse: f64::INFINITY,
            iters_done: 0,
            converged: false,
            cancel: cancel.unwrap_or_else(|| Arc::new(AtomicBool::new(false))),
        })
    }

    /// Fan a request out to every shard, then collect the responses in
    /// shard order (which *is* global subject/chunk order). Any failure
    /// aborts the surviving shards and surfaces [`ServiceError::ShardLost`]
    /// (or the worker's own typed error).
    fn fan(&mut self, req: &Json) -> Result<Vec<Json>, ServiceError> {
        for i in 0..self.conns.len() {
            if let Err(e) = self.conns[i].send(req) {
                abort_all(&mut self.conns);
                return Err(e);
            }
        }
        let mut out = Vec::with_capacity(self.conns.len());
        for i in 0..self.conns.len() {
            match self.conns[i].recv() {
                Ok(resp) => out.push(resp),
                Err(e) => {
                    abort_all(&mut self.conns);
                    return Err(e);
                }
            }
        }
        Ok(out)
    }

    /// One ALS iteration, mirroring [`crate::parafac2::FitSession::step`]
    /// checkpoint-for-checkpoint: cancel at entry, sweep, cancel (sweep
    /// discarded — workers just repeat it from the unchanged factors),
    /// then the CP step with each MTTKRP fanned out and merged.
    pub fn step(&mut self) -> Result<StepOutcome, ServiceError> {
        if self.converged || self.iters_done >= self.cfg.max_iters {
            return Ok(StepOutcome::Done);
        }
        if self.cancel.load(Ordering::Relaxed) {
            return Ok(StepOutcome::Cancelled);
        }
        let iter = self.iters_done;
        let r = self.cfg.rank;

        // --- step 1: fused Procrustes sweep, in the workers --------------
        let sw = Stopwatch::start();
        let replies = self.fan_sweep("sweep")?;
        let mut m1_partials: Vec<(Mat, u64)> = Vec::new();
        let mut y_bits: Vec<f64> = Vec::with_capacity(self.k);
        for (i, resp) in replies.iter().enumerate() {
            let parts = resp
                .get("m1")
                .ok_or("sweep reply missing m1")
                .and_then(|p| m1_partials_from_json(p).map_err(|_| "bad m1 partials"));
            let bits = resp
                .get("y_norm_bits")
                .ok_or("sweep reply missing y_norm_bits")
                .and_then(|b| f64_list_from_json(b).map_err(|_| "bad y_norm_bits"));
            match (parts, bits) {
                (Ok(p), Ok(b)) => {
                    m1_partials.extend(p);
                    y_bits.extend(b);
                }
                _ => {
                    abort_all(&mut self.conns);
                    return Err(self.conns[i].lost("malformed sweep reply"));
                }
            }
        }
        let procrustes_secs = sw.elapsed_secs();

        // Post-sweep cancellation checkpoint (sweep outputs + timing
        // discarded, exactly like the local session).
        if self.cancel.load(Ordering::Relaxed) {
            return Ok(StepOutcome::Cancelled);
        }
        self.stats.procrustes_secs += procrustes_secs;

        // --- step 2: one CP-ALS iteration, factor algebra local ----------
        // The exact sequence of `cp_iteration_from_m1`, with each MTTKRP
        // replaced by fan-out + the single-process merge.
        let sw = Stopwatch::start();
        self.y_norm_sq = y_bits.iter().sum();
        let fused = merge_fused_partials(m1_partials, r);

        // mode 1: H (M¹ was computed against the current V/W)
        let g1 = blas::hadamard(&blas::gram(&self.factors.w), &blas::gram(&self.factors.v));
        self.factors.h = solve::solve_gram_system(&fused.m1, &g1);
        normalize_cols_safe(&mut self.factors.h);

        // mode 2: V — workers consume the new H with their stored
        // (pre-update) W rows; partials scatter in global chunk order.
        let req = Json::obj(vec![
            ("verb", Json::str("mode2")),
            ("h", mat_to_json(&self.factors.h)),
        ]);
        let replies = self.fan(&req)?;
        let mut m2_partials: Vec<(Vec<u32>, Vec<f64>)> = Vec::new();
        for (i, resp) in replies.iter().enumerate() {
            match resp
                .get("m2")
                .ok_or_else(|| "mode2 reply missing m2".to_string())
                .and_then(|p| mode2_partials_from_json(p, r))
            {
                Ok(p) => m2_partials.extend(p),
                Err(_) => {
                    abort_all(&mut self.conns);
                    return Err(self.conns[i].lost("malformed mode2 reply"));
                }
            }
        }
        let m2 = mode2_merge(self.j, r, m2_partials);
        let g2 = blas::hadamard(&blas::gram(&self.factors.w), &blas::gram(&self.factors.h));
        self.factors.v = solve_mode(&m2, &g2, self.cfg.nonneg);
        normalize_cols_safe(&mut self.factors.v);

        // mode 3: W — each shard returns its K_s×R block; concatenation
        // is a pure row copy, so shard order = subject order suffices.
        let req = Json::obj(vec![
            ("verb", Json::str("mode3")),
            ("v", mat_to_json(&self.factors.v)),
        ]);
        let replies = self.fan(&req)?;
        let m3 = self.concat_m3(&replies, "m3")?;
        let g3 = blas::hadamard(&blas::gram(&self.factors.v), &blas::gram(&self.factors.h));
        self.factors.w = solve_mode(&m3, &g3, self.cfg.nonneg);

        let mut cp_stats = residual_stats(&m3, &self.factors, self.y_norm_sq);
        cp_stats.yv_products = fused.yv_products;
        let cp_secs = sw.elapsed_secs();
        self.stats.cp_secs += cp_secs;

        let sse = sse_from_parts(self.x_norm_sq, self.y_norm_sq, cp_stats.y_residual_sq);
        let fit = fit_from_sse(sse, self.x_norm);
        self.stats.fit_history.push(fit);
        self.iters_done = iter + 1;

        if sse_converged(self.prev_sse, sse, self.cfg.tol) {
            self.converged = true;
        }
        self.prev_sse = sse;

        Ok(StepOutcome::Iterated(IterationRecord { iter, sse, fit, procrustes_secs, cp_secs }))
    }

    /// Fan out a verb that ships the full current factors (this shard's
    /// `W` rows only — workers never see other shards' subjects).
    fn fan_sweep(&mut self, verb: &'static str) -> Result<Vec<Json>, ServiceError> {
        let r = self.cfg.rank;
        for i in 0..self.conns.len() {
            let subjects = self.conns[i].subjects.clone();
            let w_shard = self.factors.w.block(subjects.start, subjects.end, 0, r);
            let req = Json::obj(vec![
                ("verb", Json::str(verb)),
                ("v", mat_to_json(&self.factors.v)),
                ("h", mat_to_json(&self.factors.h)),
                ("w", mat_to_json(&w_shard)),
            ]);
            if let Err(e) = self.conns[i].send(&req) {
                abort_all(&mut self.conns);
                return Err(e);
            }
        }
        let mut out = Vec::with_capacity(self.conns.len());
        for i in 0..self.conns.len() {
            match self.conns[i].recv() {
                Ok(resp) => out.push(resp),
                Err(e) => {
                    abort_all(&mut self.conns);
                    return Err(e);
                }
            }
        }
        Ok(out)
    }

    /// Concatenate per-shard `K_s×R` blocks into the global `K×R` matrix
    /// (row copy only — no arithmetic, so no merge-order seam).
    fn concat_m3(&mut self, replies: &[Json], key: &str) -> Result<Mat, ServiceError> {
        let r = self.cfg.rank;
        let mut m3 = Mat::zeros(self.k, r);
        for (i, resp) in replies.iter().enumerate() {
            let block = match resp.get(key).map(mat_from_json) {
                Some(Ok(b)) => b,
                _ => {
                    abort_all(&mut self.conns);
                    return Err(self.conns[i].lost(&format!("malformed `{key}` block")));
                }
            };
            let subjects = self.conns[i].subjects.clone();
            if block.rows() != subjects.len() || block.cols() != r {
                abort_all(&mut self.conns);
                return Err(self.conns[i].lost(&format!(
                    "`{key}` block is {}×{}, expected {}×{r}",
                    block.rows(),
                    block.cols(),
                    subjects.len()
                )));
            }
            for (local, kk) in subjects.enumerate() {
                m3.row_mut(kk).copy_from_slice(block.row(local));
            }
        }
        Ok(m3)
    }

    /// Final pass, mirroring [`crate::parafac2::FitSession::finish`]: the
    /// workers refresh `Q_k` + `Y` from the fitted factors and report the
    /// standalone mode-3 MTTKRP, post-repack norms, and their counters;
    /// the coordinator recomputes the final SSE and assembles the model.
    /// Valid after any number of steps, including zero or a cancellation.
    pub fn finish(mut self) -> Result<Parafac2Model, ServiceError> {
        let replies = self.fan_sweep("finish")?;
        let mut qs: Vec<Mat> = Vec::with_capacity(self.k);
        let mut y_bits: Vec<f64> = Vec::with_capacity(self.k);
        let (mut yv, mut trav, mut xtrav, mut heap) = (0u64, 0u64, 0u64, 0u64);
        for (i, resp) in replies.iter().enumerate() {
            match parse_finish_reply(resp) {
                Ok((q, bits)) => {
                    if q.len() != self.conns[i].subjects.len() {
                        abort_all(&mut self.conns);
                        return Err(self.conns[i].lost("finish reply Q count mismatch"));
                    }
                    qs.extend(q);
                    y_bits.extend(bits);
                }
                Err(_) => {
                    abort_all(&mut self.conns);
                    return Err(self.conns[i].lost("malformed finish reply"));
                }
            }
            let counter = |k: &str| resp.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
            yv += counter("yv_products");
            trav += counter("traversals");
            xtrav += counter("x_traversals");
            heap += counter("heap_bytes");
        }
        self.y_norm_sq = y_bits.iter().sum();
        let m3 = self.concat_m3(&replies, "m3")?;
        let final_res = residual_stats(&m3, &self.factors, self.y_norm_sq);
        let final_sse = sse_from_parts(self.x_norm_sq, self.y_norm_sq, final_res.y_residual_sq);

        let mut stats = self.stats;
        stats.yv_products = yv;
        stats.traversals = trav;
        stats.x_traversals = xtrav;
        stats.heap_bytes = heap;
        stats.iterations = self.iters_done;
        stats.final_sse = final_sse;
        stats.final_fit = fit_from_sse(final_sse, self.x_norm);
        // The handshake pinned every worker to the coordinator's backend,
        // so the coordinator's name describes the whole topology.
        stats.kernel_backend = kernels::active_backend().name().to_string();
        stats.total_secs = self.total_sw.elapsed_secs();
        stats.secs_per_iter = if self.iters_done > 0 {
            (stats.procrustes_secs + stats.cp_secs) / self.iters_done as f64
        } else {
            0.0
        };

        Ok(Parafac2Model {
            rank: self.cfg.rank,
            h: self.factors.h,
            v: self.factors.v,
            w: self.factors.w,
            q: qs,
            stats,
        })
    }

    /// ALS iterations completed so far.
    pub fn iterations(&self) -> usize {
        self.iters_done
    }

    /// Whether the tol-based convergence test has fired.
    pub fn converged(&self) -> bool {
        self.converged
    }

    /// The session's cancel flag; setting it stops the fit within one ALS
    /// iteration (and the workers with it — they are request-driven).
    pub fn cancel_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.cancel)
    }
}

fn connect_shard(
    index: usize,
    addr: &str,
    subjects: Range<usize>,
    spec: &ShardSpec,
) -> Result<ShardConn, ServiceError> {
    let stream = TcpStream::connect(addr).map_err(|e| {
        ServiceError::ShardLost(format!("shard {index} ({addr}): connect failed: {e}"))
    })?;
    stream
        .set_read_timeout(Some(Duration::from_secs(spec.read_timeout_secs.max(1))))
        .map_err(|e| ServiceError::Io(e.to_string()))?;
    let reader = BufReader::new(
        stream.try_clone().map_err(|e| ServiceError::Io(e.to_string()))?,
    );
    let mut conn = ShardConn {
        index,
        addr: addr.to_string(),
        subjects,
        reader,
        writer: BufWriter::new(stream),
    };
    let ours = kernels::active_backend().name();
    let hello = Json::obj(vec![
        ("verb", Json::str("hello")),
        ("version", Json::num(PROTOCOL_VERSION as f64)),
        ("kernel_backend", Json::str(ours)),
    ]);
    let resp = conn.request(&hello)?;
    // The worker rejects a mismatch itself; re-checking its echo here
    // also catches a worker that answered without naming its backend.
    match resp.get("kernel_backend").and_then(Json::as_str) {
        Some(k) if k == ours => Ok(conn),
        Some(k) => Err(ServiceError::Invalid(format!(
            "shard {index} ({addr}): kernel backend mismatch: coordinator runs `{ours}`, \
             worker runs `{k}` (force a common backend with --kernel/SPARTAN_KERNEL)"
        ))),
        None => Err(ServiceError::Protocol(format!(
            "shard {index} ({addr}): hello reply missing `kernel_backend`"
        ))),
    }
}

/// Validate a `plan` reply against the coordinator's own view of the
/// dataset and pull out the per-slice ‖X_k‖² bits.
fn parse_plan_reply(
    resp: &Json,
    expect_k: usize,
    expect_j: usize,
    path: &str,
) -> Result<Vec<f64>, String> {
    let got_k = resp
        .get("k")
        .and_then(Json::as_usize)
        .ok_or("plan reply missing k")?;
    let got_j = resp
        .get("j")
        .and_then(Json::as_usize)
        .ok_or("plan reply missing j")?;
    if got_k != expect_k || got_j != expect_j {
        return Err(format!(
            "worker packed K={got_k}, J={got_j}; expected K={expect_k}, J={expect_j} — \
             is `{path}` the same dataset?"
        ));
    }
    f64_list_from_json(resp.get("x_norm_bits").ok_or("missing x_norm_bits")?)
}

/// Pull the per-subject `Q_k` factors and post-repack ‖Y_k‖² bits out of
/// a `finish` reply.
fn parse_finish_reply(resp: &Json) -> Result<(Vec<Mat>, Vec<f64>), String> {
    let q = resp
        .get("q")
        .and_then(Json::as_arr)
        .ok_or("finish reply missing q")?
        .iter()
        .map(mat_from_json)
        .collect::<Result<Vec<Mat>, String>>()?;
    let bits = f64_list_from_json(resp.get("y_norm_bits").ok_or("missing y_norm_bits")?)?;
    Ok((q, bits))
}

/// Best-effort abort fan-out: tell every surviving worker to drop its
/// per-fit state. Failures are ignored — the shard may be the one that
/// just died.
fn abort_all(conns: &mut [ShardConn]) {
    let req = Json::obj(vec![("verb", Json::str("abort"))]);
    for conn in conns.iter_mut() {
        let _ = conn.request(&req);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_spec_defaults_timeout() {
        let spec = ShardSpec::new(vec!["127.0.0.1:1".into()], "data.spt");
        assert_eq!(spec.read_timeout_secs, DEFAULT_READ_TIMEOUT_SECS);
        assert_eq!(spec.path, "data.spt");
    }

    #[test]
    fn worker_rejects_out_of_order_and_unplanned_requests() {
        let mut state: Option<WorkerFit> = None;
        let (resp, quit) = dispatch_worker(&mut state, 1, r#"{"verb":"sweep"}"#);
        assert!(!quit);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(resp.get("kind").and_then(Json::as_str), Some("invalid"));
        let (resp, _) = dispatch_worker(&mut state, 1, r#"{"verb":"nope"}"#);
        assert_eq!(resp.get("kind").and_then(Json::as_str), Some("protocol"));
    }

    #[test]
    fn hello_handshake_enforces_protocol_version() {
        let mut state: Option<WorkerFit> = None;
        let ours = kernels::active_backend().name();
        let ok_line = format!(
            r#"{{"verb":"hello","version":{PROTOCOL_VERSION},"kernel_backend":"{ours}"}}"#
        );
        let (resp, _) = dispatch_worker(&mut state, 1, &ok_line);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("kernel_backend").and_then(Json::as_str), Some(ours));
        let bad_line = format!(
            r#"{{"verb":"hello","version":{},"kernel_backend":"{ours}"}}"#,
            PROTOCOL_VERSION + 1
        );
        let (resp, _) = dispatch_worker(&mut state, 1, &bad_line);
        assert_eq!(resp.get("kind").and_then(Json::as_str), Some("invalid"));
        assert!(resp
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("")
            .contains("version mismatch"));
    }

    #[test]
    fn hello_handshake_rejects_mixed_kernel_backends() {
        let mut state: Option<WorkerFit> = None;
        // A coordinator on a backend this worker is not running (any name
        // that differs from the worker's active one — the active backend
        // is never the scalar reference under auto-selection, and if it
        // were forced to scalar, `avx512` still differs).
        let theirs =
            if kernels::active_backend() == kernels::KernelBackend::Scalar { "avx512" } else { "scalar" };
        let line = format!(
            r#"{{"verb":"hello","version":{PROTOCOL_VERSION},"kernel_backend":"{theirs}"}}"#
        );
        let (resp, _) = dispatch_worker(&mut state, 1, &line);
        assert_eq!(resp.get("kind").and_then(Json::as_str), Some("invalid"));
        assert!(resp
            .get("error")
            .and_then(Json::as_str)
            .unwrap_or("")
            .contains("kernel backend mismatch"));
        // And a hello that omits the field entirely is a protocol error.
        let line = format!(r#"{{"verb":"hello","version":{PROTOCOL_VERSION}}}"#);
        let (resp, _) = dispatch_worker(&mut state, 1, &line);
        assert_eq!(resp.get("kind").and_then(Json::as_str), Some("protocol"));
    }

    #[test]
    fn shard_split_requires_no_more_shards_than_chunks() {
        use crate::datagen::synthetic::{generate, SyntheticSpec};
        let data = generate(&SyntheticSpec {
            k: 4,
            j: 6,
            max_i_k: 3,
            target_nnz: 40,
            rank: 2,
            noise: 0.0,
            seed: 5,
        })
        .tensor;
        // 4 subjects → the plan has at most 4 chunks; 99 shards can't split.
        let spec = ShardSpec::new(
            (0..99).map(|i| format!("127.0.0.1:{}", 20_000 + i)).collect(),
            "unused.spt",
        );
        let cfg = Parafac2Config { rank: 2, ..Default::default() };
        match ShardedFitSession::new(data, &cfg, &spec, None) {
            Err(ServiceError::Invalid(msg)) => assert!(msg.contains("chunks")),
            other => panic!("expected Invalid, got {:?}", other.map(|_| ())),
        }
    }
}
